"""Probe: tree verify_step exactness contracts, all seven family archs.

1. chain-0-vs-linear: chain 0 occupies the same store columns as a linear
   window, so its logits must be BIT-identical to linear verify.  The
   linear window is padded with dummy tokens to the tree's T=1+fan*depth
   (causality keeps the first 1+depth logits independent of the tail) so
   both runs share one window shape — plain linear verify already drifts
   ulps across DIFFERENT window sizes (MLA dot shapes, moe capacity).
2. tree dense-vs-paged: the same tree window on the dense cache and the
   paged pool must produce bit-identical node logits (the PAGED_BITEXACT
   contract extended to tree windows).
3. relocation: after accepting a non-zero chain, tree_relocate + commit on
   both layouts must give bit-identical follow-up window logits.

Chains at non-zero fan offsets score the same math as a linear run but sum
the softmax at different store indices, so vs-linear they drift by ulps —
that leg is intentionally not asserted bitwise.

Run: PYTHONPATH=src python scripts/probe_tree_verify.py
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "tests")
from helpers import FAMILY_ARCHS, setup_family  # noqa: E402

from repro.models import (  # noqa: E402
    commit_verify,
    init_cache,
    init_paged_cache,
    prefill,
    tree_relocate,
    verify_step,
)


def dense_setup(cfg, params, prompt, extras, max_seq):
    b, s = prompt.shape
    cache = init_cache(cfg, b, max_seq)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    pos = jnp.full((b,), s - 1, jnp.int32)
    return cache, tok, pos


def paged_setup(cfg, params, prompt, extras, max_seq, ps):
    b, s = prompt.shape
    if max_seq % ps:
        max_seq += ps - max_seq % ps
    width = max_seq // ps
    npages = 1 + b * width
    cache = init_paged_cache(cfg, b, max_seq, npages, ps)
    bt = np.zeros((b, width), np.int32)
    spad = s + (-s) % ps
    toks = []
    for i in range(b):
        pages = 1 + i * width + np.arange(width)
        bt[i] = pages
        row = np.zeros((1, spad), np.int32)
        row[0, :s] = np.asarray(prompt[i])
        ex1 = None if extras is None else jax.tree.map(
            lambda a: jnp.asarray(a)[i : i + 1], extras)
        lg, cache = prefill(params, cfg, jnp.asarray(row), cache, ex1,
                            length=jnp.int32(s),
                            pages=jnp.asarray(pages[: spad // ps], jnp.int32),
                            slot=jnp.int32(i))
        toks.append(int(jnp.argmax(lg[0, s - 1])))
    cache = {**cache, "block_tables": jnp.asarray(bt)}
    tok = jnp.asarray(toks, jnp.int32)[:, None]
    pos = jnp.full((b,), s - 1, jnp.int32)
    return cache, tok, pos


def main():
    fan, depth, ps = 2, 2, 4
    bad = 0
    for arch in FAMILY_ARCHS:
        cfg, params, prompt, extras = setup_family(arch, b=2, s=8)
        b, s = prompt.shape
        max_seq = s + 12 + fan * depth  # page-aligned: dense == paged store
        ok = True

        # --- leg 1: chain 0 vs same-shape padded linear window, dense -----
        cache, tok, pos = dense_setup(cfg, params, prompt, extras, max_seq)
        chains = jax.random.randint(jax.random.PRNGKey(7), (b, fan, depth),
                                    0, cfg.vocab)
        window = jnp.concatenate([tok, chains.reshape(b, fan * depth)], 1)
        lg_tree, _ = verify_step(params, cfg, window, cache, pos, extras,
                                 tree=(fan, depth))
        pad = jnp.zeros((b, (fan - 1) * depth), jnp.int32)
        lin = jnp.concatenate([tok, chains[:, 0], pad], 1)
        lg_lin, _ = verify_step(params, cfg, lin, cache, pos, extras)
        if not bool(jnp.all(lg_tree[:, : 1 + depth] == lg_lin[:, : 1 + depth])):
            d = float(jnp.max(jnp.abs(lg_tree[:, : 1 + depth]
                                      - lg_lin[:, : 1 + depth])))
            print(f"  {arch}: chain0-vs-linear maxdiff={d:.3e}")
            ok = False

        # --- legs 2+3: tree + relocation, dense vs paged, stock cfg -------
        dc, dtok, dpos = dense_setup(cfg, params, prompt, extras, max_seq)
        pc, ptok, ppos = paged_setup(cfg, params, prompt, extras, max_seq, ps)
        if not bool(jnp.all(dtok == ptok)):
            print(f"  {arch}: prefill argmax differs dense vs paged")
            ok = False
        window = jnp.concatenate([dtok, chains.reshape(b, fan * depth)], 1)
        lg_d, vc_d = verify_step(params, cfg, window, dc, dpos, extras,
                                 tree=(fan, depth))
        lg_p, vc_p = verify_step(params, cfg, window, pc, ppos, extras,
                                 page_size=ps, tree=(fan, depth))
        if not bool(jnp.all(lg_d == lg_p)):
            d = float(jnp.max(jnp.abs(lg_d - lg_p)))
            print(f"  {arch}: tree dense-vs-paged maxdiff={d:.3e}")
            ok = False

        # accept chain 1 fully on both layouts
        a = jnp.full((b,), depth, jnp.int32)
        cf = jnp.ones((b,), jnp.int32)
        sel = 1 + cf * depth + (depth - 1)
        rc_d = commit_verify(cfg, tree_relocate(cfg, vc_d, dpos, a, cf,
                                                fan=fan, depth=depth), sel)
        rc_p = commit_verify(cfg, tree_relocate(cfg, vc_p, ppos, a, cf,
                                                fan=fan, depth=depth,
                                                page_size=ps), sel)
        nxt = jax.random.randint(jax.random.PRNGKey(8), (b, 2), 0, cfg.vocab)
        pos2 = dpos + depth + 1
        lg_a, _ = verify_step(params, cfg, nxt, rc_d, pos2, extras)
        lg_b, _ = verify_step(params, cfg, nxt, rc_p, pos2, extras,
                              page_size=ps)
        if not bool(jnp.all(lg_a == lg_b)):
            d = float(jnp.max(jnp.abs(lg_a - lg_b)))
            print(f"  {arch}: relocated follow-up dense-vs-paged "
                  f"maxdiff={d:.3e}")
            ok = False

        print(f"{arch}: {'OK' if ok else 'FAIL'}")
        bad += not ok
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
