"""Probe: reproduce the two moe dense-vs-paged divergence mechanisms and
check the fixes in ``models.moe.moe_apply``.

The fixed-batch engine prefills B rows in one batch while the continuous
engine admits batch-1 prompts, so historically the same row went through
different dispatch groupings between engines.  Two distinct bugs followed:

1. REDUCTION ORDER (ulp-scale, amplified to ~1e-3 across layers): the old
   combine ``einsum("ebcd,bsec->bsd")`` reduced jointly over (E, C); the k
   nonzero products sat at capacity-dependent flat offsets, so different C
   gave different float association.  Fixed by gathering each (token, slot)
   expert output exactly (<= 1 nonzero per slot) and reducing over the
   fixed top-k axis.

2. CROSS-ROW CAPACITY DROPS (semantic, ~1e-2): the old grouping flattened
   all B*S tokens and split by GROUP_TOKENS, merging rows into shared
   expert buffers — row 1's tokens faced buffers pre-filled by row 0, so
   its drops changed with batch composition.  Fixed by grouping per row
   (splitting only rows longer than the budget), making routing a per-row
   function.

Run: PYTHONPATH=src python scripts/probe_moe_exact.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import moe as M
from repro.models.common import dq, linear


def moe_apply_old(p, x, cfg, exact_combine: bool):
    """The PRE-fix moe_apply: cross-row merged grouping, and optionally the
    old joint (E, C) combine — kept here as the historical repro."""
    b0, s0, d = x.shape
    t = b0 * s0
    gt = cfg.group_tokens or M.GROUP_TOKENS
    n_groups = max(1, -(-t // gt))
    if t % n_groups == 0:
        x = x.reshape(n_groups, t // n_groups, d)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = M._capacity(s, cfg)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    sel = jax.nn.one_hot(top_i, e, dtype=jnp.float32)
    sel_flat = sel.reshape(b, s * k, e)
    pos_in_e = jnp.cumsum(sel_flat, axis=1) - 1.0
    pos = jnp.einsum("bte,bte->bt", pos_in_e, sel_flat).reshape(b, s, k)
    keep = (pos < cap).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("bske,bskc->bsec", sel, pos_oh)

    xe = jnp.einsum("bsd,bsec->ebcd", x.astype(jnp.float32), disp)
    xe = xe.astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, dq(p["gate"], xe.dtype)))
    h = h * jnp.einsum("ebcd,edf->ebcf", xe, dq(p["up"], xe.dtype))
    ye = jnp.einsum("ebcf,efd->ebcd", h, dq(p["down"], h.dtype))

    if exact_combine:
        ye_g = jnp.einsum("ebcd,bske,bskc->bskd", ye.astype(jnp.float32),
                          sel, pos_oh)
        y = jnp.einsum("bsk,bskd->bsd", top_p, ye_g).astype(x.dtype)
    else:
        comb = jnp.einsum("bske,bskc,bsk->bsec", sel, pos_oh, top_p)
        y = jnp.einsum("ebcd,bsec->bsd", ye.astype(jnp.float32),
                       comb).astype(x.dtype)

    if "shared" in p:
        sh = p["shared"]
        y = y + linear(jax.nn.silu(linear(x, sh["gate"])) * linear(x, sh["up"]),
                       sh["down"])
    return y.reshape(b0, s0, d)


def rowwise(fn, x):
    return jnp.concatenate([fn(x[i : i + 1]) for i in range(x.shape[0])], 0)


def main():
    d = 32
    key = jax.random.PRNGKey(0)
    kp, kx = jax.random.split(key)
    x = jax.random.normal(kx, (4, 16, d), jnp.float32)

    # Mechanism 1: merged grouping + joint combine, drop-free capacity —
    # pure reduction-order divergence.
    cfg = MoEConfig(n_experts=8, n_shared=1, top_k=3, d_ff_expert=64,
                    capacity_factor=8.0, group_tokens=4096)
    p = M.moe_init(kp, d, cfg, jnp.float32)
    f_old = lambda xx: moe_apply_old(p, xx, cfg, exact_combine=False)
    f_ex = lambda xx: moe_apply_old(p, xx, cfg, exact_combine=True)
    d1 = float(jnp.max(jnp.abs(f_old(x) - rowwise(f_old, x))))
    e1 = bool(jnp.all(f_ex(x) == rowwise(f_ex, x)))
    print(f"merged grouping, joint combine:  max|diff|={d1:.3e} (ulp drift)")
    print(f"merged grouping, exact combine:  bitexact={e1} (drop-free cap)")

    # Mechanism 2: merged grouping at STOCK capacity — cross-row drops.
    cfg2 = MoEConfig(n_experts=8, n_shared=1, top_k=3, d_ff_expert=64,
                     capacity_factor=1.25, group_tokens=4096)
    p2 = M.moe_init(kp, d, cfg2, jnp.float32)
    f2 = lambda xx: moe_apply_old(p2, xx, cfg2, exact_combine=True)
    d2 = float(jnp.max(jnp.abs(f2(x) - rowwise(f2, x))))
    print(f"merged grouping, stock capacity: max|diff|={d2:.3e} "
          f"(cross-row drops)")

    # The shipped moe_apply: per-row grouping + exact combine — bitexact
    # batched-vs-rowwise even at stock (dropping) capacity.
    f_new = lambda xx: M.moe_apply(p2, xx, cfg2)[0]
    e3 = bool(jnp.all(f_new(x) == rowwise(f_new, x)))
    print(f"shipped moe_apply, stock capacity: bitexact={e3}")
    return 0 if (d1 > 0 and e1 and e3) else 1


if __name__ == "__main__":
    raise SystemExit(main())
