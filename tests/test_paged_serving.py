"""Continuous batching on the paged KV cache.

Parity: paged-cache decode must be token-identical (greedy) to dense-cache
decode across all six families, under random block-table permutations
(``page_alloc_seed`` shuffles the free list, so physical page placement is
arbitrary), and under staggered admit/retire (each request's tokens equal a
solo run).  Scheduler: stop-token retirement, page accounting, recompute
preemption.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import FAMILY_ARCHS, assert_serve_matches_solo, setup_family as _setup

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    mask_after_stop,
    pim_bytes,
    quantize_tree,
)


# ------------------------------------------------------- paged/dense parity -
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_matches_dense_all_families(arch):
    """Greedy tokens from the paged continuous engine == the dense
    fixed-batch engine, with the free list shuffled so block tables are a
    random permutation of physical pages."""
    cfg, params, prompt, extras = _setup(arch)
    dense = ServingEngine(cfg, params, max_seq=16)
    want = np.asarray(dense.generate(prompt, n_new=5, extras=extras))
    paged = ContinuousBatchingEngine(
        cfg, params, slots=2, max_seq=16, page_size=4, chunk=4,
        page_alloc_seed=7)
    got = np.asarray(paged.generate(prompt, n_new=5, extras=extras))
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_paged_block_table_permutations(seed):
    """Decode is layout-independent: any permutation of physical pages
    behind the block tables yields identical tokens."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    base = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                    page_size=4, chunk=4)
    want = np.asarray(base.generate(prompt, n_new=6))
    perm = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                    page_size=4, chunk=4,
                                    page_alloc_seed=seed)
    np.testing.assert_array_equal(want, np.asarray(perm.generate(prompt, n_new=6)))


def test_paged_matches_dense_int8_kv_and_pim_weights():
    """The quantized serving stack end-to-end: int8 KV page pools + int8 PIM
    weights still decode token-identically to the dense engine."""
    cfg = get_reduced("qwen2-1.5b").replace(kv_cache_bits=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    dense = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
    want = np.asarray(dense.generate(prompt, n_new=5))
    paged = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                     page_size=4, chunk=4, pim_bits=8,
                                     page_alloc_seed=11)
    np.testing.assert_array_equal(want, np.asarray(paged.generate(prompt, n_new=5)))


# ------------------------------------------------------- scheduler behavior -
def test_per_request_extras_follow_the_request():
    """extras ride on the Request, not the slot: with more requests than
    slots, a request admitted into a freed slot must still be conditioned
    on its own image embeds — each output equals a solo dense run with that
    request's extras."""
    cfg = get_reduced("llama-3.2-vision-90b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req = 4
    embeds = jax.random.normal(
        jax.random.PRNGKey(2),
        (n_req, cfg.vision.n_image_tokens, cfg.d_model))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                    max_new=m, extras={"image_embeds": embeds[i]})
            for i, m in enumerate([3, 6, 4, 5])]
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=2)
    outs = eng.serve(reqs)
    dense = ServingEngine(cfg, params, max_seq=16)
    for i, (r, got) in enumerate(zip(reqs, outs)):
        want = np.asarray(dense.generate(
            jnp.asarray(r.prompt)[None], r.max_new,
            extras={"image_embeds": embeds[i : i + 1]}))[0]
        np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "falcon-mamba-7b"])
def test_scheduler_staggered_matches_solo(arch):
    """More requests than slots, mixed (non-page-multiple) prompt lengths
    and max_new: every request's tokens must equal running it alone on the
    dense engine — admit/retire staggering and padded-prompt prefill
    (length-masked SSM state) must not leak across slots."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shapes = [(5, 4), (7, 6), (3, 3), (9, 5), (4, 7)]
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=L).astype(np.int32),
                    max_new=m) for L, m in shapes]
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3, page_alloc_seed=1)
    outs = eng.serve(reqs)
    dense = ServingEngine(cfg, params, max_seq=24)
    for r, got in zip(reqs, outs):
        want = np.asarray(
            dense.generate(jnp.asarray(r.prompt)[None], r.max_new))[0]
        np.testing.assert_array_equal(want, got)
    # With 2 slots over 5 mixed-length requests the pool never needs the
    # dense worst case (slots * max_seq tokens of cache).
    assert eng.peak_pages_in_use < eng.slots * eng.width


def test_scheduler_stop_token_retires_early():
    """A stop token ends the request's output at the stop token and frees
    its slot/pages (the continuous engine's real early-exit, vs the fixed
    engine's post-masking)."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    dense = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(dense.generate(prompt, n_new=6))
    stop = int(base[0, 3])
    first = int(np.argmax(base[0] == stop))  # first occurrence in row 0
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=2)
    outs = eng.serve([
        Request(prompt=np.asarray(prompt[0]), max_new=6, stop_tokens=(stop,)),
        Request(prompt=np.asarray(prompt[1]), max_new=6),
    ])
    np.testing.assert_array_equal(outs[0], base[0, : first + 1])
    assert outs[0][-1] == stop
    np.testing.assert_array_equal(outs[1], base[1])
    assert eng.pages_in_use() == 0  # everything retired -> pages freed


def test_scheduler_preemption_recomputes():
    """A pool too small for both requests triggers recompute preemption of
    the younger one; outputs still match solo runs exactly."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=32,
                                   page_size=4, num_pages=9, chunk=4)
    reqs = [Request(prompt=np.asarray(prompt[0]), max_new=20),
            Request(prompt=np.asarray(prompt[1]), max_new=20)]
    outs = eng.serve(reqs)
    assert eng.preemptions > 0
    dense = ServingEngine(cfg, params, max_seq=32)
    for r, got in zip(reqs, outs):
        want = np.asarray(
            dense.generate(jnp.asarray(r.prompt)[None], r.max_new))[0]
        np.testing.assert_array_equal(want, got)


def test_scheduler_rejects_oversized_request():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_seq=16,
                                   page_size=4)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve([Request(prompt=np.asarray(prompt[0]), max_new=100)])


# ------------------------------------------------- fixed-engine stop tokens -
def test_fixed_engine_stop_tokens_mask_after_stop():
    """ServingEngine.generate masks post-stop emissions with pad_id; the
    per-token oracle agrees."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(eng.generate(prompt, n_new=6))
    stop = int(base[0, 0])
    got = np.asarray(eng.generate(prompt, n_new=6, stop_tokens=(stop,),
                                  pad_id=-1))
    ref = np.asarray(eng.generate_reference(prompt, n_new=6,
                                            stop_tokens=(stop,), pad_id=-1))
    np.testing.assert_array_equal(got, ref)
    for row_base, row in zip(base, got):
        hits = np.flatnonzero(row_base == stop)
        if hits.size:  # stop kept, everything after masked
            t = hits[0]
            np.testing.assert_array_equal(row[: t + 1], row_base[: t + 1])
            assert (row[t + 1 :] == -1).all()
        else:
            np.testing.assert_array_equal(row, row_base)


def test_reference_sampling_matches_scan():
    """generate_reference mirrors generate's sampling options and key-split
    order — one parity oracle for greedy AND sampled decoding."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
    k = jax.random.PRNGKey(5)
    a = np.asarray(eng.generate(prompt, n_new=6, greedy=False,
                                temperature=0.8, top_k=8, key=k))
    b = np.asarray(eng.generate_reference(prompt, n_new=6, greedy=False,
                                          temperature=0.8, top_k=8, key=k))
    np.testing.assert_array_equal(a, b)


# -------------------------------------------------- stop-token edge cases ---
def test_mask_after_stop_edge_positions():
    """Stop in the LAST emitted position masks nothing (there is no 'after');
    stop in the first position masks the whole tail; with multiple stop
    tokens the FIRST hit wins; the empty stop set is the identity."""
    toks = jnp.asarray([
        [1, 2, 3, 9],   # stop 9 at the last position: row unchanged
        [9, 1, 2, 3],   # stop at position 0: everything after -> pad
        [1, 9, 5, 2],   # stops 9 AND 5 present: mask after the FIRST (9)
        [1, 2, 3, 4],   # no stop: unchanged
    ], jnp.int32)
    out = np.asarray(mask_after_stop(toks, (9, 5), pad_id=-1))
    np.testing.assert_array_equal(out, [
        [1, 2, 3, 9],
        [9, -1, -1, -1],
        [1, 9, -1, -1],
        [1, 2, 3, 4],
    ])
    np.testing.assert_array_equal(np.asarray(mask_after_stop(toks, ())), toks)


def test_mask_after_stop_repeated_stop_token():
    """A second occurrence of the stop token is itself masked — only the
    first survives."""
    toks = jnp.asarray([[9, 9, 1, 9]], jnp.int32)
    out = np.asarray(mask_after_stop(toks, (9,), pad_id=0))
    np.testing.assert_array_equal(out, [[9, 0, 0, 0]])


def test_scheduler_stop_in_prompt_does_not_retire():
    """Stop tokens apply to EMITTED tokens only: a prompt that ends with the
    stop token must still decode its full budget."""
    cfg, params, prompt, _ = _setup("starcoder2-7b")
    dense = ServingEngine(cfg, params, max_seq=16)
    # find a stop value whose placement as the prompt's last token yields
    # emissions that never hit it (rewriting the prompt changes the
    # emissions, so check against the rewritten prompt's solo run)
    for stop in {int(t) for t in np.asarray(prompt[0])} | {0, 1, 7}:
        p0 = np.asarray(prompt[0]).copy()
        p0[-1] = stop  # stop token in the prompt's LAST position
        solo = np.asarray(dense.generate(jnp.asarray(p0)[None], 5))[0]
        if stop not in solo:
            break
    else:
        pytest.skip("fixture regression: every candidate re-emits the stop")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=2)
    outs = eng.serve([Request(prompt=p0, max_new=5, stop_tokens=(stop,))])
    np.testing.assert_array_equal(outs[0], solo)  # full budget emitted


def test_scheduler_stop_at_exactly_max_new():
    """The stop token landing on the max_new-th (final) emission retires the
    request exactly once: output length == max_new, ends with the stop."""
    cfg, params, prompt, _ = _setup("starcoder2-7b")
    dense = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(dense.generate(prompt, n_new=6))
    n = 4
    stop = int(base[0, n - 1])
    assert stop not in base[0, : n - 1]  # first hit is the final emission
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=3)
    outs = eng.serve([Request(prompt=np.asarray(prompt[0]), max_new=n,
                              stop_tokens=(stop,))])
    np.testing.assert_array_equal(outs[0], base[0, :n])
    assert outs[0][-1] == stop and len(outs[0]) == n
    assert eng.pages_in_use() == 0


def test_scheduler_multiple_stops_in_one_chunk():
    """Two slots hitting their (different) stop tokens inside the SAME
    compiled chunk both truncate correctly and free their pages; a request
    with several stop tokens retires at whichever fires first."""
    cfg, params, prompt, _ = _setup("starcoder2-7b")
    dense = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(dense.generate(prompt, n_new=6))
    s0, s1 = int(base[0, 2]), int(base[1, 3])
    f0 = int(np.argmax(base[0] == s0))
    f1 = int(np.argmax(base[1] == s1))
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=6)  # one chunk covers all
    outs = eng.serve([
        Request(prompt=np.asarray(prompt[0]), max_new=6,
                stop_tokens=(s0, 255)),  # extra stop never fires
        Request(prompt=np.asarray(prompt[1]), max_new=6, stop_tokens=(s1,)),
    ])
    np.testing.assert_array_equal(outs[0], base[0, : f0 + 1])
    np.testing.assert_array_equal(outs[1], base[1, : f1 + 1])
    assert eng.pages_in_use() == 0


def test_fixed_engine_stop_at_exactly_n_new():
    """ServingEngine: a stop token on the last emitted position leaves the
    row unmasked (nothing comes after it)."""
    cfg, params, prompt, _ = _setup("starcoder2-7b")
    eng = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(eng.generate(prompt, n_new=5))
    stop = int(base[0, -1])
    if stop in base[0, :-1]:  # ensure LAST position is the first hit
        pytest.skip("fixture emits the stop earlier; covered elsewhere")
    got = np.asarray(eng.generate(prompt, n_new=5, stop_tokens=(stop,),
                                  pad_id=-1))
    np.testing.assert_array_equal(got[0], base[0])


# ------------------------------------------------- page rollback / reuse ----
@pytest.mark.parametrize("speculate", [None, 4])
def test_freed_pages_reused_after_retirement(speculate):
    """A pool far smaller than the trace's total page demand forces retired
    requests' pages to be re-issued to later admits; every request must
    still match its solo run — freed pages carry no ghost K/V (and, with
    speculation, no ghost speculative writes from their previous owner)."""
    cfg, params, _, _ = _setup("qwen2-1.5b")
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=L).astype(np.int32),
                    max_new=m)
            for L, m in [(6, 6), (5, 7), (8, 4), (7, 5), (4, 8), (6, 5),
                         (7, 6), (5, 6)]]
    ps, num_pages = 4, 10  # usable capacity: 9 pages
    demand = sum(-(-(len(r.prompt) + r.max_new) // ps) for r in reqs)
    assert demand > 2 * (num_pages - 1)  # reuse is forced, repeatedly
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_seq=16, page_size=ps, num_pages=num_pages,
        chunk=3, page_alloc_seed=5, speculate=speculate)
    assert_serve_matches_solo(eng, cfg, params, reqs)
    assert eng.pages_in_use() == 0  # everything retired -> all pages freed


@pytest.mark.parametrize("speculate", [None, 4])
def test_preemption_recompute_identical_tokens(speculate):
    """Recompute preemption frees the victim's pages mid-flight and
    re-admits it from scratch; tokens must be identical to solo runs —
    including when the freed pages contained speculative writes past the
    victim's accepted frontier."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_seq=32, page_size=4, num_pages=9, chunk=4,
        speculate=speculate)
    reqs = [Request(prompt=np.asarray(prompt[0]), max_new=20),
            Request(prompt=np.asarray(prompt[1]), max_new=20)]
    assert_serve_matches_solo(eng, cfg, params, reqs)
    assert eng.preemptions > 0


def test_speculative_rejected_writes_do_not_leak_across_slots():
    """Two slots interleave speculative windows whose rejected tail writes
    land beyond their accepted frontiers; a page-permuted pool must still
    reproduce the dense engine exactly (rejected writes stay confined to
    each slot's own pages / the trash page)."""
    cfg, params, prompt, _ = _setup("falcon-mamba-7b")
    dense = ServingEngine(cfg, params, max_seq=24)
    want = np.asarray(dense.generate(prompt, n_new=8))
    for seed in (0, 11):
        eng = ContinuousBatchingEngine(
            cfg, params, slots=2, max_seq=24, page_size=4, chunk=2,
            page_alloc_seed=seed, speculate=6)
        got = np.asarray(eng.generate(prompt, n_new=8))
        np.testing.assert_array_equal(want, got, err_msg=f"seed={seed}")


# ------------------------------------------------------------- pim_bytes ----
def test_pim_bytes_skips_int4_markers():
    """The nibbles/nibbles_odd marker leaves are packing metadata, not
    shipped HBM storage — pim_bytes must count codes + scales only."""
    w = {"odd": jnp.zeros((33, 16)), "even": jnp.zeros((32, 16))}
    q = quantize_tree(w, bits=4)
    assert "nibbles_odd" in q["odd"] and "nibbles" in q["even"]
    want = sum(
        leaf.size * leaf.dtype.itemsize
        for sub in q.values()
        for name, leaf in sub.items()
        if name in ("codes", "scale")
    )
    assert pim_bytes(q) == want


# ------------------------------------------------------ page-pool guards ----
def test_page_pool_quiescent_after_serve():
    """After every request retires, every page is back on the free list
    exactly once — the no-leak invariant serve_detailed also self-checks."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=3)
    eng.serve([Request(prompt=np.asarray(prompt[0]), max_new=6),
               Request(prompt=np.asarray(prompt[1]), max_new=8)])
    assert eng.pages_in_use() == 0
    eng.assert_quiescent()  # raises on leak or double-free


def test_free_pages_rejects_double_free():
    """A page freed twice would be issued to two slots at once and
    silently cross-corrupt their KV state — _free_pages must refuse."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=3)
    eng._reset([], 0)
    pages = eng._alloc_pages(2)
    eng._free_pages(pages)
    with pytest.raises(ValueError, match="double-free"):
        eng._free_pages(pages)
    with pytest.raises(ValueError, match="double-free"):
        eng._free_pages([0])  # the trash page never circulates


def test_alloc_pages_rejects_overdraw():
    """Allocating past the free list must fail loudly, not hand out a
    short page list that would silently alias the trash page."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, num_pages=5, chunk=3)
    eng._reset([], 0)
    with pytest.raises(RuntimeError, match="overdraw"):
        eng._alloc_pages(5)  # only 4 circulating pages (page 0 = trash)
    eng.assert_quiescent()  # failed alloc must not have taken anything


def test_quiescence_detects_injected_leak():
    """assert_quiescent actually fires: simulate a leaked page."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=3)
    eng._reset([], 0)
    eng._alloc_pages(1)  # taken, never freed
    with pytest.raises(AssertionError, match="page leak"):
        eng.assert_quiescent()
