"""Trip-count-aware HLO cost analyzer: scan == unrolled invariants."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_module


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt)


W = jnp.zeros((256, 256))
X = jnp.ones((32, 256))


def test_unrolled_matmul_flops_exact():
    def f(x, w):
        for _ in range(4):
            x = x @ w
        return x

    c = _cost(f, X, W)
    assert c.flops == 4 * 2 * 32 * 256 * 256


def test_scan_matches_unrolled():
    def unrolled(x, w):
        for _ in range(8):
            x = x @ w
        return x

    def scanned(x, w):
        return jax.lax.scan(lambda h, _: (h @ w, None), x, None, length=8)[0]

    cu, cs = _cost(unrolled, X, W), _cost(scanned, X, W)
    assert cs.flops == cu.flops
    # scan bookkeeping adds some bytes but must be the same order
    assert cs.bytes_accessed < 3 * cu.bytes_accessed


def test_nested_scan_multiplies():
    def nested(x, w):
        def outer(h, _):
            inner = jax.lax.scan(lambda g, _: (g @ w, None), h, None, length=4)[0]
            return inner, None

        return jax.lax.scan(outer, x, None, length=3)[0]

    c = _cost(nested, X, W)
    assert c.flops == 12 * 2 * 32 * 256 * 256


def test_remat_counts_recompute():
    """jax.checkpoint recompute must show up as extra flops in the bwd."""

    def loss(x, w):
        h = x
        for _ in range(2):
            h = jnp.tanh(h @ w)
        return jnp.sum(h)

    def loss_remat(x, w):
        f = jax.checkpoint(lambda h: jnp.tanh(jnp.tanh(h @ w) @ w))
        return jnp.sum(f(x))

    g_plain = _cost(jax.grad(loss), X, W)
    g_remat = _cost(jax.grad(loss_remat), X, W)
    assert g_remat.flops >= g_plain.flops  # recompute adds work


def test_collectives_inside_scan_scaled():
    """A psum inside a scanned body must count trip-count times."""
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("d",))

    # single-device: no real collectives emitted — just check the parser
    # handles a scanned module without crashing and finds the loop.
    def scanned(x, w):
        return jax.lax.scan(lambda h, _: (h @ w, None), x, None, length=6)[0]

    txt = jax.jit(scanned).lower(X, W).compile().as_text()
    c = analyze_hlo(txt)
    assert any(m >= 6 for m in c.loops.values())


def test_parse_module_finds_entry_and_regions():
    def scanned(x, w):
        return jax.lax.scan(lambda h, _: (h @ w, None), x, None, length=8)[0]

    txt = jax.jit(scanned).lower(X, W).compile().as_text()
    comps = parse_module(txt)
    assert any(n.startswith("main") for n in comps)
    assert any("region" in n for n in comps)
