"""Fault-injected serving: the resilience layer's behavioral contract.

Three layers of guarantees, all deterministic under seeded chaos
(``ChaosConfig.seed`` + ``VirtualClock`` — no wall-clock flake):

1. **Parity under transient faults** — chunk retries, injected stragglers,
   and page-pool squeezes must not change a single emitted token
   (``helpers.assert_chaos_parity``): the failure paths replay the exact
   scheduling decisions, and the fold_in draw-key discipline makes the
   token streams schedule-independent.
2. **Crash replay** — a crashed engine restarted by the
   ``ServingSupervisor`` (via ``runtime.fault.HeartbeatMonitor``) must
   finish every in-flight request token-identically, including sampled
   and speculative decode, including recovery into a FRESH engine object
   from the on-disk snapshot.
3. **Policy behavior** — deadlines shed expired queue entries, the
   bounded queue sheds lowest-SLO first, corrupt payloads are rejected at
   admission, and the degradation ladder escalates under pressure and
   recovers after clean rounds — all visible in the ``ServeReport``.
"""
import os

import jax
import numpy as np
import pytest
from helpers import (
    assert_chaos_parity,
    assert_tokens_identical,
    setup_family as _setup,
)

from repro.runtime.fault import HeartbeatMonitor
from repro.serving import (
    ChaosConfig,
    ChunkFault,
    ContinuousBatchingEngine,
    EngineCrash,
    FaultInjector,
    LadderConfig,
    Request,
    ResiliencePolicy,
    ServingSupervisor,
    VirtualClock,
    load_snapshot,
)

# The non-MLA, non-moe families: prefill and decode agree bit-wise, so
# resume_mode="prefill" crash replay is token-exact for them (MLA's
# absorbed decode differs from prefill at ~1e-3; moe gates amplify
# layout noise — they use resume_mode="recompute", covered separately).
PREFILL_EXACT_ARCHS = ["qwen2-1.5b", "falcon-mamba-7b", "zamba2-1.2b"]


def _requests(prompt, max_new=8, **kw):
    return [Request(prompt=np.asarray(p), max_new=max_new, **kw)
            for p in np.asarray(prompt, np.int32)]


# ------------------------------------------------------------ determinism ---
def test_fault_injector_deterministic_and_stream_independent():
    """Same seed -> same fault trace; and one site's schedule must not
    shift when another site draws more (independent per-site streams)."""
    cfg = ChaosConfig(seed=3, fault_rate=0.3, straggle_rate=0.3)

    def trace(n_straggle_calls):
        inj = FaultInjector(cfg)
        fired = []
        for rnd in range(50):
            try:
                inj.chunk_fault(rnd)
            except ChunkFault:
                fired.append(rnd)
            for _ in range(n_straggle_calls):
                inj.chunk_latency(rnd)
        return fired

    assert trace(1) == trace(1)  # seeded determinism
    assert trace(1) == trace(5)  # straggle draws don't shift chunk faults
    assert len(trace(1)) > 0


def test_virtual_clock_monotonic():
    clk = VirtualClock()
    assert clk() == 0.0
    clk.advance(1.5)
    assert clk() == 1.5
    with pytest.raises(ValueError):
        clk.advance(-1.0)


def test_scripted_schedules_fire_exactly():
    inj = FaultInjector(ChaosConfig(fault_rounds=(2,), crash_rounds=(1,)))
    inj.chunk_fault(0)
    inj.crash(0)
    with pytest.raises(EngineCrash):
        inj.crash(1)
    inj.chunk_fault(1)
    with pytest.raises(ChunkFault):
        inj.chunk_fault(2)
    assert inj.counts == {"chunk": 1, "crash": 1}


# --------------------------------------------------- parity under faults ----
def test_retry_parity_under_chunk_faults():
    """Transient chunk faults retry with backoff; tokens unchanged."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    _, report = assert_chaos_parity(
        cfg, params, _requests(prompt), ChaosConfig(seed=0, fault_rate=0.4))
    assert report.retries > 0


def test_straggler_parity_and_latency_accounting():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    _, report = assert_chaos_parity(
        cfg, params, _requests(prompt),
        ChaosConfig(seed=1, straggle_rate=0.5, straggle_s=0.25))
    assert report.straggle_s > 0
    # injected latency shows up in completion times (virtual skew)
    assert all(r.t_done > 0 for r in report.records if r.status == "done")


def test_squeeze_parity_forces_preemption_path():
    """Withholding free pages pushes the scheduler down its recompute-
    preemption path; tokens must still match the undisturbed run."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    reqs = _requests(prompt, max_new=16)
    _, report = assert_chaos_parity(
        cfg, params, reqs,
        ChaosConfig(seed=5, squeeze_rate=0.8, squeeze_frac=0.9),
        engine_kw=dict(max_seq=32, num_pages=11))
    assert report.squeezed_pages > 0


def test_combined_chaos_parity_sampled():
    """All transient modes at once, under temperature/top-k sampling —
    the strongest single-engine parity statement."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    _, report = assert_chaos_parity(
        cfg, params, _requests(prompt),
        ChaosConfig(seed=2, fault_rate=0.25, straggle_rate=0.25,
                    squeeze_rate=0.4, squeeze_frac=0.5),
        greedy=False, temperature=0.8, top_k=8)
    assert report.retries + report.squeezed_pages > 0
    assert all(r.status == "done" for r in report.records)


def test_retry_exhaustion_escalates_to_crash():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3)
    inj = FaultInjector(ChaosConfig(fault_rounds=tuple(range(10))))
    with pytest.raises(EngineCrash, match="retries exhausted"):
        eng.serve_detailed(_requests(prompt), chaos=inj,
                           policy=ResiliencePolicy(max_retries=2))
    assert eng.last_snapshot is not None  # the supervisor's recovery point


# ----------------------------------------------------------- crash replay ---
@pytest.mark.parametrize("arch", PREFILL_EXACT_ARCHS)
def test_crash_replay_token_identical(arch):
    """Kill the engine twice mid-trace; the supervisor's snapshot-replay
    must finish every request with the undisturbed run's exact tokens
    (resume_mode='prefill': in-flight requests re-admit mid-stream)."""
    cfg, params, prompt, _ = _setup(arch)
    reqs = _requests(prompt, max_new=10)
    key = jax.random.PRNGKey(7)

    def engine():
        return ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                        page_size=4, chunk=3)

    want = engine().serve(reqs, key=key)
    clk = VirtualClock()
    eng = engine()
    eng._clock = clk
    sup = ServingSupervisor(
        eng, policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(1, 3))), clock=clk)
    report = sup.run(reqs, key=key)
    assert report.restarts == 2
    assert [f.kind.startswith("crash") for f in report.failures] == [True] * 2
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"{arch} req {i}")


def test_crash_replay_sampled_speculative():
    """Crash replay under sampled speculative decode: the wctr snapshot
    restores the verify-window draw counter, so rejection-sampling draws
    continue the exact stream."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    reqs = _requests(prompt, max_new=10)
    key = jax.random.PRNGKey(9)
    kw = dict(slots=2, max_seq=24, page_size=4, chunk=3, speculate=3)
    skw = dict(greedy=False, temperature=0.8, top_k=8, key=key)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs, **skw)
    sup = ServingSupervisor(
        ContinuousBatchingEngine(cfg, params, **kw),
        policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(1,))))
    report = sup.run(reqs, **skw)
    assert report.restarts == 1
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")


def test_crash_replay_resume_recompute():
    """resume_mode='recompute' requeues in-flight requests from scratch
    (the universally exact mode): same tokens, no mid-stream re-admit."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    reqs = _requests(prompt, max_new=8)
    key = jax.random.PRNGKey(3)
    kw = dict(slots=2, max_seq=24, page_size=4, chunk=3)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs, key=key)
    sup = ServingSupervisor(
        ContinuousBatchingEngine(cfg, params, **kw),
        policy=ResiliencePolicy(resume_mode="recompute"),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(2,))))
    report = sup.run(reqs, key=key)
    assert report.restarts == 1
    for i, rec in enumerate(report.records):
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")


def test_crash_recovery_into_fresh_engine_from_disk(tmp_path):
    """The crash takes the engine OBJECT with it: a brand-new engine plus
    the persisted snapshot file must resume the trace token-identically —
    real process-death recovery, not just in-memory retry."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    reqs = _requests(prompt, max_new=10)
    key = jax.random.PRNGKey(5)
    kw = dict(slots=2, max_seq=24, page_size=4, chunk=3)
    snap_file = str(tmp_path / "serve.snap")
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs, key=key)

    # First life: crash at round 1, snapshots persisted to disk.
    sup1 = ServingSupervisor(
        ContinuousBatchingEngine(cfg, params, **kw),
        policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(1,))),
        max_restarts=0, snapshot_path=snap_file)
    with pytest.raises(EngineCrash):
        sup1.run(reqs, key=key)
    snap = load_snapshot(snap_file)
    assert snap is not None and snap.inflight  # mid-trace recovery point

    # Second life: fresh engine, fresh supervisor, same snapshot file.
    sup2 = ServingSupervisor(
        ContinuousBatchingEngine(cfg, params, **kw),
        policy=ResiliencePolicy(), snapshot_path=snap_file)
    report = sup2.run(reqs, key=key)
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")


def test_supervisor_heartbeat_detects_death():
    """The supervisor detects the crash through the HeartbeatMonitor (the
    engine stops beating), not just the exception: host 0 must transit
    dead -> revived around each restart."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    clk = VirtualClock()
    monitor = HeartbeatMonitor(1, timeout_s=5.0, clock=clk)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3)
    eng._clock = clk
    sup = ServingSupervisor(
        eng, policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(1,))),
        monitor=monitor, clock=clk)
    report = sup.run(_requests(prompt), key=jax.random.PRNGKey(0))
    assert report.restarts == 1
    assert monitor.healthy == [0]  # revived after the restart
    assert all(r.status == "done" for r in report.records)


def test_max_restarts_exhaustion_reraises():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    sup = ServingSupervisor(
        ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                 page_size=4, chunk=3),
        policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rate=1.0)), max_restarts=3)
    with pytest.raises(EngineCrash):
        sup.run(_requests(prompt))
    assert sup.restarts == 4  # 1 + max_restarts attempts


# ------------------------------------------------------- policy behavior ----
def test_corrupt_payload_rejected_not_served():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3)
    base = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                    page_size=4, chunk=3).serve(
        _requests(prompt))
    report = eng.serve_detailed(
        _requests(prompt), chaos=FaultInjector(
            ChaosConfig(corrupt_rids=(0,))))
    assert report.records[0].status == "rejected"
    assert report.records[0].reason == "corrupt"
    assert report.rejects == 1
    # the clean request is untouched by its neighbor's corruption
    assert report.records[1].status == "done"
    assert_tokens_identical(base[1], report.records[1].tokens)


def test_invalid_requests_rejected_under_policy_raise_without():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=3)
    bad = [Request(prompt=np.asarray(prompt[0]), max_new=200),  # > max_seq
           Request(prompt=np.asarray(prompt[1]), max_new=5)]
    with pytest.raises(ValueError):  # policy-less behavior is unchanged
        eng.serve(bad)
    report = eng.serve_detailed(bad, policy=ResiliencePolicy())
    assert report.records[0].status == "rejected"
    assert report.records[1].status == "done"


def test_deadline_sheds_expired_queue_entries():
    """With one slot and a per-round virtual cost, later queue entries
    expire before admission and are shed; the survivor still finishes."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    clk = VirtualClock()
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_seq=24,
                                   page_size=4, chunk=3, clock=clk)
    reqs = [Request(prompt=np.asarray(prompt[0]), max_new=8, deadline=100.0),
            Request(prompt=np.asarray(prompt[1]), max_new=8, deadline=0.5)]
    report = eng.serve_detailed(
        reqs, policy=ResiliencePolicy(round_time=1.0))
    assert report.records[0].status == "done"
    assert report.records[0].met_deadline is True
    assert report.records[1].status == "shed"
    assert report.records[1].reason == "deadline"
    assert report.sheds == 1
    assert report.slo_attainment() == 0.5


def test_deadline_miss_flagged_on_completion():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    clk = VirtualClock()
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3, clock=clk)
    reqs = [Request(prompt=np.asarray(prompt[0]), max_new=8, deadline=0.5)]
    # shed_expired off: the request runs to completion but misses.
    report = eng.serve_detailed(
        reqs, policy=ResiliencePolicy(shed_expired=False, round_time=1.0))
    assert report.records[0].status == "done"
    assert report.records[0].met_deadline is False
    assert report.goodput_tokens() == 0


def test_bounded_queue_sheds_lowest_slo_first():
    cfg, params, prompt, _ = _setup("qwen2-1.5b", b=2)
    p = np.asarray(prompt[0])
    reqs = [Request(prompt=p, max_new=6, slo=2),
            Request(prompt=p, max_new=6, slo=0),   # lowest class: shed
            Request(prompt=p, max_new=6, slo=1),
            Request(prompt=p, max_new=6, slo=2)]
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_seq=24,
                                   page_size=4, chunk=3)
    report = eng.serve_detailed(
        reqs, policy=ResiliencePolicy(max_queue=3))
    statuses = [r.status for r in report.records]
    assert statuses[1] == "shed" and report.records[1].reason == "queue"
    assert statuses.count("shed") == 1  # one over capacity, one victim
    assert all(s == "done" for i, s in enumerate(statuses) if i != 1)


def test_ladder_escalates_and_recovers_with_greedy_parity():
    """Sustained bad rounds (scripted stragglers) drive the ladder up
    (spec shrinks, then disables); clean rounds bring it back down — and
    greedy tokens never change (every rung is token-preserving)."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    reqs = _requests(prompt, max_new=16)
    # chunk=2/k=2 caps the per-round advance at 6 tokens so the 16-token
    # trace is guaranteed to span the escalations AND the cooldown.
    kw = dict(slots=2, max_seq=32, page_size=4, chunk=2, speculate=2)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    eng = ContinuousBatchingEngine(cfg, params, **kw)
    report = eng.serve_detailed(
        reqs, chaos=FaultInjector(ChaosConfig(straggle_rounds=(0, 1))),
        policy=ResiliencePolicy(ladder=LadderConfig(cooldown=2)))
    assert report.max_ladder_level >= 2  # at least halve_k -> no_spec
    assert any(reason == "recovered" for _, _, reason in report.ladder_trace)
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")


def test_ladder_top_rung_sheds_low_slo_queue():
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    p = np.asarray(prompt[0])
    reqs = [Request(prompt=p, max_new=16, slo=1),
            Request(prompt=p, max_new=16, slo=0)]  # below protect_slo
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_seq=32,
                                   page_size=4, chunk=4)
    # Two scripted bad rounds: the ladder (no spec -> only 2 rungs) tops
    # out at shed_low_slo while request 0 occupies the single slot.
    report = eng.serve_detailed(
        reqs, chaos=FaultInjector(ChaosConfig(straggle_rounds=(0, 1))),
        policy=ResiliencePolicy(
            ladder=LadderConfig(cooldown=10, protect_slo=1)))
    assert report.records[0].status == "done"
    assert report.records[1].status == "shed"
    assert report.records[1].reason == "ladder"


def test_oom_request_shed_with_policy_raises_without():
    """Requests whose prompts can never fit the pool: policy-less serve
    raises (seed behavior); with a policy they are shed as 'oom' and the
    engine exits cleanly instead of wedging."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    # num_pages=2 -> one circulating page (page 0 is trash); the 8-token
    # prompts need two, so admission can never succeed.
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, num_pages=2, chunk=3)
    reqs = _requests(prompt, max_new=4)
    with pytest.raises(RuntimeError, match="page pool too small"):
        eng.serve(reqs)
    eng2 = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                    page_size=4, num_pages=2, chunk=3)
    report = eng2.serve_detailed(reqs, policy=ResiliencePolicy())
    assert [r.status for r in report.records] == ["shed", "shed"]
    assert all(r.reason == "oom" for r in report.records)
    eng2.assert_quiescent()


def test_arrival_times_respected():
    """A request must not be admitted before its arrival time (virtual
    clock + round_time make this deterministic)."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    clk = VirtualClock()
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3, clock=clk)
    reqs = [Request(prompt=np.asarray(prompt[0]), max_new=8),
            Request(prompt=np.asarray(prompt[1]), max_new=8, arrival=2.5)]
    report = eng.serve_detailed(reqs, policy=ResiliencePolicy(round_time=1.0))
    assert all(r.status == "done" for r in report.records)
    assert report.records[1].t_admit >= 2.5
    # the late arrival changes nothing about the tokens
    base = ContinuousBatchingEngine(
        cfg, params, slots=2, max_seq=24, page_size=4, chunk=3).serve(
        _requests(prompt, max_new=8))
    assert_tokens_identical(base[1], report.records[1].tokens)


def test_serve_report_shape_and_snapshot_roundtrip(tmp_path):
    """Report bookkeeping + snapshot JSON roundtrip (the on-disk recovery
    format must reconstruct the exact inflight state)."""
    cfg, params, prompt, _ = _setup("qwen2-1.5b")
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3)
    report = eng.serve_detailed(_requests(prompt),
                                policy=ResiliencePolicy())
    assert report.rounds > 0
    assert report.slo_attainment() == 1.0  # no deadlines -> all met
    assert report.goodput_tokens() == sum(
        len(r.tokens) for r in report.records)
    snap = eng.last_snapshot  # terminal snapshot: all finished
    assert snap is not None and not snap.inflight and not snap.queued
    from repro.serving import save_snapshot
    path = str(tmp_path / "s.json")
    save_snapshot(path, snap)
    back = load_snapshot(path)
    assert back.finished == snap.finished
    assert back.round == snap.round
