"""Fault-tolerance runtime: heartbeats, stragglers, elastic re-mesh,
supervisor recovery (simulated failures, real control-flow code paths)."""
import pytest

from repro.runtime import (
    HeartbeatMonitor,
    StragglerDetector,
    TrainingSupervisor,
    plan_elastic_remesh,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_dead_host():
    clock = FakeClock()
    mon = HeartbeatMonitor(4, timeout_s=10.0, clock=clock)
    clock.t = 5.0
    for h in (0, 1, 2):
        mon.beat(h)
    clock.t = 12.0
    assert mon.sweep() == [3]
    assert mon.healthy == [0, 1, 2]
    # no double-reporting
    clock.t = 13.0
    assert mon.sweep() == []


def test_straggler_detection():
    det = StragglerDetector(4, window=8, factor=2.0)
    for _ in range(8):
        for h in range(3):
            det.record(h, 1.0)
        det.record(3, 5.0)
    assert det.stragglers() == [3]


def test_elastic_plan_full_strength():
    plan = plan_elastic_remesh(512, model_parallel=16, nominal_data=32)
    assert plan.shape == (2, 16, 16)
    assert plan.batch_scale == 1.0


def test_elastic_plan_degraded():
    plan = plan_elastic_remesh(300, model_parallel=16, nominal_data=32)
    assert plan.shape == (16, 16)  # 16 data rows fit in 300 hosts
    assert plan.batch_scale == 0.5


def test_elastic_plan_below_minimum_raises():
    with pytest.raises(RuntimeError, match="cannot sustain"):
        plan_elastic_remesh(8, model_parallel=16)


def test_supervisor_recovers_from_host_loss():
    """Kill a host mid-run: supervisor must restore the last checkpoint,
    re-plan a smaller mesh, and complete all steps."""
    clock = FakeClock()
    mon = HeartbeatMonitor(512, timeout_s=10.0, clock=clock)
    saved = {"step": None}
    log = []

    def run_step(step, plan):
        clock.t += 1.0
        for h in mon.healthy:
            mon.beat(h)
        if step == 120 and 511 not in mon.dead:
            mon.dead.add(511)  # host 511 dies silently
            raise RuntimeError("device unreachable")
        log.append((step, plan.shape))
        return 1.0

    def save(step):
        saved["step"] = step

    def restore():
        return saved["step"]

    sup = TrainingSupervisor(
        512, run_step, save, restore,
        replan=lambda n: plan_elastic_remesh(n, model_parallel=16, nominal_data=32),
        monitor=mon, ckpt_every=50,
    )
    state = sup.run(total_steps=200)
    assert state.step == 200
    assert state.restarts == 1
    # resumed from step 100 checkpoint
    assert saved["step"] == 200
    steps_run = [s for s, _ in log]
    assert 120 in steps_run  # the failed step was re-run after restore
    # after failure, the mesh shrank from (2,16,16) to (16,16)
    assert state.plans[0].shape == (2, 16, 16)
    assert state.plans[-1].shape == (16, 16)


def test_supervisor_straggler_triggers_replan():
    clock = FakeClock()
    mon = HeartbeatMonitor(512, timeout_s=1e9, clock=clock)
    det = StragglerDetector(512, window=4, factor=2.0)

    def run_step(step, plan):
        clock.t += 1.0
        for h in mon.healthy:
            mon.beat(h)
        return 1.0

    # poison one host's timing stats
    for _ in range(4):
        det.record(7, 100.0)
        for h in range(512):
            if h != 7:
                det.record(h, 1.0)

    sup = TrainingSupervisor(
        512, run_step, save=lambda s: None, restore=lambda: None,
        replan=lambda n: plan_elastic_remesh(n, model_parallel=16, nominal_data=32),
        monitor=mon, detector=det,
    )
    state = sup.run(total_steps=3)
    assert any(f.kind == "straggler" for f in state.failures)
