"""Adaptive speculation: the per-request acceptance-EMA controller that
makes speculation pay (or get out of the way) under load.

Contracts under test (see serving.speculative's module docstring):

* **Greedy token identity at ANY window schedule** — adaptive speculation,
  alone or composed with the degradation ladder and chaos, emits exactly
  the plain greedy engine's tokens: an accepted token is always the
  argmax the baseline would have produced, and narrowing/widening the
  window only changes how many verify steps are paid.
* **Sampled**: run-to-run determinism for a fixed key, and distributional
  equivalence with plain sampled decode (the controller's k is a
  deterministic function of already-emitted data, so rejection sampling
  stays exact by induction over windows).  Cross-engine token identity is
  NOT claimed for sampled adaptive — the fixed engine picks k in-loop
  per iteration while the continuous engine picks per scheduling round,
  so the two consume different draw layouts (the repo's ladder precedent:
  degraded-schedule parity is greedy-only).
* **Controller economics** — the batch-aggregate bucket argmax collapses
  to plain decode (k=0) on hostile/random text, re-grows on repetitive
  text via the k=0 free probe, and resolves ties toward the smaller
  window.
* **n-gram history warm-rebuild** — the proposer's history row equals
  prompt + every emission after every speculative chunk, across ladder
  no_spec rounds, recompute preemption, and crash-replay resume
  (``engine.debug_check_hist`` turns the invariant into a hard assert).
* **Typical acceptance** — the explicitly lossy entropy-band mode:
  deterministic for a fixed key, and degenerating to plain sampled decode
  when the acceptance band is empty (eps=0 rejects every proposal, so
  every token comes from the target's own distribution).

Also home to the moe bit-exactness regression that underpins the parity
matrices above: batched-vs-rowwise moe routing must be BIT-identical even
at stock (dropping) capacity — the two-part fix (per-row dispatch groups +
exact top-k combine) is what promoted the moe archs into
helpers.PAGED_BITEXACT_ARCHS.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import (
    PAGED_BITEXACT_ARCHS,
    assert_distributions_match,
    assert_tokens_identical,
    batch_requests,
    histogram_decode,
    setup_family,
)

from repro.serving import (
    ChaosConfig,
    ContinuousBatchingEngine,
    FaultInjector,
    LadderConfig,
    Request,
    ResiliencePolicy,
    ServingEngine,
    ServingSupervisor,
    SpecConfig,
)
from repro.serving.resilience import InflightState, ServeSnapshot
from repro.serving.speculative import adaptive_k_host, ctrl_buckets

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ADAPTIVE = SpecConfig(k=4, adaptive=True)


# ------------------------------------------------------------- controller --
def test_ctrl_buckets_are_powers_of_two_up_to_k():
    assert ctrl_buckets(1) == (0, 1)
    assert ctrl_buckets(4) == (0, 1, 2, 4)
    assert ctrl_buckets(6) == (0, 1, 2, 4, 6)
    assert ctrl_buckets(8) == (0, 1, 2, 4, 8)


def test_adaptive_k_host_grows_with_acceptance():
    spec = SpecConfig(k=8, adaptive=True)
    live = np.ones(4, bool)
    assert adaptive_k_host(np.full(4, 0.99, np.float32), live, spec) == 8
    assert adaptive_k_host(np.full(4, 0.0, np.float32), live, spec) == 0
    lo = adaptive_k_host(np.full(4, 0.3, np.float32), live, spec)
    hi = adaptive_k_host(np.full(4, 0.9, np.float32), live, spec)
    assert 0 < lo < hi <= 8


def test_adaptive_k_host_ignores_dead_slots_and_empty_batch():
    spec = SpecConfig(k=4, adaptive=True)
    ema = np.asarray([0.99, 0.0], np.float32)
    assert adaptive_k_host(ema, np.asarray([True, False]), spec) == 4
    assert adaptive_k_host(ema, np.asarray([False, True]), spec) == 0
    assert adaptive_k_host(ema, np.zeros(2, bool), spec) == 0


def test_adaptive_k_host_tie_prefers_smaller_window():
    # e=0 makes every bucket's expected emissions 1.0; only the cost
    # denominator differs, so the argmax must land on the narrowest
    # window even under float tie noise.
    spec = SpecConfig(k=4, adaptive=True)
    assert adaptive_k_host(np.zeros(3, np.float32), np.ones(3, bool),
                           spec) == 0


def test_spec_config_validation_new_modes():
    with pytest.raises(ValueError, match="tree"):
        SpecConfig(k=2, tree_fan=2, mode="draft")
    with pytest.raises(ValueError, match="exclusive"):
        SpecConfig(k=2, tree_fan=2, adaptive=True)
    with pytest.raises(ValueError, match="linear-only"):
        SpecConfig(k=2, tree_fan=2, accept="typical")
    with pytest.raises(ValueError, match="accept"):
        SpecConfig(k=2, accept="nearly")
    with pytest.raises(ValueError, match="ctrl_alpha"):
        SpecConfig(k=2, adaptive=True, ctrl_alpha=0.0)
    with pytest.raises(ValueError, match="ctrl_cost"):
        SpecConfig(k=2, adaptive=True, ctrl_cost=-1.0)
    with pytest.raises(ValueError, match="tree_fan"):
        SpecConfig(k=2, tree_fan=-1)


# ------------------------------------------------- greedy parity matrices --
# The matrices run at the same horizon as test_speculative's (n_new=5,
# max_seq=16).  Speculative greedy parity for the MOE archs is
# horizon-limited for ANY window mode, fixed or adaptive: a token that
# shares a verify window with row-mates can be capacity-dropped where the
# same token decoded alone never is, so once a drop fires inside a window
# the spec trace forks from plain decode (measured: moonshot forks at
# token 8 under k=4, fixed and adaptive alike).  Dense-vs-paged and
# cross-engine parity — the contracts PAGED_BITEXACT_ARCHS names — are
# unaffected: both sides run the same windows.
@pytest.mark.parametrize("arch", PAGED_BITEXACT_ARCHS)
def test_adaptive_fixed_engine_greedy_parity(arch):
    """Fixed engine, every family: adaptive greedy == plain greedy,
    token-for-token (the in-loop controller only caps acceptance)."""
    cfg, params, prompt, extras = setup_family(arch)
    eng = ServingEngine(cfg, params, max_seq=16)
    want = np.asarray(eng.generate(prompt, n_new=5, extras=extras))
    got = np.asarray(eng.generate(prompt, n_new=5, extras=extras,
                                  speculate=ADAPTIVE))
    assert_tokens_identical(want, got, msg=arch)
    assert eng.spec_stats["adaptive"] is True


@pytest.mark.parametrize("arch", PAGED_BITEXACT_ARCHS)
def test_adaptive_continuous_engine_greedy_parity(arch):
    """Continuous engine, every family: the host controller re-picks the
    round's window width from the returned EMAs (down to plain decode)
    and tokens still match the non-speculative scheduler exactly."""
    cfg, params, prompt, extras = setup_family(arch)
    kw = dict(slots=2, max_seq=16, page_size=4, chunk=3)
    reqs = batch_requests(prompt, 5, extras)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    eng = ContinuousBatchingEngine(cfg, params, speculate=ADAPTIVE, **kw)
    eng.debug_check_hist = True
    got = eng.serve(reqs)
    for i, (w, g) in enumerate(zip(want, got)):
        assert_tokens_identical(w, g, msg=f"{arch} req {i}")


def test_adaptive_long_horizon_greedy_parity_dense():
    """Longer horizon (24 tokens) on the dense family, where no moe
    window-drop caveat applies: adaptive == plain, both engines."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=40)
    want = np.asarray(eng.generate(prompt, n_new=24, extras=extras))
    got = np.asarray(eng.generate(prompt, n_new=24, extras=extras,
                                  speculate=ADAPTIVE))
    assert_tokens_identical(want, got, msg="fixed long horizon")
    kw = dict(slots=2, max_seq=40, page_size=4, chunk=3)
    reqs = batch_requests(prompt, 24, extras)
    cw = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    ce = ContinuousBatchingEngine(cfg, params, speculate=ADAPTIVE, **kw)
    ce.debug_check_hist = True
    cg = ce.serve(reqs)
    for i, (w, g) in enumerate(zip(cw, cg)):
        assert_tokens_identical(w, g, msg=f"continuous long req {i}")


def test_adaptive_collapses_to_plain_decode_on_hostile_text():
    """When the proposer can't win — temperature-1.0 sampling over the
    full vocab churns the continuation too fast for n-gram lookup — the
    controller must spend the trace at k=0, which is visible as exactly
    one emission per live verify window (k=0 windows ARE plain decode
    steps, priced as such by serving_bench).  Greedy is deliberately NOT
    used here: the tiny model's greedy continuation degenerates into
    repetition, which the proposer legitimately wins."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    spec = SpecConfig(k=4, adaptive=True, ctrl_init=0.0)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=40,
                                   page_size=4, chunk=4, speculate=spec)
    eng.serve(batch_requests(prompt, 24, extras), greedy=False,
              temperature=1.0, top_k=0, key=jax.random.PRNGKey(5))
    assert eng.spec_emitted == eng.spec_live_steps


def test_adaptive_regrows_on_repetitive_text():
    """A strongly periodic prompt makes the n-gram proposer near-perfect:
    the k=0 probe must pull the EMA up and the controller back to wide
    windows — measurable as >1 emitted token per live window."""
    cfg, params, _, _ = setup_family("qwen2-1.5b")
    p = np.asarray([5, 9, 5, 9, 5, 9, 5, 9], np.int32)
    spec = SpecConfig(k=4, adaptive=True, ctrl_init=0.0)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=48,
                                   page_size=4, chunk=4, speculate=spec)
    eng.serve([Request(prompt=p, max_new=32), Request(prompt=p, max_new=32)])
    assert eng.spec_emitted / eng.spec_live_steps > 1.2


# ------------------------------------------------------- sampled contracts --
def _sampled_serve(spec, key, *, greedy=False, arch="qwen2-1.5b", n_new=12):
    cfg, params, prompt, extras = setup_family(arch)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3, speculate=spec)
    outs = eng.serve(batch_requests(prompt, n_new, extras), greedy=greedy,
                     temperature=0.8, top_k=8, key=key)
    return [np.asarray(o) for o in outs]


def test_adaptive_sampled_deterministic_and_key_sensitive():
    k1, k2 = jax.random.PRNGKey(11), jax.random.PRNGKey(12)
    a = _sampled_serve(ADAPTIVE, k1)
    b = _sampled_serve(ADAPTIVE, k1)
    c = _sampled_serve(ADAPTIVE, k2)
    for i, (x, y) in enumerate(zip(a, b)):
        assert_tokens_identical(x, y, msg=f"req {i} not deterministic")
    assert any(not np.array_equal(x, y) for x, y in zip(a, c)), \
        "different keys produced identical traces"


def test_adaptive_fixed_engine_sampled_deterministic():
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=24)
    kw = dict(extras=extras, greedy=False, temperature=0.8, top_k=8,
              speculate=ADAPTIVE, key=jax.random.PRNGKey(11))
    a = np.asarray(eng.generate(prompt, n_new=12, **kw))
    b = np.asarray(eng.generate(prompt, n_new=12, **kw))
    assert_tokens_identical(a, b, msg="fixed adaptive sampled")


def test_adaptive_sampled_distribution_matches_plain():
    """Distributional equivalence: the controller's k schedule is a
    deterministic function of already-emitted data, so adaptive sampled
    speculation leaves plain sampled decode's output law unchanged —
    chi-square over seeded decodes at the last emitted position."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b", b=1, s=6)
    batch = 250
    prompt = jnp.tile(prompt, (batch, 1))
    eng = ServingEngine(cfg, params, max_seq=16)

    def gen(spec):
        def f(key):
            return eng.generate(prompt, n_new=3, extras=extras, greedy=False,
                                temperature=1.0, top_k=0, key=key,
                                speculate=spec)
        return f

    plain = histogram_decode(gen(None), cfg.vocab, 750, base_seed=100)
    adapt = histogram_decode(gen(ADAPTIVE), cfg.vocab, 750, base_seed=900)
    assert_distributions_match(plain, adapt, msg="adaptive vs plain sampled")


# ------------------------------------------------ typical acceptance mode --
def test_typical_mode_deterministic_and_in_vocab():
    spec = SpecConfig(k=4, accept="typical")
    key = jax.random.PRNGKey(11)
    a = _sampled_serve(spec, key)
    b = _sampled_serve(spec, key)
    cfg, _, _, _ = setup_family("qwen2-1.5b")
    for i, (x, y) in enumerate(zip(a, b)):
        assert_tokens_identical(x, y, msg=f"typical req {i}")
        assert (x >= 0).all() and (x < cfg.vocab).all()


def test_typical_accepts_more_than_exact_on_hostile_text():
    """The lossy trade, measured: exact verification accepts a proposal
    with probability p(d) — near 1/V on temperature-1.0 text — while the
    typical band accepts DETERMINISTICALLY once p(d) clears
    ``min(eps, delta*exp(-H))``, which a near-uniform target sets well
    below 1/V.  So on hostile text typical must emit strictly more
    tokens per verify window than exact; that surplus IS the bias the
    mode trades for throughput (there is no parameter that recovers
    exactness — eps=0 still accepts any nonzero-mass draft)."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")

    def run(accept):
        eng = ContinuousBatchingEngine(
            cfg, params, slots=2, max_seq=40, page_size=4, chunk=4,
            speculate=SpecConfig(k=4, accept=accept))
        eng.serve(batch_requests(prompt, 24, extras), greedy=False,
                  temperature=1.0, top_k=0, key=jax.random.PRNGKey(5))
        return eng.spec_emitted / eng.spec_live_steps

    assert run("typical") > run("exact")


def test_typical_adaptive_compose():
    """adaptive=True with accept='typical' is legal (the controller
    schedules, typical accepts) and stays deterministic."""
    spec = SpecConfig(k=4, adaptive=True, accept="typical")
    key = jax.random.PRNGKey(13)
    a = _sampled_serve(spec, key)
    b = _sampled_serve(spec, key)
    for x, y in zip(a, b):
        assert_tokens_identical(x, y)


# ------------------------------------- ladder / chaos / replay composition --
def test_adaptive_with_ladder_and_chaos_greedy_parity():
    """The full composition: adaptive controller x degradation ladder x
    chaos (stragglers + page squeezes) — greedy tokens must match the
    undisturbed non-speculative run for every request that finishes, and
    the n-gram history invariant holds after every chunk."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    reqs = batch_requests(prompt, 16, extras)
    kw = dict(slots=2, max_seq=32, page_size=4, chunk=2)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    eng = ContinuousBatchingEngine(cfg, params, speculate=ADAPTIVE, **kw)
    eng.debug_check_hist = True
    report = eng.serve_detailed(
        reqs,
        chaos=FaultInjector(ChaosConfig(straggle_rounds=(0, 1),
                                        squeeze_rounds=(3,),
                                        squeeze_frac=0.5)),
        policy=ResiliencePolicy(ladder=LadderConfig(cooldown=2)))
    assert report.max_ladder_level >= 1  # the ladder actually engaged
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")
    eng.assert_quiescent()


def test_adaptive_crash_replay_greedy_token_identical():
    """Crash replay with the controller on: the snapshot carries each
    in-flight request's acc_ema, the resumed engine keeps scheduling from
    the learned rate, and greedy tokens replay exactly."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    reqs = batch_requests(prompt, 12, extras)
    kw = dict(slots=2, max_seq=24, page_size=4, chunk=3, speculate=ADAPTIVE)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    eng = ContinuousBatchingEngine(cfg, params, **kw)
    eng.debug_check_hist = True
    sup = ServingSupervisor(
        eng, policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(1,))))
    report = sup.run(reqs)
    assert report.restarts == 1
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")


def test_hist_warm_rebuild_under_preemption_and_ladder():
    """The n-gram history audit: a page pool tight enough to force
    recompute preemption, plus scripted bad rounds driving the ladder
    through halve_k/no_spec and back — after every speculative chunk each
    live slot's history row must equal prompt + emissions exactly
    (debug_check_hist raises otherwise), and the output still matches the
    undisturbed plain run."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    reqs = batch_requests(prompt, 16, extras)
    base_kw = dict(slots=2, max_seq=32, page_size=4, chunk=2)
    want = ContinuousBatchingEngine(cfg, params, **base_kw).serve(reqs)
    # num_pages below the 2-slot worst case => top-ups preempt the
    # youngest slot mid-stream; the preempted request re-admits fresh
    # with its history rebuilt whole.
    eng = ContinuousBatchingEngine(cfg, params, speculate=ADAPTIVE,
                                   num_pages=13, **base_kw)
    eng.debug_check_hist = True
    report = eng.serve_detailed(
        reqs,
        chaos=FaultInjector(ChaosConfig(straggle_rounds=(0, 1, 2))),
        policy=ResiliencePolicy(ladder=LadderConfig(cooldown=1)))
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")
    eng.assert_quiescent()


def test_inflight_snapshot_roundtrips_acc_ema(tmp_path):
    """acc_ema rides the JSON snapshot, and snapshots written before the
    field existed still load (default)."""
    snap = ServeSnapshot(
        finished={0: [1, 2]},
        inflight={1: InflightState(emitted=[3], wctr=2, acc_ema=0.875)},
        queued=[2], closed={}, round=5)
    import json

    j = snap.to_json()
    back = ServeSnapshot.from_json(j)
    assert back.inflight[1].acc_ema == 0.875
    legacy = json.loads(j)
    legacy["inflight"] = {"1": {"emitted": [3], "wctr": 2,
                                "t_admit": None, "t_first": None}}
    assert (ServeSnapshot.from_json(json.dumps(legacy))
            .inflight[1].acc_ema == 0.5)


# ------------------------------------------------- 8-device mesh identity --
ADAPTIVE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax
sys.path.insert(0, os.path.join(r"{repo}", "tests"))
from helpers import setup_family, build_engine, generate_tokens, batch_requests
from repro.serving import SpecConfig, make_decode_mesh

ARCHS = sys.argv[1].split(",")
mesh = make_decode_mesh(8)
spec = SpecConfig(k=4, adaptive=True)
out = []
for arch in ARCHS:
    cfg, params, prompt, extras = setup_family(arch)
    row = {{"arch": arch}}
    plain = build_engine("fixed", cfg, params, max_seq=16, bits=8)
    shard = build_engine("fixed", cfg, params, max_seq=16, bits=8, mesh=mesh)
    want = generate_tokens(plain, prompt, 5, extras)
    got = generate_tokens(shard, prompt, 5, extras, speculate=spec)
    row["fixed_identical"] = bool(np.array_equal(want, got))
    pl = build_engine("continuous", cfg, params, max_seq=16, bits=8,
                      page_alloc_seed=7)
    sh = build_engine("continuous", cfg, params, max_seq=16, bits=8,
                      page_alloc_seed=7, mesh=mesh, speculate=spec)
    a = pl.serve(batch_requests(prompt, 5, extras))
    b = sh.serve(batch_requests(prompt, 5, extras))
    row["paged_identical"] = bool(all(np.array_equal(x, y)
                                      for x, y in zip(a, b)))
    out.append(row)
print("RESULT " + json.dumps(out))
""".format(repo=REPO)


def test_adaptive_sharded_greedy_identity_all_families():
    """Acceptance: adaptive speculation on a forced 8-virtual-device mesh
    == plain single-device greedy, both engines, all families (the
    controller state is replicated, so every device schedules the same
    window widths)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", ADAPTIVE_SNIPPET,
         ",".join(PAGED_BITEXACT_ARCHS)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    import json
    for row in json.loads(line[len("RESULT "):]):
        assert row["fixed_identical"], row
        assert row["paged_identical"], row


# --------------------------------------------------- moe gate bit-exactness --
def test_moe_batched_vs_rowwise_bitexact_at_stock_capacity():
    """The satellite fix behind the parity matrices: moe routing is a
    per-row function (dispatch groups never span rows) and the top-k
    combine reduces over the fixed k axis, so a batch of 4 rows and the
    same rows run one-at-a-time produce BIT-identical outputs even at
    stock (dropping) capacity.  Guards both halves of the fix that
    promoted the moe archs into PAGED_BITEXACT_ARCHS."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as M

    d = 32
    cfg = MoEConfig(n_experts=8, n_shared=1, top_k=3, d_ff_expert=64,
                    capacity_factor=1.25, group_tokens=4096)
    kp, kx = jax.random.split(jax.random.PRNGKey(0))
    p = M.moe_init(kp, d, cfg, jnp.float32)
    x = jax.random.normal(kx, (4, 16, d), jnp.float32)
    batched = M.moe_apply(p, x, cfg)[0]
    rows = jnp.concatenate(
        [M.moe_apply(p, x[i : i + 1], cfg)[0] for i in range(4)], 0)
    assert bool(jnp.all(batched == rows)), (
        f"max|diff|={float(jnp.max(jnp.abs(batched - rows))):.3e}")
