"""int8 KV cache (kv_cache_bits=8): correctness + storage accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import decode_step, forward, init_cache, init_params
from repro.serving.engine import pim_bytes


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "llama3.2-3b", "zamba2-1.2b"])
def test_int8_cache_decode_matches_forward(arch):
    cfg = get_reduced(arch).replace(kv_cache_bits=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 2, 8)
    outs = []
    for pos in range(8):
        lg, cache = decode_step(params, cfg, toks[:, pos : pos + 1], cache,
                                jnp.int32(pos))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    agree = (np.asarray(dec).argmax(-1) == np.asarray(full).argmax(-1)).mean()
    assert agree > 0.95, agree
    rel = float(jnp.linalg.norm(dec - full) / jnp.linalg.norm(full))
    assert rel < 0.05, rel


def test_int8_cache_halves_storage():
    cfg = get_reduced("llama3.2-3b")
    c16 = init_cache(cfg, 4, 128)
    c8 = init_cache(cfg.replace(kv_cache_bits=8), 4, 128)
    # int8 codes + f32/(D=16) scales vs f32 (reduced configs are f32):
    # expect >= 3x smaller; on bf16 production dtype it is ~1.9x.
    assert pim_bytes(c16) / pim_bytes(c8) > 3.0


def test_int8_cache_structure():
    cfg = get_reduced("qwen2-1.5b").replace(kv_cache_bits=8)
    cache = init_cache(cfg, 2, 16)
    layer = cache["layers"]
    assert layer["k"].dtype == jnp.int8
    assert layer["k_scale"].dtype == jnp.float32
    assert layer["k"].shape[-2] == 16  # (L, B, KV, S, D) head-major
