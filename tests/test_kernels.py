"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
sweeping shapes and dtypes as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bitplane import bitplane_matmul
from repro.kernels.fold_reduce import fold_reduce
from repro.kernels.pim_matmul import pim_matmul
from repro.quant import (
    dequantize,
    from_bitplanes,
    pack_int4,
    quantize_symmetric,
    to_bitplanes,
    unpack_int4,
)

INTERP = dict(interpret=True)


def _mk(m, k, n, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), dtype=dtype)
    w = jax.random.normal(kw, (k, n), dtype=jnp.float32)
    return x, w


# ------------------------------------------------------------------- quant --
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_roundtrip_error_bounded(bits):
    _, w = _mk(1, 64, 32)
    q = quantize_symmetric(w, bits=bits, axis=0)
    err = jnp.abs(dequantize(q) - w)
    step = q.scale  # max quantization step per column
    assert float(jnp.max(err / (step / 2 + 1e-9))) <= 1.001


def test_int4_pack_roundtrip():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-8, 8, size=(64, 16)), dtype=jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(codes))), codes)


@pytest.mark.parametrize("bits", [4, 8])
def test_bitplane_roundtrip(bits):
    rng = np.random.default_rng(1)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    codes = jnp.asarray(rng.integers(lo, hi, size=(32, 8)), dtype=jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(from_bitplanes(to_bitplanes(codes, bits))), codes
    )


# -------------------------------------------------------------- pim_matmul --
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (8, 32, 16, 8, 16, 16),
        (16, 128, 64, 8, 32, 32),
        (32, 256, 128, 16, 128, 64),
        (128, 512, 256, 128, 128, 512),  # full MXU-aligned tiles
        (4, 64, 8, 4, 8, 64),  # single-tile K
    ],
)
def test_pim_matmul_int8_matches_ref(m, k, n, bm, bn, bk):
    x, w = _mk(m, k, n, seed=m + k + n)
    q = quantize_symmetric(w, bits=8, axis=0)
    got = pim_matmul(x, q.codes, q.scale, bits=8, bm=bm, bn=bn, bk=bk, **INTERP)
    want = ref.pim_matmul_int8_ref(x, q.codes, q.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "m,k,n,bk", [(8, 64, 16, 32), (16, 128, 32, 64), (32, 256, 64, 256)]
)
def test_pim_matmul_int4_matches_ref(m, k, n, bk):
    x, w = _mk(m, k, n, seed=7)
    q = quantize_symmetric(w, bits=4, axis=0)
    packed = pack_int4(q.codes)
    got = pim_matmul(x, packed, q.scale, bits=4, bm=8, bn=16, bk=bk, **INTERP)
    want = ref.pim_matmul_int4_ref(x, packed, q.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pim_matmul_dtypes(dtype):
    x, w = _mk(16, 64, 32, seed=3, dtype=dtype)
    q = quantize_symmetric(w, bits=8, axis=0)
    got = pim_matmul(x, q.codes, q.scale, bits=8, bm=16, bn=32, bk=32, **INTERP)
    want = ref.pim_matmul_int8_ref(x, q.codes, q.scale)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10
    )


def test_pim_matmul_int8_end_to_end_accuracy():
    """Dequant-fused output must track the f32 matmul within quant error."""
    x, w = _mk(32, 512, 64, seed=11)
    q = quantize_symmetric(w, bits=8, axis=0)
    got = pim_matmul(x, q.codes, q.scale, bits=8, bm=32, bn=64, bk=128, **INTERP)
    exact = x @ w
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert rel < 1.5e-2, rel  # int8 per-channel quant error at K=512


# ---------------------------------------------------------- bitplane matmul -
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("m,k,n,bk", [(8, 32, 16, 16), (16, 128, 32, 64)])
def test_bitplane_matmul_matches_ref(bits, m, k, n, bk):
    x, w = _mk(m, k, n, seed=bits * 100 + m)
    q = quantize_symmetric(w, bits=bits, axis=0)
    planes = to_bitplanes(q.codes, bits)
    got = bitplane_matmul(x, planes, q.scale, bm=8, bn=16, bk=bk, **INTERP)
    want = ref.bitplane_matmul_ref(x, planes, q.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_bitplane_equals_packed_path():
    """The PIM-semantic plane kernel and the packed kernel agree exactly."""
    x, w = _mk(16, 64, 32, seed=21)
    q = quantize_symmetric(w, bits=8, axis=0)
    planes = to_bitplanes(q.codes, 8)
    a = bitplane_matmul(x, planes, q.scale, bm=16, bn=32, bk=32, **INTERP)
    b = pim_matmul(x, q.codes, q.scale, bits=8, bm=16, bn=32, bk=32, **INTERP)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------- fold_reduce --
@pytest.mark.parametrize("rows,q,br", [(8, 16, 8), (64, 128, 32), (256, 64, 256)])
def test_fold_reduce_matches_ref(rows, q, br):
    x = jax.random.normal(jax.random.PRNGKey(rows + q), (rows, q))
    got = fold_reduce(x, br=br, **INTERP)
    want = ref.fold_reduce_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.sum(x, axis=-1)), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 5).map(lambda e: 2**e),
    st.integers(0, 1000),
)
def test_fold_reduce_property(qexp, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, qexp * 2))
    got = fold_reduce(x, br=4, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.sum(x, axis=-1)), rtol=1e-4, atol=1e-4
    )


def test_fold_reduce_rejects_non_pow2():
    with pytest.raises(AssertionError):
        fold_reduce(jnp.ones((4, 12)), interpret=True)
