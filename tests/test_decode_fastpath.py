"""Decode fast path: scan-compiled generation parity vs the seed per-token
loop, single-pass prefill vs forward/per-token caches, and the
epilogue-fused pim_matvec kernel vs its pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels import ref
from repro.kernels.pim_matmul import pim_matmul
from repro.kernels.pim_matvec import pim_matvec
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.models.common import dq, linear, set_matvec_dispatch, weight_shape
from repro.quant import pack_int4, quantize_symmetric
from repro.serving import ServingEngine, quantize_tree


def _token_loop_cache(params, cfg, tokens, cache):
    """Per-token prefill oracle: feed the prompt one token at a time through
    decode_step (the seed-era reference path, now inlined here — the engine
    keeps a single oracle, ``ServingEngine.generate_reference``)."""
    for i in range(tokens.shape[1]):
        _, cache = decode_step(params, cfg, tokens[:, i : i + 1], cache,
                               jnp.int32(i))
    return cache


def _mk(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(kx, (m, k)), jax.random.normal(kw, (k, n))


# --------------------------------------------------- scan-compiled generate -
@pytest.mark.parametrize("pim_bits", [0, 8, 4])
def test_generate_matches_seed_loop(pim_bits):
    """Greedy, batch > 1: the one-XLA-program generate must emit exactly the
    seed per-token loop's tokens (same argmax path, same cache layout)."""
    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=pim_bits)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab)
    fast = eng.generate(prompt, n_new=6)
    seed = eng.generate_reference(prompt, n_new=6)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(seed))


def test_generate_matches_seed_loop_ssm():
    """SSM family: chunked single-pass prefill state == per-token state."""
    cfg = get_reduced("falcon-mamba-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=8)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    np.testing.assert_array_equal(
        np.asarray(eng.generate(prompt, n_new=5)),
        np.asarray(eng.generate_reference(prompt, n_new=5)),
    )


def test_generate_prime_prompt_ssm():
    """Prime prompt length exercises the SSM prefill's masked pad-to-chunk
    path (chunk no longer degrades to 1 for indivisible lengths)."""
    cfg = get_reduced("falcon-mamba-7b")  # reduced chunk=16
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=0)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    np.testing.assert_array_equal(
        np.asarray(eng.generate(prompt, n_new=4)),
        np.asarray(eng.generate_reference(prompt, n_new=4)),
    )


def test_generate_sampling_modes():
    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=8)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    a = eng.generate(prompt, n_new=6, greedy=False, temperature=0.7, top_k=8,
                     key=jax.random.PRNGKey(5))
    b = eng.generate(prompt, n_new=6, greedy=False, temperature=0.7, top_k=8,
                     key=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # same key
    assert a.shape == (2, 6) and int(a.max()) < cfg.vocab
    c = eng.generate(prompt, n_new=6, greedy=False, temperature=1.3,
                     key=jax.random.PRNGKey(6))
    assert c.shape == (2, 6)


# --------------------------------------------------------- single-pass prefill
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "zamba2-1.2b"])
def test_prefill_matches_forward_and_token_loop(arch):
    """prefill() logits == forward() logits exactly, and the filled cache
    decodes the same next token as the per-token reference prefill."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits, cache = prefill(params, cfg, tokens, init_cache(cfg, b, s + 4))
    fwd, _ = forward(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(fwd),
                               rtol=1e-5, atol=1e-5)
    ref_cache = _token_loop_cache(params, cfg, tokens, init_cache(cfg, b, s + 4))
    nt = jnp.zeros((b, 1), jnp.int32)
    l1, _ = decode_step(params, cfg, nt, cache, jnp.int32(s))
    l2, _ = decode_step(params, cfg, nt, ref_cache, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-3, atol=1e-2)


def test_prefill_int8_kv_cache():
    """Quantized KV cache: prefill writes the same int8 codes the per-token
    path would (prompt attends against quantize->dequantize K/V)."""
    cfg = get_reduced("qwen2-1.5b").replace(kv_cache_bits=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    _, cache = prefill(params, cfg, tokens, init_cache(cfg, b, s + 4))
    ref_cache = _token_loop_cache(params, cfg, tokens, init_cache(cfg, b, s + 4))
    got = np.asarray(cache["layers"]["k"], np.int32)
    want = np.asarray(ref_cache["layers"]["k"], np.int32)
    # int8 codes of identical values; allow off-by-one rounding at the edge
    assert np.abs(got - want).max() <= 1


# --------------------------------------------------------------- pim_matvec -
@pytest.mark.parametrize(
    "m,k,n,bn,bk",
    [
        (1, 64, 32, 16, 16),
        (4, 128, 64, 64, 64),
        (8, 256, 128, 128, 512),
        (2, 96, 100, 32, 64),  # N not a multiple of bn -> pad-to-tile
        (3, 50, 30, 16, 16),   # M, K, N all non-multiples
    ],
)
def test_pim_matvec_int8_matches_ref(m, k, n, bn, bk):
    x, w = _mk(m, k, n, seed=m + k + n)
    q = quantize_symmetric(w, bits=8, axis=0)
    got = pim_matvec(x, q.codes, q.scale, bits=8, bn=bn, bk=bk, interpret=True)
    want = ref.pim_matvec_ref(x, q.codes, q.scale, bits=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "m,k,n,bk", [(1, 64, 16, 32), (4, 128, 32, 64), (2, 100, 48, 64)]
)
def test_pim_matvec_int4_matches_ref(m, k, n, bk):
    x, w = _mk(m, k, n, seed=7)
    q = quantize_symmetric(w, bits=4, axis=0)
    packed = pack_int4(q.codes)
    got = pim_matvec(x, packed, q.scale, bits=4, bn=16, bk=bk, interpret=True)
    want = ref.pim_matvec_ref(x, packed, q.scale, bits=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("activation", ["none", "relu", "silu", "gelu"])
def test_pim_matvec_fused_epilogue(activation):
    """scale x bias + activation + residual fused in the flush step."""
    m, k, n = 4, 64, 48
    x, w = _mk(m, k, n, seed=3)
    q = quantize_symmetric(w, bits=8, axis=0)
    bias = jax.random.normal(jax.random.PRNGKey(9), (n,))
    res = jax.random.normal(jax.random.PRNGKey(10), (m, n))
    got = pim_matvec(x, q.codes, q.scale, bits=8, bias=bias,
                     activation=activation, residual=res, bn=16, bk=32,
                     interpret=True)
    want = ref.pim_matvec_ref(x, q.codes, q.scale, bits=8, bias=bias,
                              activation=activation, residual=res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_pim_matvec_rejects_large_m():
    x, w = _mk(16, 32, 16)
    q = quantize_symmetric(w, bits=8, axis=0)
    with pytest.raises(ValueError, match="decode-shaped"):
        pim_matvec(x, q.codes, q.scale, bits=8, interpret=True)


def test_pim_matmul_pad_to_tile_and_epilogue():
    """Shapes that are not block multiples no longer assert; epilogue fused."""
    m, k, n = 12, 100, 70
    x, w = _mk(m, k, n, seed=5)
    q = quantize_symmetric(w, bits=8, axis=0)
    bias = jax.random.normal(jax.random.PRNGKey(9), (n,))
    got = pim_matmul(x, q.codes, q.scale, bits=8, bm=8, bn=32, bk=64,
                     bias=bias, activation="relu", interpret=True)
    want = ref.pim_matvec_ref(x, q.codes, q.scale, bits=8, bias=bias,
                              activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------------------- linear kernel dispatch -
@pytest.mark.parametrize("bits,kdim", [(8, 64), (4, 64), (4, 33)])
def test_linear_dispatches_to_matvec(bits, kdim):
    """force mode: decode-shaped quantized linear routes through pim_matvec
    (interpret) and agrees with the XLA overlay path — including the odd-K
    int4 'nibbles_odd' packing."""
    w = {"w": jax.random.normal(jax.random.PRNGKey(2), (kdim, 24))}
    q = quantize_tree(w, bits=bits)["w"]
    assert isinstance(q, dict)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 1, kdim))
    b = jax.random.normal(jax.random.PRNGKey(6), (24,))
    prev = set_matvec_dispatch("force")
    try:
        y_kernel = linear(x, q, b)
        set_matvec_dispatch("off")
        y_overlay = linear(x, q, b)
    finally:
        set_matvec_dispatch(prev)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_overlay),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------- int4 odd-K quantize --
def test_quantize_tree_int4_odd_k_packs():
    """Odd K no longer silently ships INT8: one zero code row is padded and
    flagged via the 'nibbles_odd' marker; dq() drops it on unpack."""
    w = {"w": jax.random.normal(jax.random.PRNGKey(0), (33, 16))}
    q = quantize_tree(w, bits=4)["w"]
    assert "nibbles_odd" in q and "nibbles" not in q
    assert q["codes"].shape == (17, 16)  # (33+1)/2 packed rows
    assert weight_shape(q) == (33, 16)
    dense = dq(q)
    assert dense.shape == (33, 16)
    # quantization error bounded by half a step, as for even K
    err = jnp.abs(dense - w["w"])
    assert float(jnp.max(err / (q["scale"] / 2 + 1e-9))) <= 1.001


def test_quantize_tree_int4_even_k_unchanged():
    w = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 16))}
    q = quantize_tree(w, bits=4)["w"]
    assert "nibbles" in q and "nibbles_odd" not in q
    assert q["codes"].shape == (16, 16)
    assert weight_shape(q) == (32, 16)


def test_pack_int4_rejects_odd_k():
    from repro.quant import pack_int4 as pk
    with pytest.raises(ValueError, match="even K"):
        pk(jnp.zeros((33, 8), jnp.int8))
