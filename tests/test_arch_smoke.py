"""Per-architecture smoke tests: REDUCED configs, one forward + one decode
step on CPU, asserting output shapes and absence of NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import decode_step, encode, forward, init_cache, init_params, loss_fn

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    kt, kf, ki = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(kt, (BATCH, SEQ), 0, cfg.vocab)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(kf, (BATCH, cfg.audio.n_frames, cfg.d_model))
        b["dec_tokens"] = b.pop("tokens")
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            ki, (BATCH, cfg.vision.n_image_tokens, cfg.d_model)
        )
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_loss(arch_id):
    cfg = get_reduced(arch_id)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg = get_reduced(arch_id)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    cache = init_cache(cfg, BATCH, max_seq=SEQ)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (BATCH, cfg.vision.n_image_tokens, cfg.d_model)
        )
    if cfg.family == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(3), (BATCH, cfg.audio.n_frames, cfg.d_model)
        )
        extras["enc_out"] = encode(params, cfg, frames)

    tok = jnp.zeros((BATCH, 1), jnp.int32)
    step = jax.jit(
        lambda p, t, c, pos: decode_step(p, cfg, t, c, pos, extras)
    )
    for pos in range(3):
        logits, cache = step(params, tok, cache, jnp.int32(pos))
        assert logits.shape == (BATCH, 1, cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits)))
        tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch_id", ["qwen2-1.5b", "falcon-mamba-7b", "zamba2-1.2b"])
def test_train_step_reduces_loss(arch_id):
    """A few SGD steps on a fixed batch must reduce the loss (learnability)."""
    cfg = get_reduced(arch_id)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(lambda q: loss_fn(q, cfg, batch), has_aux=True)(p)
        return l, jax.tree.map(lambda a, b: a - 0.05 * b.astype(a.dtype), p, g)

    l0, params = step(params)
    for _ in range(5):
        l1, params = step(params)
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_decode_matches_forward_dense():
    """Greedy decode logits must match the teacher-forced forward (dense)."""
    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, {"tokens": toks})

    cache = init_cache(cfg, 1, max_seq=8)
    outs = []
    for pos in range(8):
        lg, cache = decode_step(params, cfg, toks[:, pos : pos + 1], cache,
                                jnp.int32(pos))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_decode_matches_forward_ssm():
    """Token-by-token SSM recurrence == chunked full-sequence scan."""
    cfg = get_reduced("falcon-mamba-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 1, max_seq=16)
    outs = []
    for pos in range(16):
        lg, cache = decode_step(params, cfg, toks[:, pos : pos + 1], cache,
                                jnp.int32(pos))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_decode_matches_forward_mamba2():
    cfg = get_reduced("zamba2-1.2b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 1, max_seq=16)
    outs = []
    for pos in range(16):
        lg, cache = decode_step(params, cfg, toks[:, pos : pos + 1], cache,
                                jnp.int32(pos))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )
