"""Speculative multi-token decode (serving.speculative).

The contract is exactness: greedy verification accepts only proposals that
match the target's own argmax, so speculative decode must be TOKEN-IDENTICAL
to plain greedy decode — independent of proposer quality, draft model,
acceptance rate, engine, bit width, or device mesh.  The matrix here runs
family x engine x bits through the shared parity harness (tests/helpers.py);
the 8-virtual-device legs reuse the subprocess idiom of
test_sharded_decode.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import (
    FAMILY_ARCHS,
    assert_serve_matches_solo,
    assert_tokens_identical,
    batch_requests,
    build_engine,
    generate_tokens,
    setup_family,
)

from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    SpecConfig,
    propose_ngram,
)
from repro.serving.speculative import greedy_accept

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ parity matrix -
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_spec_fixed_engine_parity_all_families(arch):
    """ServingEngine.generate(speculate=) == plain greedy generate, every
    family — acceptance criterion's single-device fixed-engine leg."""
    cfg, params, prompt, extras = setup_family(arch)
    eng = build_engine("fixed", cfg, params, max_seq=16, bits=8)
    want = generate_tokens(eng, prompt, 5, extras)
    got = generate_tokens(eng, prompt, 5, extras, speculate=SpecConfig(k=4))
    assert_tokens_identical(want, got, msg=arch)
    assert eng.spec_stats["verify_steps"] >= 1
    assert eng.spec_stats["emitted_per_step"] >= 1.0


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_spec_continuous_engine_parity_all_families(arch):
    """The speculative continuous-batching engine (per-slot history,
    variable accepted-length page advance) == the plain one, every family —
    the single-device paged-engine leg."""
    cfg, params, prompt, extras = setup_family(arch)
    plain = build_engine("continuous", cfg, params, max_seq=16,
                         page_alloc_seed=7)
    want = generate_tokens(plain, prompt, 5, extras)
    spec = build_engine("continuous", cfg, params, max_seq=16,
                        page_alloc_seed=7, speculate=SpecConfig(k=4))
    got = generate_tokens(spec, prompt, 5, extras)
    assert_tokens_identical(want, got, msg=arch)
    assert spec.spec_live_steps >= 1
    assert spec.spec_emitted >= spec.spec_live_steps  # >= 1 token per window


@pytest.mark.parametrize("bits,kv_bits", [(0, 0), (8, 0), (4, 0), (8, 8)])
@pytest.mark.parametrize("kind", ["fixed", "continuous"])
def test_spec_parity_bits_matrix(kind, bits, kv_bits):
    """Weight storage (dense / INT8 / INT4) and the INT8 KV cache (the
    quantized branch of ``attn_verify``: window re-quantization + the
    k_scale/v_scale scatter) never break speculative token-identity, on
    either engine."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b", kv_bits=kv_bits)
    if kind == "fixed":
        eng = build_engine(kind, cfg, params, max_seq=16, bits=bits)
        want = generate_tokens(eng, prompt, 5, extras)
        got = generate_tokens(eng, prompt, 5, extras, speculate=4)
    else:
        want = generate_tokens(
            build_engine(kind, cfg, params, max_seq=16, bits=bits),
            prompt, 5, extras)
        got = generate_tokens(
            build_engine(kind, cfg, params, max_seq=16, bits=bits,
                         speculate=4),
            prompt, 5, extras)
    assert_tokens_identical(want, got, msg=f"{kind} bits={bits} kv={kv_bits}")


@pytest.mark.parametrize("k", [1, 2, 7])
def test_spec_window_sizes(k):
    """Any window size is exact (k=1 is the minimal draft; k=7 overshoots
    n_new, exercising the emission cap)."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    eng = build_engine("fixed", cfg, params, max_seq=32)
    want = generate_tokens(eng, prompt, 6, extras)
    got = generate_tokens(eng, prompt, 6, extras, speculate=SpecConfig(k=k))
    assert_tokens_identical(want, got, msg=f"k={k}")


def test_spec_staggered_continuous_matches_solo():
    """Mixed prompt lengths / budgets through the speculative scheduler:
    every request equals its solo dense run (admit/retire staggering with
    per-slot accepted lengths)."""
    cfg, params, _, _ = setup_family("qwen2-1.5b")
    rng = np.random.default_rng(0)
    shapes = [(5, 4), (7, 6), (3, 3), (9, 5), (4, 7)]
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=L).astype(np.int32),
                    max_new=m) for L, m in shapes]
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=3, page_alloc_seed=1,
                                   speculate=SpecConfig(k=4))
    assert_serve_matches_solo(eng, cfg, params, reqs)


def test_spec_stop_token_truncates_inside_window():
    """A stop token landing mid-window truncates that slot's emissions at
    the stop and retires it, exactly like the per-token engine."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    dense = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(dense.generate(prompt, n_new=6))
    stop = int(base[0, 3])
    first = int(np.argmax(base[0] == stop))
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                   page_size=4, chunk=2,
                                   speculate=SpecConfig(k=4))
    outs = eng.serve([
        Request(prompt=np.asarray(prompt[0]), max_new=6, stop_tokens=(stop,)),
        Request(prompt=np.asarray(prompt[1]), max_new=6),
    ])
    assert_tokens_identical(base[0, : first + 1], outs[0])
    assert_tokens_identical(base[1], outs[1])
    assert eng.pages_in_use() == 0


def test_spec_fixed_engine_stop_tokens_masked():
    """The fixed engine's stop handling is post-masking; speculation must
    compose with it identically."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=16)
    base = np.asarray(eng.generate(prompt, n_new=6))
    stop = int(base[0, 2])
    want = np.asarray(eng.generate(prompt, n_new=6, stop_tokens=(stop,),
                                   pad_id=-1))
    got = np.asarray(eng.generate(prompt, n_new=6, stop_tokens=(stop,),
                                  pad_id=-1, speculate=4))
    assert_tokens_identical(want, got)


# ------------------------------------------------------------- draft model --
def test_spec_draft_mode_self_draft_full_acceptance():
    """Draft == target: every proposal matches, so each verify window emits
    its full k+1 tokens (minus the final capped window) and the output is
    identical to plain greedy."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=8, draft_cfg=cfg,
                        draft_params=params, draft_pim_bits=8)
    want = generate_tokens(eng, prompt, 9, extras)
    got = generate_tokens(eng, prompt, 9, extras,
                          speculate=SpecConfig(k=3, mode="draft"))
    assert_tokens_identical(want, got)
    # b=2 rows, 8 post-prefill tokens each, k+1=4 per window: 2 windows/row
    assert eng.spec_stats["emitted_per_step"] == pytest.approx(4.0)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b"])
def test_spec_draft_mode_mismatched_draft_still_exact(arch):
    """A WORSE draft (int4-quantized weights vs the int8 target) may get
    rejected more, but exactness is independent of draft quality — the SSM
    state rollback to the accepted step is what this stresses."""
    cfg, params, prompt, extras = setup_family(arch)
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=8, draft_cfg=cfg,
                        draft_params=params, draft_pim_bits=4)
    want = generate_tokens(eng, prompt, 7, extras)
    got = generate_tokens(eng, prompt, 7, extras,
                          speculate=SpecConfig(k=3, mode="draft"))
    assert_tokens_identical(want, got, msg=arch)


def test_spec_draft_mode_requires_draft_model():
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=16)
    with pytest.raises(ValueError, match="draft"):
        eng.generate(prompt, n_new=4, speculate=SpecConfig(k=2, mode="draft"))


# --------------------------------------------------------------- guardrails -
def test_spec_sampling_now_supported():
    """Sampling + speculation no longer raises: it routes to the
    rejection-sampling verifier (tests/test_sampled_speculative.py owns the
    behavioural matrix; this pins the API)."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=16)
    out = eng.generate(prompt, n_new=4, greedy=False, temperature=0.8,
                       speculate=4, key=jax.random.PRNGKey(0))
    assert out.shape == (2, 4)
    assert eng.spec_stats["greedy"] is False
    outs = ContinuousBatchingEngine(
        cfg, params, slots=1, max_seq=16, page_size=4, speculate=4).serve(
        [Request(prompt=np.asarray(prompt[0]), max_new=2)], greedy=False)
    assert len(outs) == 1 and len(outs[0]) <= 2


def test_spec_config_validation():
    with pytest.raises(ValueError, match="k >= 1"):
        SpecConfig(k=0)
    with pytest.raises(ValueError, match="mode"):
        SpecConfig(mode="oracle")
    cfg, params, _, _ = setup_family("qwen2-1.5b")
    with pytest.raises(ValueError, match="draft"):
        ContinuousBatchingEngine(cfg, params, slots=1, max_seq=16,
                                 page_size=4,
                                 speculate=SpecConfig(mode="draft"))


# ------------------------------------------------------------ proposer unit -
def test_propose_ngram_prompt_lookup():
    """The trailing n-gram [3, 4] recurs earlier; proposals are the tokens
    that followed the MOST RECENT earlier occurrence."""
    hist = jnp.asarray([[1, 3, 4, 9, 3, 4, 7, 2, 3, 4, 0, 0]], jnp.int32)
    hlen = jnp.asarray([10])  # live prefix: 1 3 4 9 3 4 7 2 3 4
    out = np.asarray(propose_ngram(hist, hlen, k=3, n=2))
    # most recent earlier [3,4] is at 4..5 -> continuation 7, 2, 3
    np.testing.assert_array_equal(out, [[7, 2, 3]])


def test_propose_ngram_fallback_repeats_last():
    hist = jnp.zeros((1, 8), jnp.int32).at[0, :4].set(
        jnp.asarray([5, 6, 7, 8]))
    out = np.asarray(propose_ngram(hist, jnp.asarray([4]), k=3, n=2))
    np.testing.assert_array_equal(out, [[8, 8, 8]])  # no earlier [7,8]


def test_propose_ngram_continuation_past_live_end():
    """A match whose continuation runs past the live prefix pads with the
    pending token instead of reading stale history."""
    hist = jnp.asarray([[2, 5, 2, 5, 0, 0, 0, 0]], jnp.int32)
    out = np.asarray(propose_ngram(hist, jnp.asarray([4]), k=4, n=2))
    # match [2,5] at 0..1 -> continuation 2, 5, then past hlen -> last (5)
    np.testing.assert_array_equal(out, [[2, 5, 5, 5]])


def test_greedy_accept_longest_prefix():
    window = jnp.asarray([[7, 1, 2, 3]], jnp.int32)  # tok + drafts 1,2,3
    v = 10
    logits = jnp.full((1, 4, v), -1.0)
    # target's argmax after 7 -> 1 (match), after 1 -> 2 (match),
    # after 2 -> 9 (MISMATCH with draft 3), after 3 -> irrelevant
    logits = logits.at[0, 0, 1].set(1.0).at[0, 1, 2].set(1.0)
    logits = logits.at[0, 2, 9].set(1.0).at[0, 3, 4].set(1.0)
    g, a = greedy_accept(window, logits)
    assert int(a[0]) == 2  # drafts 1, 2 accepted, 3 rejected
    np.testing.assert_array_equal(np.asarray(g), [[1, 2, 9, 4]])
    # row emits g[: a+1] = [1, 2, 9]: accepted drafts + bonus correction


# ----------------------------------------------- 8-device token identity ----
SPEC_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, os.path.join(r"{repo}", "tests"))
from helpers import setup_family, build_engine, generate_tokens, batch_requests
from repro.serving import SpecConfig, make_decode_mesh

MODE = sys.argv[1]
ARCHS = sys.argv[2].split(",")
mesh = make_decode_mesh(8)
out = []
for arch in ARCHS:
    cfg, params, prompt, extras = setup_family(arch)
    row = {{"arch": arch}}
    if MODE == "fixed":
        plain = build_engine("fixed", cfg, params, max_seq=16, bits=8)
        shard = build_engine("fixed", cfg, params, max_seq=16, bits=8,
                             mesh=mesh)
        want = generate_tokens(plain, prompt, 5, extras)
        got = generate_tokens(shard, prompt, 5, extras,
                              speculate=SpecConfig(k=4))
        row["identical"] = bool(np.array_equal(want, got))
        row["emitted_per_step"] = shard.spec_stats["emitted_per_step"]
    elif MODE == "paged":
        plain = build_engine("continuous", cfg, params, max_seq=16, bits=8,
                             page_alloc_seed=7)
        shard = build_engine("continuous", cfg, params, max_seq=16, bits=8,
                             page_alloc_seed=7, mesh=mesh,
                             speculate=SpecConfig(k=4))
        reqs_a = batch_requests(prompt, 5, extras)
        reqs_b = batch_requests(prompt, 5, extras)
        a, b = plain.serve(reqs_a), shard.serve(reqs_b)
        row["identical"] = bool(all(np.array_equal(x, y)
                                    for x, y in zip(a, b)))
    out.append(row)
print("RESULT " + json.dumps(out))
""".format(repo=REPO)


def _run_spec_sharded(mode: str, archs: str):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SPEC_SNIPPET, mode, archs],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_spec_sharded_fixed_engine_all_families():
    """Acceptance: speculative greedy decode on a forced 8-virtual-device
    mesh == non-speculative single-device greedy, fixed engine, all
    families."""
    rows = _run_spec_sharded("fixed", ",".join(FAMILY_ARCHS))
    for r in rows:
        assert r["identical"], r
        assert r["emitted_per_step"] >= 1.0, r


def test_spec_sharded_paged_engine_all_families():
    """Acceptance: the speculative continuous-batching scheduler under
    shard_map == its plain single-device run, all families."""
    rows = _run_spec_sharded("paged", ",".join(FAMILY_ARCHS))
    for r in rows:
        assert r["identical"], r
