"""shard_map compressed gradient exchange on a real multi-device mesh."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import _axis_kwargs
from repro.optim.distributed import dp_train_step_factory

mesh = jax.make_mesh((8,), ("data",), **_axis_kwargs(1))
W = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
params = {"w": jnp.zeros((16, 4))}
x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
y = x @ W

def loss_fn(p, b):
    pred = b["x"] @ p["w"]
    return jnp.mean((pred - b["y"]) ** 2)

step = dp_train_step_factory(loss_fn, mesh, axis="data")
residual = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params)
losses = []
# 150 steps (was 60): under jax 0.4.37 the int8 error-feedback exchange
# reaches the 100x loss-reduction bar at ~step 100 (trajectory verified
# monotone: 10.75 -> 0.18 @60 -> 0.042 @100 -> 0.012 @140); the original
# 60-step budget was tuned on a newer jax and never passed in this image.
for i in range(150):
    with mesh:
        g, residual, loss = step(params, {"x": x, "y": y}, residual)
    params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    losses.append(float(loss))

# exact-gradient comparison on final params
g_exact = jax.grad(loss_fn)(params, {"x": x, "y": y})["w"]
print("RESULT " + json.dumps({
    "first": losses[0], "last": losses[-1],
    "gnorm": float(jnp.linalg.norm(g_exact)),
}))
"""


@pytest.mark.slow
def test_compressed_dp_training_converges():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SNIPPET], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # int8-compressed gradient exchange must still solve the least-squares
    assert out["last"] < 0.01 * out["first"], out
