"""Crash-mid-save atomicity of CheckpointManager.

The recovery contract claimed in runtime/fault.py ("restore latest atomic
checkpoint") only holds if a save that dies at ANY point — mid leaf write,
before the manifest, between the rename-aside and the publish rename —
leaves ``latest_step``/``restore_latest`` pointing at a COMPLETE
checkpoint.  These tests inject crashes at each stage with monkeypatched
I/O and assert resume still works; they also lock the manifest-last commit
ordering and the ``.tmp``/``.old`` staging-dir hygiene that makes the
parsing in ``latest_step``/``_gc`` crash-proof.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_mod
from repro.checkpoint.ckpt import CheckpointManager, latest_step, save_tree


def tree_for(step: int):
    return {"w": np.full((4, 3), float(step), np.float32),
            "b": {"x": np.arange(step + 1, dtype=np.int32)}}


def assert_restores(mgr, want_step):
    step, got = mgr.restore_latest(tree_for(0))
    assert step == want_step
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  tree_for(want_step)["w"])


class Boom(RuntimeError):
    pass


def crash_after(monkeypatch, obj, name, n_calls):
    """Let ``obj.name`` run ``n_calls`` times, then raise Boom forever."""
    real = getattr(obj, name)
    state = {"n": 0}

    def wrapper(*a, **kw):
        state["n"] += 1
        if state["n"] > n_calls:
            raise Boom(f"injected crash in {name} after {n_calls}")
        return real(*a, **kw)

    monkeypatch.setattr(obj, name, wrapper)
    return state


def test_crash_mid_leaf_write_keeps_previous(tmp_path, monkeypatch):
    """Dying while writing leaf .npy files (manifest never written) must
    leave the previous checkpoint as the restorable latest."""
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, tree_for(1))
    crash_after(monkeypatch, ckpt_mod.np, "save", 1)  # 2nd leaf dies
    with pytest.raises(Boom):
        mgr.save(2, tree_for(2))
    monkeypatch.undo()
    assert latest_step(str(tmp_path)) == 1
    assert_restores(mgr, 1)
    # the half-written staging dir must not shadow anything or crash parsing
    leftovers = [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    assert leftovers == ["step_00000002.tmp"]
    # ...and the next manager sweep cleans it up
    mgr.save(3, tree_for(3))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_crash_before_publish_rename(tmp_path, monkeypatch):
    """Dying after staging completes but before the publish rename: the
    .tmp dir is complete (manifest and all) yet must stay invisible."""
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(5, tree_for(5))
    crash_after(monkeypatch, ckpt_mod.os, "rename", 0)
    with pytest.raises(Boom):
        mgr.save(6, tree_for(6))
    monkeypatch.undo()
    assert os.path.exists(os.path.join(tmp_path, "step_00000006.tmp",
                                       "manifest.json"))
    assert latest_step(str(tmp_path)) == 5
    assert_restores(mgr, 5)


def test_crash_between_aside_and_publish_on_resave(tmp_path, monkeypatch):
    """Re-saving an existing step dies between the rename-aside of the old
    dir and the publish of the new one: resume must survive — the .old
    aside is ignored by latest_step (this window is why the old dir is
    renamed aside rather than deleted: a complete .tmp still exists, and
    nothing half-deleted can be picked up)."""
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, tree_for(1))
    mgr.save(7, tree_for(7))
    crash_after(monkeypatch, ckpt_mod.os, "rename", 1)  # aside ok, publish no
    with pytest.raises(Boom):
        mgr.save(7, tree_for(3))
    monkeypatch.undo()
    # step 7 is aside as .old; step 1 is the newest PUBLISHED checkpoint,
    # and the int() parse must not trip on "step_00000007.old"/".tmp"
    assert latest_step(str(tmp_path)) == 1
    assert_restores(mgr, 1)
    # recovery path: the next save sweeps the staging leftovers
    mgr.save(8, tree_for(8))
    assert not any(n.endswith((".tmp", ".old")) for n in os.listdir(tmp_path))
    assert_restores(mgr, 8)


def test_manifest_written_last(tmp_path, monkeypatch):
    """The manifest is the commit record: every leaf file must hit disk
    before it.  Crash the manifest write itself and assert the directory
    is not counted as a checkpoint."""
    calls = []
    real_open = ckpt_mod.open if hasattr(ckpt_mod, "open") else open

    def tracking_open(path, *a, **kw):
        calls.append(os.path.basename(str(path)))
        if os.path.basename(str(path)) == "manifest.json" and "w" in (
                a[0] if a else kw.get("mode", "r")):
            raise Boom("manifest write dies")
        return real_open(path, *a, **kw)

    monkeypatch.setattr(ckpt_mod, "open", tracking_open, raising=False)
    with pytest.raises(Boom):
        save_tree(tree_for(1), str(tmp_path / "step_00000001.tmp"))
    monkeypatch.undo()
    # all leaves were opened (written) before the manifest was attempted
    assert calls[-1] == "manifest.json"
    assert len([c for c in calls if c.endswith(".npy")]) == 2
    assert latest_step(str(tmp_path)) is None


def test_gc_keeps_last_and_ignores_foreign_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    os.makedirs(tmp_path / "step_notanumber")  # foreign dir: must not crash
    for s in (1, 2, 3, 4):
        mgr.save(s, tree_for(s))
    steps = sorted(n for n in os.listdir(tmp_path)
                   if ckpt_mod._step_of(n) is not None)
    assert steps == ["step_00000003", "step_00000004"]
    assert latest_step(str(tmp_path)) == 4


def test_roundtrip_still_exact(tmp_path):
    """The durability changes must not disturb the save/restore contract."""
    tree = {"a": np.random.default_rng(0).normal(size=(5, 4)).astype(
        np.float32), "b": [np.arange(3), np.ones((2, 2), np.int32)]}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(11, tree)
    step, got = mgr.restore_latest(jax.tree.map(np.zeros_like, tree))
    assert step == 11
    for w, g in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
