"""Sampled speculative decoding via rejection sampling — the
distributional-equivalence test harness.

The contract has two layers, because the algorithm is only PARTLY
key-deterministic:

* **Seeded exactness** — every sampled draw is keyed by ``(base key,
  request id, draw counter)`` (``serving.sampling``), so for one given
  algorithm (plain sampled decode, or sampled speculation at a fixed k)
  the same key must produce IDENTICAL tokens across {dense fixed engine,
  paged continuous engine} x {1, 8 virtual devices}, across slot
  assignments/chunk sizes, and across recompute-preemption replays.
  Asserted token-for-token below.
* **Distributional equivalence** — speculative and plain decode consume
  DIFFERENT draw counts, so across algorithms only the output law is
  preserved: rejection-sampling verification (accept ``d ~ q`` w.p.
  ``min(1, p(d)/q(d))``, resample the first rejection from
  ``norm(max(p-q, 0))``) leaves the distribution of plain sampled decode
  exactly unchanged.  Asserted by pooled-bin chi-square homogeneity tests
  at alpha=0.01 over thousands of seeded decodes
  (``helpers.histogram_decode``) — per model family in the ``slow`` leg
  (CI runs it seeded with PYTHONHASHSEED pinned).

Plus hypothesis property tests (with stub-proof fixed-sample twins) for
the rejection primitive in isolation, the stop-token x sampled-speculation
interaction, and paged draft-cache coverage (leaks / freed-page reissue /
preemption mid-speculation).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from helpers import (
    FAMILY_ARCHS,
    PAGED_BITEXACT_ARCHS,
    assert_distributions_match,
    assert_sampled_parity,
    assert_tokens_identical,
    chi_square_homogeneity,
    histogram_decode,
    setup_family,
    total_variation,
)

from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    SpecConfig,
    acceptance_probs,
    rejection_sample,
    residual_dist,
)
from repro.serving.sampling import TAG_WINDOW, draw_keys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand_dists(rng, b, k, v, sharpness=1.0):
    """Random (q (b,k,v), p (b,k+1,v)) distribution stacks."""
    q = rng.gamma(sharpness, size=(b, k, v)) + 1e-9
    p = rng.gamma(sharpness, size=(b, k + 1, v)) + 1e-9
    return (jnp.asarray(q / q.sum(-1, keepdims=True), jnp.float32),
            jnp.asarray(p / p.sum(-1, keepdims=True), jnp.float32))


# ------------------------------------------ primitive: property + fixed twins
def _check_acceptance_probs(q, p, drafts):
    acc = np.asarray(acceptance_probs(drafts, q, p))
    assert acc.shape == drafts.shape
    assert (acc >= 0.0).all() and (acc <= 1.0).all()
    # the ratio itself where q(d) > 0
    qd = np.take_along_axis(np.asarray(q), np.asarray(drafts)[..., None],
                            -1)[..., 0]
    pd = np.take_along_axis(np.asarray(p)[:, :drafts.shape[1]],
                            np.asarray(drafts)[..., None], -1)[..., 0]
    mask = qd > 0
    np.testing.assert_allclose(acc[mask], np.minimum(1.0, pd / qd)[mask],
                               rtol=1e-5)


def _check_residual(p, q):
    r = np.asarray(residual_dist(p, q))
    assert (r >= -1e-7).all()  # non-negative
    np.testing.assert_allclose(r.sum(-1), 1.0, atol=1e-5)  # normalised
    # wherever p <= q the residual puts no mass (up to float eps)
    leq = np.asarray(p) <= np.asarray(q)
    has_mass = (np.maximum(np.asarray(p) - np.asarray(q), 0)
                .sum(-1, keepdims=True) > 0)
    assert (r[leq & np.broadcast_to(has_mass, r.shape)] < 1e-6).all()


@settings(max_examples=20)
@given(seed=st.integers(0, 2**16), k=st.integers(1, 5),
       v=st.integers(2, 17), sharpness=st.sampled_from([0.3, 1.0, 4.0]))
def test_acceptance_probs_properties(seed, k, v, sharpness):
    rng = np.random.default_rng(seed)
    q, p = _rand_dists(rng, 3, k, v, sharpness)
    drafts = jnp.asarray(rng.integers(0, v, size=(3, k)), jnp.int32)
    _check_acceptance_probs(q, p, drafts)


def test_acceptance_probs_fixed_samples():
    rng = np.random.default_rng(0)
    q, p = _rand_dists(rng, 2, 3, 8)
    _check_acceptance_probs(q, p, jnp.asarray(rng.integers(0, 8, (2, 3)),
                                              jnp.int32))
    # q(d) == 0 corner: accept prob is 1 where p(d) > 0, 0 where p(d) == 0
    q0 = jnp.zeros((1, 1, 4)).at[0, 0, 0].set(1.0)
    p0 = jnp.asarray([[[0.0, 0.5, 0.5, 0.0], [0.25] * 4]])
    acc = np.asarray(acceptance_probs(jnp.asarray([[1]], jnp.int32), q0, p0))
    assert acc[0, 0] == 1.0  # p(1)=0.5 > 0, q(1)=0
    acc = np.asarray(acceptance_probs(jnp.asarray([[3]], jnp.int32), q0, p0))
    assert acc[0, 0] == 0.0  # p(3)=0, q(3)=0


@settings(max_examples=20)
@given(seed=st.integers(0, 2**16), v=st.integers(2, 17),
       sharpness=st.sampled_from([0.3, 1.0, 4.0]))
def test_residual_dist_properties(seed, v, sharpness):
    rng = np.random.default_rng(seed)
    q, p = _rand_dists(rng, 2, 1, v, sharpness)
    _check_residual(p[:, 0], q[:, 0])


def test_residual_dist_fixed_samples():
    rng = np.random.default_rng(3)
    q, p = _rand_dists(rng, 4, 1, 11)
    _check_residual(p[:, 0], q[:, 0])
    # q == p: zero residual mass falls back to p itself (unreachable from
    # the sampler — q == p accepts with probability 1 — but total)
    same = p[:, 0]
    np.testing.assert_allclose(np.asarray(residual_dist(same, same)),
                               np.asarray(same), atol=1e-7)
    # disjoint supports: the residual IS p (plain target sampling)
    pq = jnp.asarray([[0.0, 0.0, 0.3, 0.7]])
    qq = jnp.asarray([[0.6, 0.4, 0.0, 0.0]])
    np.testing.assert_allclose(np.asarray(residual_dist(pq, qq)),
                               np.asarray(pq), atol=1e-7)


def _check_q_equals_p_accepts_all(p, drafts, seed):
    keys = draw_keys(jax.random.PRNGKey(seed),
                     jnp.arange(p.shape[0], dtype=jnp.int32), 0, TAG_WINDOW)
    toks, a = rejection_sample(keys, drafts, p[:, :-1], p)
    k = drafts.shape[1]
    np.testing.assert_array_equal(np.asarray(a), k)  # everything accepted
    np.testing.assert_array_equal(np.asarray(toks)[:, :k], np.asarray(drafts))


@settings(max_examples=15)
@given(seed=st.integers(0, 2**16), k=st.integers(1, 5), v=st.integers(2, 13))
def test_rejection_q_equals_p_accepts_all_properties(seed, k, v):
    rng = np.random.default_rng(seed)
    _, p = _rand_dists(rng, 3, k, v)
    # drafts must lie in q's support (they were "sampled from q"): resample
    # until every draft has positive mass — gamma draws are a.s. positive,
    # so any index works
    drafts = jnp.asarray(rng.integers(0, v, size=(3, k)), jnp.int32)
    _check_q_equals_p_accepts_all(p, drafts, seed)


def test_rejection_q_equals_p_accepts_all_fixed():
    rng = np.random.default_rng(9)
    _, p = _rand_dists(rng, 4, 3, 16)
    _check_q_equals_p_accepts_all(
        p, jnp.asarray(rng.integers(0, 16, (4, 3)), jnp.int32), 123)


def _check_disjoint_reduces_to_target(seed):
    """q's support disjoint from p's: every proposal rejects at position 0
    and the emitted token is a plain sample from p (the residual IS p)."""
    v, b, k = 12, 64, 3
    rng = np.random.default_rng(seed)
    p_half = rng.gamma(1.0, size=(v // 2,)) + 1e-9
    p_row = np.concatenate([np.zeros(v // 2), p_half])
    p_row /= p_row.sum()
    q_row = np.concatenate([np.ones(v // 2) / (v // 2), np.zeros(v // 2)])
    p = jnp.asarray(np.tile(p_row, (b, k + 1, 1)), jnp.float32)
    q = jnp.asarray(np.tile(q_row, (b, k, 1)), jnp.float32)
    drafts = jnp.asarray(rng.integers(0, v // 2, size=(b, k)), jnp.int32)
    keys = draw_keys(jax.random.PRNGKey(seed),
                     jnp.arange(b, dtype=jnp.int32), 0, TAG_WINDOW)
    toks, a = rejection_sample(keys, drafts, q, p)
    np.testing.assert_array_equal(np.asarray(a), 0)  # nothing accepted
    emitted = np.asarray(toks)[:, 0]
    assert (p_row[emitted] > 0).all()  # in p's support, never q's


@settings(max_examples=10)
@given(seed=st.integers(0, 2**16))
def test_rejection_disjoint_reduces_to_target_properties(seed):
    _check_disjoint_reduces_to_target(seed)


def test_rejection_disjoint_reduces_to_target_fixed():
    _check_disjoint_reduces_to_target(5)


def test_rejection_sample_primitive_preserves_target_distribution():
    """The sharpest single-window check: drafts sampled from a KNOWN q,
    verified against a KNOWN p — the first emitted token's histogram must
    match direct categorical sampling from p (chi-square, alpha=0.01)."""
    v, k, n = 24, 3, 4000
    rng = np.random.default_rng(42)
    q_row = rng.gamma(0.7, size=v) + 1e-9
    q_row /= q_row.sum()
    p_row = rng.gamma(0.7, size=v) + 1e-9
    p_row /= p_row.sum()
    q = jnp.asarray(np.tile(q_row, (n, k, 1)), jnp.float32)
    p = jnp.asarray(np.tile(p_row, (n, k + 1, 1)), jnp.float32)
    rids = jnp.arange(n, dtype=jnp.int32)
    dkeys = draw_keys(jax.random.PRNGKey(1), rids, 7, TAG_WINDOW)
    drafts = jax.vmap(
        lambda kk: jax.random.categorical(kk, jnp.log(jnp.asarray(q_row)),
                                          shape=(k,)))(dkeys).astype(jnp.int32)
    wkeys = draw_keys(jax.random.PRNGKey(2), rids, 0, TAG_WINDOW)
    toks, a = rejection_sample(wkeys, drafts, q, p)
    got = np.bincount(np.asarray(toks)[:, 0], minlength=v)
    ref_keys = draw_keys(jax.random.PRNGKey(3), rids, 0, TAG_WINDOW)
    ref = jax.vmap(
        lambda kk: jax.random.categorical(kk, jnp.log(jnp.asarray(p_row))))(
            ref_keys)
    want = np.bincount(np.asarray(ref), minlength=v)
    assert_distributions_match(got, want, msg="rejection primitive vs p")
    assert 0 < int(np.asarray(a).mean() * 1000)  # some acceptances happen


# --------------------------------------------------------- seeded exactness -
def test_sampled_spec_deterministic_and_key_sensitive():
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=24, pim_bits=8)
    k1, k2 = jax.random.PRNGKey(3), jax.random.PRNGKey(4)
    a = np.asarray(eng.generate(prompt, n_new=6, greedy=False,
                                temperature=0.9, key=k1, speculate=4))
    b = np.asarray(eng.generate(prompt, n_new=6, greedy=False,
                                temperature=0.9, key=k1, speculate=4))
    c = np.asarray(eng.generate(prompt, n_new=6, greedy=False,
                                temperature=0.9, key=k2, speculate=4))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)  # 12 draws over vocab 256: astronomically unlikely


@pytest.mark.parametrize("arch", PAGED_BITEXACT_ARCHS)
def test_sampled_parity_plain_all_families(arch):
    """Plain temperature/top-k generate: same key => identical tokens on the
    dense fixed engine and the paged continuous engine, for every arch —
    all seven families are bit-identical across the two cache layouts now
    that the moe expert combine reduces over the fixed top-k axis (see
    helpers.PAGED_BITEXACT_ARCHS)."""
    cfg, params, prompt, extras = setup_family(arch)
    assert_sampled_parity(cfg, params, prompt, extras, msg=arch)


@pytest.mark.parametrize("arch", PAGED_BITEXACT_ARCHS)
def test_sampled_spec_parity_all_families(arch):
    """Sampled SPECULATIVE decode (rejection-sampling verification): same
    key => identical tokens across dense/paged engines — the single-device
    dense-vs-paged leg of the acceptance matrix, now covering all seven
    families including the moe archs (exact top-k combine)."""
    cfg, params, prompt, extras = setup_family(arch)
    assert_sampled_parity(cfg, params, prompt, extras,
                          speculate=SpecConfig(k=4), msg=arch)


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                  "moonshot-v1-16b-a3b"])
def test_sampled_spec_moe_per_engine_exactness(arch):
    """moe-specific determinism knobs beyond the cross-engine parity the
    archs now meet (exact top-k combine promoted them into
    PAGED_BITEXACT_ARCHS): key-determinism on the fixed engine, and
    schedule independence on the paged engine (slot count / chunk size /
    page permutation never change a request's sampled tokens)."""
    cfg, params, prompt, extras = setup_family(arch)
    key = jax.random.PRNGKey(11)
    kw = dict(greedy=False, temperature=0.8, top_k=8, key=key)
    eng = ServingEngine(cfg, params, max_seq=24)
    a = np.asarray(eng.generate(prompt, n_new=5, extras=extras,
                                speculate=4, **kw))
    b = np.asarray(eng.generate(prompt, n_new=5, extras=extras,
                                speculate=4, **kw))
    assert_tokens_identical(a, b, msg=f"{arch} fixed-engine determinism")
    outs = []
    for slots, chunk, seed in ((2, 3, 1), (3, 2, 9)):
        cont = ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=24, page_size=4, chunk=chunk,
            page_alloc_seed=seed, speculate=4)
        outs.append(np.asarray(cont.generate(prompt, n_new=5, extras=extras,
                                             **kw)))
    assert_tokens_identical(outs[0], outs[1],
                            msg=f"{arch} paged schedule independence")


@pytest.mark.parametrize("temperature,top_k", [(0.7, 0), (1.2, 8), (0.5, 3)])
def test_sampled_spec_parity_warp_grid(temperature, top_k):
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    assert_sampled_parity(cfg, params, prompt, extras, temperature=temperature,
                          top_k=top_k, speculate=SpecConfig(k=3),
                          msg=f"T={temperature} top_k={top_k}")


def test_sampled_serve_schedule_independence():
    """The fold_in key discipline makes a request's sampled tokens depend
    only on (key, request index, progress): different slot counts, chunk
    sizes, and page-allocation orders must serve IDENTICAL outputs for the
    same key — speculative and plain."""
    cfg, params, _, _ = setup_family("qwen2-1.5b")
    rng = np.random.default_rng(0)
    reqs = lambda: [
        Request(prompt=rng_p, max_new=m)
        for rng_p, m in [(rng.integers(0, cfg.vocab, size=L).astype(np.int32), m)
                         for L, m in [(5, 6), (7, 4), (3, 7), (6, 5), (4, 6)]]]
    trace = reqs()
    key = jax.random.PRNGKey(21)
    for spec in (None, SpecConfig(k=3)):
        outs = []
        for slots, chunk, seed in ((2, 3, 1), (3, 2, 9), (2, 4, None)):
            eng = ContinuousBatchingEngine(
                cfg, params, slots=slots, max_seq=16, page_size=4,
                chunk=chunk, page_alloc_seed=seed, speculate=spec)
            outs.append(eng.serve(trace, greedy=False, temperature=0.8,
                                  key=key))
        for other in outs[1:]:
            for i, (x, y) in enumerate(zip(outs[0], other)):
                assert_tokens_identical(x, y, msg=f"req {i} spec={spec}")


def test_sampled_preemption_replays_same_stream():
    """Recompute preemption under sampling: the preempted request re-draws
    the SAME keys on re-admit, so a pool small enough to force preemption
    serves exactly what a huge pool serves."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    key = jax.random.PRNGKey(13)
    reqs = lambda: [Request(prompt=np.asarray(prompt[0]), max_new=18),
                    Request(prompt=np.asarray(prompt[1]), max_new=18)]
    big = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=32,
                                   page_size=4, chunk=4, speculate=4)
    want = big.serve(reqs(), greedy=False, temperature=0.8, key=key)
    small = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=32,
                                     page_size=4, num_pages=9, chunk=4,
                                     speculate=4)
    got = small.serve(reqs(), greedy=False, temperature=0.8, key=key)
    assert small.preemptions > 0
    for i, (x, y) in enumerate(zip(want, got)):
        assert_tokens_identical(x, y, msg=f"request {i}")


# ------------------------------------------- stop tokens x sampled windows --
def test_sampled_spec_stop_token_truncates_inside_window():
    """A stop token ACCEPTED mid-window must truncate the slot's emissions
    at the stop and retire it — nothing after the stop may leak out of the
    window (the sampled extension of the PR 3/4 stop-edge tests)."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    key = jax.random.PRNGKey(5)
    base_eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                        page_size=4, chunk=2, speculate=4)
    base = base_eng.serve(
        [Request(prompt=np.asarray(prompt[0]), max_new=8),
         Request(prompt=np.asarray(prompt[1]), max_new=8)],
        greedy=False, temperature=0.9, key=key)
    stop = int(base[0][3])  # row 0's 4th emission becomes the stop token
    first = int(np.argmax(np.asarray(base[0]) == stop))
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=24,
                                   page_size=4, chunk=2, speculate=4)
    outs = eng.serve(
        [Request(prompt=np.asarray(prompt[0]), max_new=8,
                 stop_tokens=(stop,)),
         Request(prompt=np.asarray(prompt[1]), max_new=8)],
        greedy=False, temperature=0.9, key=key)
    # same key => same stream up to the stop; emissions end AT the stop
    assert_tokens_identical(np.asarray(base[0])[: first + 1], outs[0])
    assert int(outs[0][-1]) == stop
    assert_tokens_identical(base[1], outs[1])  # other slot unaffected
    assert eng.pages_in_use() == 0


def test_sampled_spec_fixed_engine_stop_tokens_masked():
    """Fixed engine: stop handling is mask-after-stop post-processing; the
    sampled speculative path must compose with it exactly (stop kept,
    everything after masked — same key, same stream)."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=24)
    key = jax.random.PRNGKey(17)
    kw = dict(greedy=False, temperature=0.9, key=key, speculate=4)
    base = np.asarray(eng.generate(prompt, n_new=7, **kw))
    stop = int(base[0, 2])
    got = np.asarray(eng.generate(prompt, n_new=7, stop_tokens=(stop,),
                                  pad_id=-1, **kw))
    for row_base, row in zip(base, got):
        hits = np.flatnonzero(row_base == stop)
        if hits.size:
            t = hits[0]
            np.testing.assert_array_equal(row[: t + 1], row_base[: t + 1])
            assert (row[t + 1:] == -1).all()
        else:
            np.testing.assert_array_equal(row, row_base)
    assert (got[0] == -1).any()  # the chosen stop actually truncated row 0


# ------------------------------------------------- paged draft-cache cover --
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "falcon-mamba-7b",
                                  "zamba2-1.2b"])
def test_draft_mode_continuous_greedy_parity(arch):
    """Draft-model speculation on the continuous engine (paged draft cache
    sharing the target's block tables) stays token-identical to the plain
    paged engine under greedy decode — incl. SSM/hybrid per-slot draft
    state rollback."""
    cfg, params, prompt, extras = setup_family(arch)
    plain = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=32,
                                     page_size=4, chunk=3)
    want = np.asarray(plain.generate(prompt, n_new=6, extras=extras))
    draft = ContinuousBatchingEngine(
        cfg, params, slots=2, max_seq=32, page_size=4, chunk=3,
        speculate=SpecConfig(k=3, mode="draft"), draft_cfg=cfg,
        draft_params=params)
    got = np.asarray(draft.generate(prompt, n_new=6, extras=extras))
    assert_tokens_identical(want, got, msg=arch)
    assert draft.spec_emitted >= draft.spec_live_steps


def test_draft_mode_sampled_parity_dense_vs_paged():
    """Sampled draft speculation: same key => identical tokens on the fixed
    engine (dense draft cache) and the continuous engine (PAGED draft
    cache) — the read-back positions of the draft chain must come from its
    provisioned pages, not the trash page."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    assert_sampled_parity(cfg, params, prompt, extras, n_new=7, max_seq=32,
                          speculate=SpecConfig(k=3, mode="draft"), draft=True,
                          msg="draft")


def test_draft_mode_rejected_writes_do_not_leak_across_slots():
    """Draft chains of two slots interleave writes (their own pages + the
    shared trash page) in BOTH pools; page-permuted allocation must still
    reproduce the dense fixed-engine draft run exactly."""
    cfg, params, prompt, _ = setup_family("falcon-mamba-7b")
    spec = SpecConfig(k=4, mode="draft")
    dense = ServingEngine(cfg, params, max_seq=32, draft_cfg=cfg,
                          draft_params=params)
    want = np.asarray(dense.generate(prompt, n_new=8, speculate=spec))
    for seed in (0, 11):
        eng = ContinuousBatchingEngine(
            cfg, params, slots=2, max_seq=32, page_size=4, chunk=2,
            page_alloc_seed=seed, speculate=spec, draft_cfg=cfg,
            draft_params=params)
        got = np.asarray(eng.generate(prompt, n_new=8))
        np.testing.assert_array_equal(want, got, err_msg=f"seed={seed}")


def test_draft_mode_freed_page_reissue():
    """A small pool forces freed pages to be re-issued across BOTH pools
    (target + draft); every request still matches its solo dense-draft
    run — no ghost K/V or draft state from the previous owner."""
    cfg, params, _, _ = setup_family("qwen2-1.5b")
    rng = np.random.default_rng(3)
    spec = SpecConfig(k=3, mode="draft")
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=L).astype(np.int32),
                    max_new=m)
            for L, m in [(6, 6), (5, 7), (8, 4), (7, 5), (4, 8), (6, 5)]]
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_seq=20, page_size=4, num_pages=11, chunk=3,
        page_alloc_seed=5, speculate=spec, draft_cfg=cfg, draft_params=params)
    outs = eng.serve(reqs)
    dense = ServingEngine(cfg, params, max_seq=20, draft_cfg=cfg,
                          draft_params=params)
    for i, (r, got) in enumerate(zip(reqs, outs)):
        want = np.asarray(dense.generate(jnp.asarray(r.prompt)[None],
                                         r.max_new, speculate=spec))[0]
        assert_tokens_identical(want, got, msg=f"request {i}")
    assert eng.pages_in_use() == 0


def test_draft_mode_preemption_mid_speculation():
    """Recompute preemption of a slot mid-speculation with a draft model:
    the victim's pages (in both pools) are freed and re-admitted from
    scratch; tokens must equal the no-preemption run (same key replay)."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    spec = SpecConfig(k=3, mode="draft")
    kw = dict(slots=2, max_seq=32, page_size=4, chunk=4, speculate=spec,
              draft_cfg=cfg, draft_params=params)
    reqs = lambda: [Request(prompt=np.asarray(prompt[0]), max_new=16),
                    Request(prompt=np.asarray(prompt[1]), max_new=16)]
    big = ContinuousBatchingEngine(cfg, params, **kw)
    want = big.serve(reqs())
    small = ContinuousBatchingEngine(cfg, params, num_pages=11, **kw)
    got = small.serve(reqs())
    assert small.preemptions > 0
    for i, (x, y) in enumerate(zip(want, got)):
        assert_tokens_identical(x, y, msg=f"request {i}")


def test_draft_mode_sampled_parity_at_max_seq_boundary():
    """A request using the FULL max_seq budget: the draft chain's last
    windows read speculative positions past the request frontier, which
    must come from real provisioned storage on both engines (the paged
    pools and the dense draft cache carry k positions of read-ahead) —
    not the trash page / dropped writes — or cross-engine key-determinism
    breaks exactly at the boundary."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    # len(prompt)=8 + n_new=8 == max_seq=16: zero slack
    assert_sampled_parity(cfg, params, prompt, extras, n_new=8, max_seq=16,
                          speculate=SpecConfig(k=3, mode="draft"), draft=True,
                          msg="draft at max_seq boundary")


def test_draft_mode_requires_draft_model():
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    with pytest.raises(ValueError, match="draft"):
        ContinuousBatchingEngine(cfg, params, slots=1, max_seq=16,
                                 page_size=4,
                                 speculate=SpecConfig(mode="draft"))


# ------------------------------------------------ distributional equivalence
def _spec_vs_plain_histograms(arch, n_draws, *, batch=250, n_new=3,
                              temperature=1.0, top_k=0, speculate=4,
                              draft=False):
    """Histograms of the LAST emitted token: plain sampled decode vs
    sampled speculative decode on identical replicated prompts.  Rows of a
    batch are independent seeded decodes under the per-row key discipline,
    so one compiled call yields ``batch`` draws."""
    cfg, params, prompt, extras = setup_family(arch, b=1, s=6)
    prompt = jnp.tile(prompt, (batch, 1))
    if extras is not None:
        extras = jax.tree.map(lambda a: jnp.tile(
            a, (batch,) + (1,) * (a.ndim - 1)), extras)
    dkw = dict(draft_cfg=cfg, draft_params=params) if draft else {}
    eng = ServingEngine(cfg, params, max_seq=16, **dkw)
    spec = (SpecConfig(k=int(speculate), mode="draft") if draft
            else SpecConfig(k=int(speculate)))

    def gen(speculate_arg):
        def f(key):
            return eng.generate(prompt, n_new=n_new, extras=extras,
                                greedy=False, temperature=temperature,
                                top_k=top_k, key=key, speculate=speculate_arg)
        return f

    plain = histogram_decode(gen(None), cfg.vocab, n_draws, base_seed=100)
    spec_h = histogram_decode(gen(spec), cfg.vocab, n_draws, base_seed=900)
    return plain, spec_h


def test_spec_distribution_matches_plain_quick():
    """The fast (tier-1) distributional leg: one arch, 750 draws."""
    plain, spec = _spec_vs_plain_histograms("qwen2-1.5b", 750)
    assert_distributions_match(plain, spec, msg="qwen2-1.5b quick")


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_spec_distribution_matches_plain_all_families(arch):
    """ACCEPTANCE: for every model family, empirical token histograms of
    sampled speculative decode vs plain sampled decode pass a chi-square
    test at alpha=0.01 over >= 2000 seeded draws."""
    plain, spec = _spec_vs_plain_histograms(arch, 2000)
    assert_distributions_match(plain, spec, msg=arch)


@pytest.mark.slow
def test_spec_distribution_matches_plain_draft_mode():
    """Draft-model sampled speculation preserves the distribution too (the
    q used in the accept ratio is the draft's own warped softmax)."""
    plain, spec = _spec_vs_plain_histograms("qwen2-1.5b", 2000, draft=True,
                                            speculate=3)
    assert_distributions_match(plain, spec, msg="draft")


@pytest.mark.slow
@pytest.mark.parametrize("temperature,top_k", [(0.7, 0), (1.0, 8)])
def test_spec_distribution_matches_plain_warped(temperature, top_k):
    """Temperature/top-k warps shift both p and q consistently; the
    preserved distribution is the WARPED one."""
    plain, spec = _spec_vs_plain_histograms(
        "qwen2-1.5b", 2000, temperature=temperature, top_k=top_k)
    assert_distributions_match(plain, spec,
                               msg=f"T={temperature} top_k={top_k}")


def test_chi_square_helper_detects_mismatch():
    """The harness itself must have power: clearly different distributions
    reject at alpha=0.01, identical-sample splits do not."""
    rng = np.random.default_rng(0)
    a = rng.multinomial(2000, np.ones(64) / 64)
    b = rng.multinomial(2000, np.ones(64) / 64)
    _, _, p_same = chi_square_homogeneity(a, b)
    assert p_same >= 0.01
    skew = np.ones(64)
    skew[:8] = 8.0
    c = rng.multinomial(2000, skew / skew.sum())
    _, _, p_diff = chi_square_homogeneity(a, c)
    assert p_diff < 1e-6
    assert total_variation(a, c) > total_variation(a, b)


# ----------------------------------------------- 8-device key determinism ---
SAMPLED_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, os.path.join(r"{repo}", "tests"))
from helpers import setup_family
from repro.serving import (ContinuousBatchingEngine, ServingEngine,
                           SpecConfig, make_decode_mesh)

ARCHS = sys.argv[1].split(",")
mesh = make_decode_mesh(8)
key = jax.random.PRNGKey(23)
kw = dict(greedy=False, temperature=0.8, top_k=8, key=key)
out = []
for arch in ARCHS:
    cfg, params, prompt, extras = setup_family(arch)
    row = {{"arch": arch}}
    single = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
    want = np.asarray(single.generate(prompt, 5, extras=extras,
                                      speculate=4, **kw))
    shard = ServingEngine(cfg, params, max_seq=16, pim_bits=8, mesh=mesh)
    got = np.asarray(shard.generate(prompt, 5, extras=extras,
                                    speculate=4, **kw))
    row["fixed_identical"] = bool(np.array_equal(want, got))
    cont1 = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                     page_size=4, chunk=3, pim_bits=8,
                                     speculate=4)
    want_p = np.asarray(cont1.generate(prompt, 5, extras=extras, **kw))
    cont8 = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                     page_size=4, chunk=3, pim_bits=8,
                                     mesh=mesh, speculate=4)
    got_p = np.asarray(cont8.generate(prompt, 5, extras=extras, **kw))
    row["paged_identical"] = bool(np.array_equal(want_p, got_p))
    out.append(row)
print("RESULT " + json.dumps(out))
""".format(repo=REPO)


def test_sampled_spec_sharded_key_identity_all_families():
    """ACCEPTANCE, 8-device leg: sampled speculative decode with one key is
    token-identical between 1 and 8 virtual devices for BOTH engines,
    every family (subprocess with forced host devices, like the PR 3/4
    sharded suites) — the mesh all-gather is a pure concatenation, so
    sharding never changes a sampled draw.  The dense-vs-paged axis is
    asserted in-process at a single lowering
    (test_sampled_spec_parity_all_families): the two cache layouts' logits
    are bit-equal per arch there — all seven families since the exact moe
    top-k combine."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SAMPLED_SNIPPET, ",".join(FAMILY_ARCHS)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    for row in json.loads(line[len("RESULT "):]):
        assert row["fixed_identical"], row
        assert row["paged_identical"], row
