"""Serving: PIM quantize_tree correctness + batched generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import setup_family

from repro.configs import ARCH_IDS, get_reduced
from repro.models import forward, init_params
from repro.serving import ServingEngine, quantize_tree
from repro.serving.engine import pim_bytes


def _batch(cfg, key, b=2, s=16):
    kt, kf, ki = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(kf, (b, cfg.audio.n_frames, cfg.d_model))
        out["dec_tokens"] = out.pop("tokens")
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            ki, (b, cfg.vision.n_image_tokens, cfg.d_model)
        )
    return out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_quantized_forward_tracks_dense(arch_id):
    """PIM-mode (int8) logits must stay close to the dense logits."""
    cfg = get_reduced(arch_id)
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    dense, _ = forward(params, cfg, batch)
    quant, _ = forward(qparams, cfg, batch)
    dense, quant = np.asarray(dense, np.float32), np.asarray(quant, np.float32)
    # top-1 agreement is the serving-relevant metric
    agree = (dense.argmax(-1) == quant.argmax(-1)).mean()
    assert agree > 0.9, agree
    rel = np.linalg.norm(quant - dense) / (np.linalg.norm(dense) + 1e-9)
    assert rel < 0.1, rel


def test_quantize_tree_shrinks_bytes():
    cfg = get_reduced("starcoder2-7b").replace(param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    q = quantize_tree(params, bits=8)
    # f32 -> int8 on the matmul weights: expect a >2.5x overall shrink.
    assert pim_bytes(params) / pim_bytes(q) > 2.5


def test_quantize_tree_keeps_norms_dense():
    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    q = quantize_tree(params, bits=8)
    assert not isinstance(q["ln_f"], dict)
    assert isinstance(q["layers"]["mlp"]["gate"], dict)  # quantized
    assert q["layers"]["mlp"]["gate"]["codes"].dtype == jnp.int8


def test_serving_engine_generates():
    cfg, params, _, _ = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=32, pim_bits=8)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    out = eng.generate(prompt, n_new=5)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.vocab


def test_serving_engine_greedy_deterministic():
    cfg = get_reduced("llama3.2-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=16, pim_bits=0)
    prompt = jnp.ones((1, 3), jnp.int32)
    a = eng.generate(prompt, n_new=4)
    b = eng.generate(prompt, n_new=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
