"""Substrate tests: data determinism, optimizer, compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_step, restore_tree, save_tree
from repro.configs import SHAPES, get_reduced
from repro.data import DataConfig, make_batch, token_stream
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    cosine_schedule,
    decompress_gradients,
    error_feedback_update,
    global_norm,
)


# --------------------------------------------------------------------- data -
def test_data_deterministic_and_restart_safe():
    dc = DataConfig(seed=7, vocab=128)
    a = token_stream(dc, step=3, shape=(4, 64))
    b = token_stream(dc, step=3, shape=(4, 64))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = token_stream(dc, step=4, shape=(4, 64))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_data_shard_disjoint():
    dc = DataConfig(seed=7, vocab=128)
    a = token_stream(dc, step=0, shape=(2, 32), shard=0)
    b = token_stream(dc, step=0, shape=(2, 32), shard=1)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_make_batch_families():
    for arch in ("qwen2-1.5b", "seamless-m4t-medium", "llama-3.2-vision-90b"):
        cfg = get_reduced(arch)
        b = make_batch(cfg, SHAPES["train_4k"], batch_override=2, seq_override=16)
        key = "dec_tokens" if cfg.family == "encdec" else "tokens"
        assert b[key].shape == (2, 16)
        assert int(b[key].max()) < cfg.vocab


# ---------------------------------------------------------------- optimizer -
def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_bf16_params_f32_master():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw_init(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4, 4), 1e-3, jnp.bfloat16)}
    p2, state, _ = adamw_update(params, g, state, AdamWConfig())
    assert p2["w"].dtype == jnp.bfloat16
    assert int(state["step"]) == 1


def test_grad_clipping():
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params)
    g = {"w": jnp.array([1e6, 1e6, 1e6])}
    _, _, m = adamw_update(params, g, state, AdamWConfig(grad_clip=1.0))
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(0, 100, 1000))
    s_warm = float(cosine_schedule(100, 100, 1000))
    s_end = float(cosine_schedule(1000, 100, 1000))
    assert s0 < 0.02 and abs(s_warm - 1.0) < 1e-5 and 0.09 < s_end < 0.11


# -------------------------------------------------------------- compression -
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_compression_roundtrip_error_small(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (8, 16))
    comp = compress_gradients({"g": g})
    rec = decompress_gradients(comp)["g"]
    denom = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    assert float(jnp.max(jnp.abs(rec - g) / (denom + 1e-9))) < 1 / 120


def test_error_feedback_accumulates():
    """With error feedback, the *running sum* of decompressed grads tracks
    the true running sum (unbiased-in-the-limit compression)."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((4, 8))
    rec_sum = jnp.zeros((4, 8))
    residual = None
    for i in range(30):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (4, 8)) * 1e-4}
        comp, residual = error_feedback_update(g, residual)
        rec = decompress_gradients(comp)["g"]
        true_sum = true_sum + g["g"]
        rec_sum = rec_sum + rec
    err = float(jnp.max(jnp.abs(rec_sum - true_sum)))
    # residual carries at most one quantization step
    assert err < 2e-4, err


# ------------------------------------------------------------- checkpoints --
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16), "step": jnp.int32(7)},
    }
    d = str(tmp_path / "ck")
    save_tree(tree, d)
    out = restore_tree(jax.tree.map(jnp.zeros_like, tree), d)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_checkpoint_manager_atomic_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (10, 20, 30):
        mgr.save(s, {"w": jnp.full((4,), float(s))})
    assert latest_step(str(tmp_path)) == 30
    # GC keeps only the last two
    assert not os.path.exists(mgr.dir_for(10))
    step, restored = mgr.restore_latest(tree)
    assert step == 30
    assert float(restored["w"][0]) == 30.0


def test_checkpoint_crash_mid_save_preserves_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, {"w": jnp.ones((2,))})
    # simulate a crash: a stale .tmp directory exists for step 2
    os.makedirs(mgr.dir_for(2) + ".tmp", exist_ok=True)
    assert latest_step(str(tmp_path)) == 1
    step, restored = mgr.restore_latest({"w": jnp.zeros((2,))})
    assert step == 1 and float(restored["w"][0]) == 1.0


def test_restore_missing_key_raises(tmp_path):
    d = str(tmp_path / "ck")
    save_tree({"a": jnp.ones((2,))}, d)
    with pytest.raises(ValueError, match="missing keys"):
        restore_tree({"a": jnp.zeros((2,)), "b": jnp.zeros((2,))}, d)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5
