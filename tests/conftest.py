"""Shared test config.

NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 host devices.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
When it is absent we install a minimal stub module so test files that do
``from hypothesis import given, settings, strategies as st`` still collect;
every ``@given``-decorated test is then skipped instead of erroring.
"""
import os
import sys
import types

import pytest

# Tests run the serve loop in STRICT mode: a request left "pending" after
# the scheduler drains means the scheduler LOST it, and must raise instead
# of being coerced to "done" (engine.serve_detailed's final sweep).  Only a
# default — hardened-mode tests override via Engine.strict_pending.
os.environ.setdefault("REPRO_STRICT_SERVE", "1")


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run tests marked slow (the >=2000-draw chi-square legs of "
        "the sampled-speculation statistical harness; CI runs them in a "
        "dedicated seeded leg)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: many-seed statistical tests — skipped unless --run-slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow statistical leg; pass --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


try:
    from hypothesis import HealthCheck, settings

    # JIT compilation makes first examples slow; wall-clock deadlines are noise.
    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
except ModuleNotFoundError:  # pragma: no cover - exercised only without dev deps

    class _Strategy:
        """Inert strategy: supports the combinators our tests use."""

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

    def _strategy(*args, **kwargs):
        return _Strategy()

    def _given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "text", "composite"):
        setattr(_st, _name, _strategy)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    _hyp.assume = lambda *a, **k: True
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
