"""Shared test config.

NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 host devices.
"""
from hypothesis import HealthCheck, settings

# JIT compilation makes first examples slow; wall-clock deadlines are noise.
settings.register_profile(
    "repro",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")
