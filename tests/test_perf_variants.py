"""Perf-variant knobs lower correctly on a multi-device mesh (subprocess)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import _axis_kwargs
from repro.launch.steps import lower_cell
from repro.launch.roofline import analyze

mesh = jax.make_mesh((2, 4), ("data", "model"), **_axis_kwargs(2))
cfg = get_reduced("qwen2-1.5b")
out = {}
for vname, spec in [
    ("baseline", {}),
    ("no_fsdp", {"fsdp": False}),
    ("pim4", {"pim_bits": 4}),
    ("no_remat", {"remat": False}),
]:
    sc = ShapeConfig("train_t" if vname == "no_remat" else "decode_t", 64, 8,
                     "train" if vname == "no_remat" else "decode")
    cell = lower_cell(cfg, sc, mesh, variant=spec)
    roof = analyze(cell, cfg, sc)
    out[vname] = {"coll": roof.collective_bytes, "bytes": roof.hlo_bytes}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_variants_lower_and_change_artifacts():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SNIPPET], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # no_fsdp must reduce decode collective bytes vs baseline
    assert out["no_fsdp"]["coll"] < out["baseline"]["coll"]
    # all variants produced nonzero analyses
    for v in out.values():
        assert v["bytes"] > 0
