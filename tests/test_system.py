"""End-to-end system behaviour: a quantized linear layer executed on the
simulated PiCaSO machine matches the framework's quantized matmul, and the
cycle accounting matches the paper's analytical model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.mapping import matvec_cycles, simulate_matvec
from repro.kernels.ref import pim_matmul_int8_ref
from repro.quant import quantize_symmetric


def test_pim_machine_executes_quantized_linear():
    """The paper's machine and the TPU kernel path compute the same layer.

    A float weight matrix is int8-quantized once; the integer matvec runs
    (a) on the bit-serial PiCaSO simulator and (b) through the framework's
    dequant-matmul reference; results agree exactly up to the shared scales.
    """
    rng = np.random.default_rng(0)
    m, k, width = 4, 32, 8
    wf = rng.normal(size=(k, m)).astype(np.float32)
    q = quantize_symmetric(jnp.asarray(wf), bits=8, axis=0)
    codes = np.asarray(q.codes)  # (K, M)
    x_int = rng.integers(-100, 100, size=k)

    # (a) PIM overlay: integer matvec on the simulated machine
    vals, cycles = simulate_matvec(codes.T.copy(), x_int, width)

    # (b) framework: x @ codes in integer math
    want = x_int.astype(np.int64) @ codes.astype(np.int64)
    np.testing.assert_array_equal(vals, want)

    # and the float results agree with the dequant-fused kernel oracle
    got_f = vals * np.asarray(q.scale)[0]
    ref_f = np.asarray(
        pim_matmul_int8_ref(jnp.asarray(x_int, jnp.float32)[None, :], q.codes, q.scale)
    )[0]
    np.testing.assert_allclose(got_f, ref_f, rtol=1e-5)


def test_matvec_cycle_model_matches_paper_formulas():
    k, width = 64, 8
    acc_w = 2 * width + cm.log2i(k) + 1
    want = cm.mult_cycles_overlay(width) + cm.accum_cycles_picaso(k, acc_w)
    assert matvec_cycles(1, k, width, total_pes=k) == want
    # M rows in one wave cost the same as one row (SIMD)
    assert matvec_cycles(16, k, width, total_pes=16 * k) == want
    # but 2 waves cost twice
    assert matvec_cycles(2, k, width, total_pes=k) == 2 * want


def test_booth_average_halves_mult():
    assert cm.mult_cycles_overlay_booth_avg(8) == cm.mult_cycles_overlay(8) // 2
