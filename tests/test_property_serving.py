"""Property tests on the serving primitives: stop-token masking, the
sampling head, and the PIM quantize round-trip.

Each invariant lives in a plain ``_check_*`` helper driven twice: by a
hypothesis ``@given`` search (skipped under the conftest stub when the dev
dependency is absent) and by a deterministic fixed-sample test, so the
invariants stay exercised in every environment.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.serving import ServingEngine, mask_after_stop, pim_bytes, quantize_tree
from repro.serving.engine import sample_logits
from repro.models.common import dq, weight_shape


# ----------------------------------------------------------- mask_after_stop
def _check_mask_after_stop(tokens: np.ndarray, stops: tuple, pad: int):
    toks = jnp.asarray(tokens, jnp.int32)
    out = np.asarray(mask_after_stop(toks, stops, pad))
    # idempotence: masking a masked batch changes nothing (needs the pad
    # itself to not be a stop token, which the strategies guarantee)
    again = np.asarray(mask_after_stop(jnp.asarray(out), stops, pad))
    np.testing.assert_array_equal(out, again)
    if not stops:
        np.testing.assert_array_equal(out, tokens)
        return
    for row_in, row_out in zip(tokens, out):
        hits = np.flatnonzero(np.isin(row_in, list(stops)))
        if hits.size == 0:
            np.testing.assert_array_equal(row_out, row_in)
        else:
            t = hits[0]
            # prefix INCLUDING the first stop token survives untouched
            np.testing.assert_array_equal(row_out[: t + 1], row_in[: t + 1])
            # strictly everything after it is the pad id
            assert (row_out[t + 1 :] == pad).all()


@settings(max_examples=30)
@given(
    shape=st.tuples(st.integers(1, 4), st.integers(1, 12)),
    seed=st.integers(0, 2**16),
    stops=st.lists(st.integers(0, 9), max_size=3).map(tuple),
)
def test_mask_after_stop_properties(shape, seed, stops):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, 10, size=shape).astype(np.int32)
    _check_mask_after_stop(tokens, stops, pad=-1)


def test_mask_after_stop_fixed_samples():
    _check_mask_after_stop(
        np.asarray([[3, 1, 4, 1, 5], [9, 2, 6, 5, 3]], np.int32), (1, 5), -7)
    _check_mask_after_stop(np.asarray([[0, 0, 0]], np.int32), (0,), -1)
    _check_mask_after_stop(np.asarray([[2, 4, 6]], np.int32), (), -1)
    _check_mask_after_stop(np.asarray([[5]], np.int32), (5,), -1)


# -------------------------------------------------------------- sample_logits
def _check_sample_logits(logits: np.ndarray, top_k: int, seed: int):
    lg = jnp.asarray(logits, jnp.float32)
    key = jax.random.PRNGKey(seed)
    greedy = np.asarray(sample_logits(lg, key, greedy=True, temperature=1.0,
                                      top_k=0))
    np.testing.assert_array_equal(greedy, np.argmax(logits, -1))
    samp = np.asarray(sample_logits(lg, key, greedy=False, temperature=0.8,
                                    top_k=top_k))
    again = np.asarray(sample_logits(lg, key, greedy=False, temperature=0.8,
                                     top_k=top_k))
    np.testing.assert_array_equal(samp, again)  # same key -> same sample
    v = logits.shape[-1]
    kk = min(top_k, v) if top_k else v
    for row, tok in zip(logits.reshape(-1, v), samp.reshape(-1)):
        topk_set = np.argsort(row)[::-1][:kk]
        kth = row[topk_set[-1]]
        # support membership: the sampled id's logit is >= the kth-largest
        # (ties with the cut make the id set ambiguous; the logit bound
        # is the sharp invariant)
        assert row[tok] >= kth


@settings(max_examples=30)
@given(
    shape=st.tuples(st.integers(1, 3), st.integers(2, 9)),
    seed=st.integers(0, 2**16),
    top_k=st.integers(0, 12),
)
def test_sample_logits_properties(shape, seed, top_k):
    rng = np.random.default_rng(seed)
    _check_sample_logits(rng.normal(size=shape).astype(np.float32), top_k,
                         seed)


def test_sample_logits_fixed_samples():
    rng = np.random.default_rng(0)
    _check_sample_logits(rng.normal(size=(2, 7)).astype(np.float32), 3, 1)
    _check_sample_logits(rng.normal(size=(1, 4)).astype(np.float32), 0, 2)
    _check_sample_logits(rng.normal(size=(3, 5)).astype(np.float32), 99, 3)


# ----------------------------------------------------- quantize_tree round --
def _check_quantize_roundtrip(w: np.ndarray, bits: int):
    tree = {"layers": {"mlp": {"gate": jnp.asarray(w)}}}
    q = quantize_tree(tree, bits=bits)["layers"]["mlp"]["gate"]
    assert isinstance(q, dict) and q["codes"].dtype == jnp.int8
    k = w.shape[-2]
    if bits == 4:
        marker = "nibbles_odd" if k % 2 else "nibbles"
        assert marker in q
        assert q["codes"].shape[-2] == (k + 1) // 2  # two K rows per byte
    assert weight_shape(q) == w.shape
    dense = np.asarray(dq(q), np.float32)
    assert dense.shape == w.shape
    # symmetric quantization: |err| <= scale/2 everywhere (half a step;
    # the 1.001 slack absorbs f32 rounding in the scale itself)
    scale = np.asarray(q["scale"], np.float32)
    err = np.abs(dense - w)
    assert (err <= scale / 2 * 1.001 + 1e-7).all()
    # marker leaves are metadata: byte accounting counts codes+scale only
    want_bytes = (q["codes"].size * q["codes"].dtype.itemsize
                  + q["scale"].size * q["scale"].dtype.itemsize)
    assert pim_bytes({"w": q}) == want_bytes


@settings(max_examples=25)
@given(
    k=st.integers(8, 33),
    n=st.integers(8, 24),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_quantize_tree_roundtrip_properties(k, n, bits, seed):
    rng = np.random.default_rng(seed)
    _check_quantize_roundtrip(rng.normal(size=(k, n)).astype(np.float32),
                              bits)


def test_quantize_tree_roundtrip_fixed_samples():
    rng = np.random.default_rng(7)
    _check_quantize_roundtrip(rng.normal(size=(33, 16)).astype(np.float32), 4)
    _check_quantize_roundtrip(rng.normal(size=(32, 16)).astype(np.float32), 4)
    _check_quantize_roundtrip(rng.normal(size=(17, 9)).astype(np.float32), 8)
    # stacked leading dims (scanned layers) round-trip too
    _check_quantize_roundtrip(rng.normal(size=(3, 16, 8)).astype(np.float32),
                              4)


# -------------------------------------------------- pim_bytes(per_device=) --
def _check_pim_bytes_consistency(tree):
    total = pim_bytes(tree)
    per_dev = pim_bytes(tree, per_device=True)
    # an unplaced (or 1-device) tree: per-device IS the total; in general
    # one device can never hold more than everything
    assert 0 < per_dev <= total
    # total equals the sum over leaves minus marker metadata
    marker = ("nibbles", "nibbles_odd", "tp")
    want = sum(
        leaf.size * leaf.dtype.itemsize
        for path, leaf in jax.tree_util.tree_leaves_with_path(tree)
        if str(getattr(path[-1], "key", "")) not in marker)
    assert total == want


@settings(max_examples=15)
@given(
    k=st.integers(8, 24).map(lambda v: 2 * v),
    n=st.sampled_from([8, 16, 24]),
    bits=st.sampled_from([4, 8]),
)
def test_pim_bytes_consistency_properties(k, n, bits):
    tree = quantize_tree(
        {"a": {"wq": jnp.ones((k, n))}, "ln": jnp.ones((n,))}, bits=bits)
    _check_pim_bytes_consistency(tree)


def test_pim_bytes_per_device_sharded_tree():
    """On the always-available 1-device mesh a sharded tree reports
    per-device == total; the 8-device < comparison runs in
    test_sharded_decode's subprocess leg."""
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import make_decode_mesh, shard_quantized_tree

    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    for bits in (8, 4):
        t = shard_quantized_tree(quantize_tree(params, bits),
                                 make_decode_mesh(1))
        _check_pim_bytes_consistency(t)
        assert pim_bytes(t, per_device=True) == pim_bytes(t)
