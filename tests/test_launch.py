"""Launch layer: sharding rules, lowering, dry-run (subprocess, 8 devices)."""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.launch.sharding import param_spec, sanitize

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------- sharding rules ---
def test_param_spec_attention():
    assert param_spec(["layers", "attn", "wq"], 3, "data") == P(None, "data", "model")
    assert param_spec(["layers", "attn", "wo"], 3, "data") == P(None, "model", "data")


def test_param_spec_moe_vs_mlp():
    moe = param_spec(["layers", "moe", "gate"], 4, "data")
    mlp = param_spec(["layers", "mlp", "gate"], 3, "data")
    assert moe == P(None, "model", "data", None)  # experts over model (EP)
    assert mlp == P(None, "data", "model")


def test_param_spec_embed_vocab_sharded():
    assert param_spec(["embed"], 2, "data") == P("model", None)


def test_sanitize_drops_indivisible():
    mesh = jax.make_mesh((1,), ("model",))  # 1-device 'model' axis
    sh = {"w": NamedSharding(mesh, P("model", None))}
    shapes = {"w": jax.ShapeDtypeStruct((7, 4), jax.numpy.float32)}
    out = sanitize(sh, shapes)
    # 7 % 1 == 0 so kept; now with a fake bigger axis we can't build on 1 CPU,
    # so test divisibility logic directly on dim < axis size via size-1 dim
    shapes2 = {"w": jax.ShapeDtypeStruct((0, 4), jax.numpy.float32)}
    assert out["w"].spec[0] == "model"


DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import _axis_kwargs
from repro.launch.steps import lower_cell
from repro.launch.roofline import analyze

mesh = jax.make_mesh((2, 4), ("data", "model"), **_axis_kwargs(2))
out = []
for arch in sys.argv[1].split(","):
    cfg = get_reduced(arch)
    for sc in [ShapeConfig("train_t", 64, 8, "train"),
               ShapeConfig("prefill_t", 64, 8, "prefill"),
               ShapeConfig("decode_t", 64, 8, "decode")]:
        cell = lower_cell(cfg, sc, mesh)
        roof = analyze(cell, cfg, sc)
        out.append({
            "arch": arch, "shape": sc.name, "flops": roof.hlo_flops,
            "coll": roof.collective_bytes, "bottleneck": roof.bottleneck,
        })
print("RESULT " + json.dumps(out))
"""


def _run_snippet(archs: str):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", DRYRUN_SNIPPET, archs],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_lower_compile_all_kinds_multidevice():
    """Reduced configs of three families lower + compile on a 2x4 mesh with
    real collectives present (integration version of the 512-dev dry-run)."""
    rows = _run_snippet("qwen2-1.5b,deepseek-v2-lite-16b,falcon-mamba-7b")
    assert len(rows) == 9
    for r in rows:
        assert r["flops"] > 0, r
    # sharded training must communicate
    train_rows = [r for r in rows if r["shape"] == "train_t"]
    assert all(r["coll"] > 0 for r in train_rows)


def test_lower_cell_single_device_mesh():
    """lower_cell works on the 1-device mesh (no subprocess)."""
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import lower_cell

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced("qwen2-1.5b")
    cell = lower_cell(cfg, ShapeConfig("t", 32, 2, "train"), mesh)
    assert cell.lowered is not None
    compiled = cell.lowered.compile()
    assert compiled.cost_analysis() is not None
