"""Golden tests: the analytical models must reproduce the paper's numbers.

Every assertion cites the paper table/figure it validates.
"""
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.archmodels import (
    ARCHS,
    SPAR2,
    TABLE_IV,
    memory_efficiency_table,
    peak_throughput_table,
    relative_mac_latency,
)
from repro.core.devices import ALVEO_U55, TABLE_VII, VIRTEX7_485
from repro.core.scalability import max_array, scaling_study
from repro.core.simulator import simulate_dot_product


# ------------------------------------------------------------------ Table V -
def test_table5_add_mult():
    assert cm.add_sub_cycles(32) == 64  # 2N
    assert cm.mult_cycles_overlay(32) == 2 * 32 * 32 + 2 * 32  # 2N^2+2N


def test_table5_accumulation_goldens():
    """q=128, N=32: SPAR-2 NEWS = 4512 cycles, PiCaSO-F = 259 -> ~17x."""
    assert cm.accum_cycles_spar2(128, 32) == 4512
    assert cm.accum_cycles_picaso(128, 32) == 259
    assert cm.accum_cycles_spar2(128, 32) / cm.accum_cycles_picaso(128, 32) > 17


def test_table5_picaso_formula_matches_table8_at_q16():
    """For q=16 the Table V formula equals the Table VIII(d) block form."""
    for n in (4, 8, 16, 32):
        assert cm.accum_cycles_picaso(16, n) == cm.accum_cycles_picaso_block(16, n)


# --------------------------------------------------------------- Table VIII -
def test_table8_latency_goldens():
    """q=16, N=8 row: mult 86/144; accum 80 (custom) / 48 (PiCaSO) / 40 (Mod)."""
    assert cm.mult_cycles_custom(8) == 86
    assert cm.mult_cycles_overlay(8) == 144
    assert cm.accum_cycles_custom(16, 8) == 80
    assert cm.accum_cycles_picaso_block(16, 8) == 48
    assert cm.accum_cycles_amod(16, 8) == 40


def test_table8_clock_overheads():
    assert ARCHS["CCB"].clock_overhead == 0.60
    assert ARCHS["CoMeFa-D"].clock_overhead == 0.25
    assert ARCHS["CoMeFa-A"].clock_overhead == 1.50
    assert ARCHS["PiCaSO-F"].clock_overhead == 0.0
    # §IV-A: PiCaSO at BRAM fmax runs 1.62x / 1.25x faster than CCB / CoMeFa-D.
    f = ARCHS["PiCaSO-F"].fmax(ALVEO_U55)
    assert f / ARCHS["CCB"].fmax(ALVEO_U55) == pytest.approx(1.60, abs=0.03)
    assert f / ARCHS["CoMeFa-D"].fmax(ALVEO_U55) == pytest.approx(1.25, abs=0.01)


def test_table8_parallel_macs():
    """Custom designs: 144 PEs/BRAM36; PiCaSO 1/4 of the bitlines -> 36."""
    assert ARCHS["CCB"].parallel_macs_per_bram36 == 144
    assert ARCHS["PiCaSO-F"].parallel_macs_per_bram36 == 36


# -------------------------------------------------------------------- Fig 7 -
def test_fig7_memory_efficiency_goldens():
    """N=16: CCB 50%, CoMeFa 68.8%, PiCaSO 93.8% (paper §V)."""
    eff = memory_efficiency_table(16)
    assert eff["CCB"] == pytest.approx(0.50, abs=1e-3)
    assert eff["CoMeFa-A"] == pytest.approx(0.688, abs=1e-3)
    assert eff["PiCaSO-F"] == pytest.approx(0.938, abs=1e-3)


def test_fig7_amod_improvement():
    """A-Mod removes the copy scratchpad: +6.2pp over CoMeFa (paper §V-A)."""
    for n in (4, 8, 16):
        gain = ARCHS["A-Mod"].memory_efficiency(n) - ARCHS["CoMeFa-A"].memory_efficiency(n)
        assert gain == pytest.approx(n / 256, abs=1e-9)
    assert (
        ARCHS["A-Mod"].memory_efficiency(16) - ARCHS["CoMeFa-A"].memory_efficiency(16)
    ) == pytest.approx(0.0625, abs=1e-4)


# -------------------------------------------------------------------- Fig 5 -
def test_fig5_picaso_vs_comefa_a_latency():
    """PiCaSO 1.72x-2.56x faster than CoMeFa-A over plotted precisions."""
    ratios = [relative_mac_latency(n)["CoMeFa-A"] for n in (4, 8, 16)]
    assert max(ratios) == pytest.approx(2.56, abs=0.05)
    assert min(ratios) >= 1.72


def test_fig5_comefa_d_16bit_exception():
    """'With the exception of CoMeFa-D at 16-bit, PiCaSO has shortest latency'."""
    rel16 = relative_mac_latency(16)
    assert rel16["CoMeFa-D"] < 1.0
    for name in ("CCB", "CoMeFa-A"):
        assert rel16[name] > 1.0
    for n in (4, 8):
        rel = relative_mac_latency(n)
        for name in ("CCB", "CoMeFa-A", "CoMeFa-D"):
            assert rel[name] > 1.0


def test_fig5_mod_latency_improvement():
    """A-Mod/D-Mod improve custom MAC latency by ~13.4%-19.5% (paper §V-A)."""
    for n in (8, 16):
        base = ARCHS["CoMeFa-A"].mac16_latency_us(n, ALVEO_U55)
        mod = ARCHS["A-Mod"].mac16_latency_us(n, ALVEO_U55)
        gain = 1 - mod / base
        assert 0.10 < gain < 0.30


# -------------------------------------------------------------------- Fig 6 -
def test_fig6_picaso_throughput_fraction():
    """PiCaSO reaches 75-80% of CoMeFa-A peak TMAC/s on U55 (paper §V).

    The peak model credits the overlay's Booth NOP skipping (§V-B).
    """
    for n, lo, hi in ((4, 0.75, 0.85), (8, 0.70, 0.80)):
        tbl = peak_throughput_table(n)
        frac = tbl["PiCaSO-F"] / tbl["CoMeFa-A"]
        assert lo <= frac <= hi, (n, frac)


def test_fig6_mod_throughput_improvement():
    """A-Mod/D-Mod gain throughput from the zero-copy accumulation.

    Paper claims +5%-18% "over different precisions"; our 16-MAC-block model
    gives 10.8%-31.7% over N in {8,16,32} (N=16: 19.2%, matching the paper's
    19.5% latency claim).  The gain must shrink as mult dominates at high N.
    """
    gains = []
    for n in (8, 16, 32):
        base = cm.mac16_cycles_custom(n)
        mod = cm.mac16_cycles_mod(n)
        gains.append(base / mod - 1)
    assert all(0.05 < g < 0.35 for g in gains), gains
    assert gains == sorted(gains, reverse=True)  # monotone decreasing in N
    assert gains[1] == pytest.approx(0.195, abs=0.02)  # paper's 19.5% @ N=16


# ----------------------------------------------------------------- Table IV -
def test_table4_frequency_goldens():
    assert TABLE_IV[("full-pipe", "V7")].fmax_mhz == 540.0
    assert TABLE_IV[("full-pipe", "U55")].fmax_mhz == 737.0
    # 2.25x / 1.67x over the SPAR-2 benchmark (paper §IV-A).
    assert 540.0 / TABLE_IV[("benchmark", "V7")].fmax_mhz == pytest.approx(2.25, abs=0.01)
    assert 737.0 / TABLE_IV[("benchmark", "U55")].fmax_mhz == pytest.approx(1.66, abs=0.01)


def test_table4_slice_utilization_2x():
    """All PiCaSO configs offer >= ~2x better slice utilisation than SPAR-2."""
    for dev in ("V7", "U55"):
        bench = TABLE_IV[("benchmark", dev)].slice_tile
        full = TABLE_IV[("full-pipe", dev)].slice_tile
        assert bench / full >= 2.0


# ------------------------------------------------------- Table VI / Fig 4 ---
def test_table6_virtex7_max_arrays():
    """xc7vx485: SPAR-2 24K PEs (control-set limited), PiCaSO 33K (BRAM)."""
    spar2 = max_array("spar2", VIRTEX7_485)
    picaso = max_array("picaso", VIRTEX7_485)
    assert spar2.limited_by == "control-sets"
    assert 23_000 <= spar2.pes <= 25_000
    assert picaso.limited_by == "bram"
    assert 32_500 <= picaso.pes <= 33_500
    assert picaso.pes / spar2.pes == pytest.approx(1.375, abs=0.08)  # +37.5%
    assert picaso.bram_util > 0.99


def test_table6_u55_max_arrays():
    """U55: SPAR-2 63K (98.4% BRAM), PiCaSO 64K (100% BRAM, 2x slice util)."""
    spar2 = max_array("spar2", ALVEO_U55)
    picaso = max_array("picaso", ALVEO_U55)
    assert 62_000 <= spar2.pes <= 65_000
    assert picaso.pes == 64_512  # 2016 BRAM36 x 32 PEs
    assert picaso.bram_util == pytest.approx(1.0)
    assert spar2.slice_util / picaso.slice_util > 1.8


def test_fig4_picaso_scales_with_bram_everywhere():
    """Fig 4: PiCaSO hits 100% BRAM on every Table VII device; Max PE# col."""
    study = scaling_study(TABLE_VII)
    paper_max_pe = {
        "V7-a": 24_000, "V7-b": 32_960, "V7-c": 41_344, "V7-d": 60_160,
        "US-a": 23_040, "US-b": 67_584, "US-c": 69_120, "US-d": 86_016,
    }
    for dev_id, reports in study.items():
        pic = reports["picaso"]
        assert pic.limited_by == "bram", dev_id
        assert pic.bram_util == pytest.approx(1.0, abs=0.01), dev_id
        assert abs(pic.pes - paper_max_pe[dev_id]) / paper_max_pe[dev_id] < 0.01


def test_fig4_utilization_extremes():
    """V7-a (lowest LUT:BRAM): ~40% LUT/FF; US-c (highest): ~5%."""
    study = scaling_study(TABLE_VII)
    v7a = study["V7-a"]["picaso"]
    usc = study["US-c"]["picaso"]
    assert 0.30 < v7a.lut_util < 0.50
    assert 0.30 < v7a.ff_util < 0.50
    assert usc.lut_util < 0.07
    assert usc.ff_util < 0.07


# -------------------------------------------------- simulator cross-check ---
@pytest.mark.parametrize("q,width", [(16, 8), (32, 8), (64, 8), (128, 8), (16, 4)])
def test_simulator_dot_product_value_and_cycles(q, width):
    rng = np.random.default_rng(q + width)
    lo, hi = -(1 << (width - 1)), 1 << (width - 1)
    x = rng.integers(lo, hi, size=q)
    w = rng.integers(lo, hi, size=q)
    val, cycles = simulate_dot_product(x, w, width)
    assert val == int(np.dot(x.astype(np.int64), w.astype(np.int64)))
    # Cycle accounting = MULT + full PiCaSO accumulation at accumulator width.
    acc_w = 2 * width + cm.log2i(q) + 1
    want = cm.mult_cycles_overlay(width) + cm.accum_cycles_picaso(q, acc_w)
    assert cycles == want


def test_simulator_accumulation_beats_spar2_17x():
    """End-to-end: the simulated reduction reproduces the Table V headline."""
    q, n = 128, 32
    picaso = cm.accum_cycles_picaso(q, n)
    spar2 = cm.accum_cycles_spar2(q, n)
    assert spar2 / picaso == pytest.approx(4512 / 259, rel=1e-6)
