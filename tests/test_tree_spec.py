"""Tree (multi-candidate) speculative decoding: fan-of-chains drafts
verified in ONE target pass through shared-prefix attention.

The proposer keeps the top ``tree_fan`` history matches instead of only
the most recent (``propose_ngram_tree``; chain 0 IS the linear
proposer's pick).  ``models.verify_step(tree=(fan, depth))`` scores the
1 + fan*depth node window with tree-structured masking — every chain
attends to the shared root and its own prefix only — and acceptance
picks one chain: longest greedy prefix (``greedy_tree_accept``) or
SpecInfer-style sequential head elimination (``tree_reject_sample``,
exact multi-draft speculative sampling).  The winning chain's cache
columns are relocated into canonical positions (``models.tree_relocate``)
before commit, on dense AND paged layouts.

Contracts under test:

* **Greedy token identity** — tree speculation emits exactly plain
  greedy's tokens on every family, both engines (same moe horizon caveat
  as linear speculation; see test_adaptive_spec).
* **Sampled cross-engine identity** — unlike adaptive, the tree schedule
  is static (fixed window shape, fixed draw shapes F+D-1 uniforms + one
  categorical per window), so the SAME key gives IDENTICAL sampled
  tokens on the dense fixed engine and the paged continuous engine.
* **Degeneration** — a fan-1 tree is linear speculation: greedy output
  matches ``SpecConfig(k=depth)`` exactly.
* **Distribution preservation** — exact tree verification leaves plain
  sampled decode's output law unchanged (chi-square).
* **Relocation** — long-horizon paged runs cross page boundaries with
  relocated columns and still match plain decode bit-for-bit.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import (
    PAGED_BITEXACT_ARCHS,
    assert_distributions_match,
    assert_sampled_parity,
    assert_tokens_identical,
    batch_requests,
    histogram_decode,
    setup_family,
)

from repro.serving import ContinuousBatchingEngine, ServingEngine, SpecConfig
from repro.serving.sampling import tree_reject_sample, typical_accept_sample
from repro.serving.speculative import (
    greedy_tree_accept,
    propose_ngram,
    propose_ngram_tree,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TREE = SpecConfig(k=2, tree_fan=2)


# ---------------------------------------------------------------- proposer --
def test_tree_proposer_chain0_is_linear_proposer():
    hist = jnp.asarray([[5, 9, 5, 9, 5, 0, 0, 0],
                        [1, 2, 3, 1, 2, 3, 1, 0]], jnp.int32)
    hlen = jnp.asarray([5, 7], jnp.int32)
    lin = propose_ngram(hist, hlen, 3, 2)
    tree = propose_ngram_tree(hist, hlen, fan=2, depth=3, n=2)
    assert tree.shape == (2, 2, 3)
    np.testing.assert_array_equal(np.asarray(tree[:, 0]), np.asarray(lin))


def test_tree_proposer_distinct_matches_and_fallback():
    # Row 0: [7,8,X,7,8,Y,7,8] — the trailing (7,8) matched at two earlier
    # sites with DIFFERENT continuations; most recent first.
    hist = jnp.asarray([[7, 8, 3, 7, 8, 4, 7, 8, 0, 0]], jnp.int32)
    tree = propose_ngram_tree(hist, jnp.asarray([8]), fan=2, depth=1, n=2)
    assert np.asarray(tree[0, :, 0]).tolist() == [4, 3]
    # No match anywhere: every chain falls back to repeating last token.
    hist2 = jnp.asarray([[1, 2, 3, 4, 5, 0, 0, 0]], jnp.int32)
    tree2 = propose_ngram_tree(hist2, jnp.asarray([5]), fan=2, depth=2, n=2)
    assert (np.asarray(tree2) == 5).all()


# -------------------------------------------------------------- acceptance --
def _onehot_logits(tokens, vocab=16, scale=10.0):
    """Logits whose argmax (and ~all softmax mass) is ``tokens``."""
    return scale * jax.nn.one_hot(jnp.asarray(tokens), vocab)


def test_greedy_tree_accept_picks_longest_chain():
    # fan=2, depth=2.  Node order: [root, c0s0, c0s1, c1s0, c1s1].
    # Target's argmax: root->4, after c0's 5 -> 9, after c1's 4 -> 6,
    # after c1's 6 -> 8.  Chain 0 = [5, 9] matches 0 steps (5 != 4);
    # chain 1 = [4, 6] matches both and earns the bonus 8.
    chains = jnp.asarray([[[5, 9], [4, 6]]], jnp.int32)
    logits = _onehot_logits([[4, 9, 7, 6, 8]])
    toks, a, cf = greedy_tree_accept(chains, logits)
    assert (int(a[0]), int(cf[0])) == (2, 1)
    assert np.asarray(toks[0]).tolist() == [4, 6, 8]


def test_greedy_tree_accept_tie_prefers_chain0_and_kcap_caps():
    # Both chains match 1 step: lowest index (the linear chain) wins.
    chains = jnp.asarray([[[4, 9], [4, 6]]], jnp.int32)
    logits = _onehot_logits([[4, 1, 2, 3, 5]])
    toks, a, cf = greedy_tree_accept(chains, logits)
    assert (int(a[0]), int(cf[0])) == (1, 0)
    assert np.asarray(toks[0])[:2].tolist() == [4, 1]
    _, a0, _ = greedy_tree_accept(chains, logits,
                                  kcap=jnp.asarray([0], jnp.int32))
    assert int(a0[0]) == 0


def test_tree_reject_sample_accepts_dominant_chain():
    """Target mass concentrated on chain 1's path => chain 1 fully
    accepted with probability ~1, bonus from the last node."""
    chains = jnp.asarray([[[5, 9], [4, 6]]], jnp.int32)
    p = jax.nn.softmax(_onehot_logits([[4, 9, 7, 6, 8]], scale=30.0))
    keys = jax.random.split(jax.random.PRNGKey(0), 1)
    toks, a, cf = tree_reject_sample(keys, chains, p)
    assert (int(a[0]), int(cf[0])) == (2, 1)
    assert np.asarray(toks[0]).tolist() == [4, 6, 8]


def test_tree_reject_sample_rejects_zero_mass_heads():
    """Target puts zero mass on BOTH heads: every head rejects and the
    emitted token comes from the double-residual — never a head, and the
    kcap=0 row plain-samples the root distribution."""
    chains = jnp.asarray([[[5, 9], [4, 6]]], jnp.int32)
    p = jax.nn.softmax(_onehot_logits([[7, 1, 1, 1, 1]], scale=30.0))
    for seed in range(6):
        keys = jax.random.split(jax.random.PRNGKey(seed), 1)
        toks, a, cf = tree_reject_sample(keys, chains, p)
        assert int(a[0]) == 0
        assert int(toks[0, 0]) not in (5, 4)
        toks0, a0, _ = tree_reject_sample(keys, chains, p,
                                          kcap=jnp.asarray([0], jnp.int32))
        assert int(a0[0]) == 0 and int(toks0[0, 0]) == 7


def test_typical_accept_band():
    """The entropy band: an on-mass draft under a peaked target clears
    ``min(eps, delta*exp(-H))`` and is accepted DETERMINISTICALLY (no
    coin flip — this is where typical beats exact on acceptance); an
    off-mass draft falls below the band, the prefix stops, and the next
    token is sampled from the target's own distribution at the cut."""
    keys = jax.random.split(jax.random.PRNGKey(0), 1)
    p = jax.nn.softmax(_onehot_logits([[4, 9, 3]], scale=30.0))
    toks, a = typical_accept_sample(keys, jnp.asarray([[4, 9]], jnp.int32), p)
    assert int(a[0]) == 2 and np.asarray(toks[0])[:2].tolist() == [4, 9]
    toks0, a0 = typical_accept_sample(keys, jnp.asarray([[7, 7]], jnp.int32),
                                      p)
    assert int(a0[0]) == 0 and int(toks0[0, 0]) == 4  # p0's argmax mass
    # kcap=0 still plain-samples from p0 regardless of the band.
    tc, ac = typical_accept_sample(keys, jnp.asarray([[4, 9]], jnp.int32), p,
                                   kcap=jnp.asarray([0], jnp.int32))
    assert int(ac[0]) == 0 and int(tc[0, 0]) == 4


# ------------------------------------------------------------------ parity --
@pytest.mark.parametrize("arch", PAGED_BITEXACT_ARCHS)
def test_tree_fixed_engine_greedy_parity(arch):
    """Fixed engine, every family: fan-2 depth-2 tree greedy == plain
    greedy (tree masking + relocation leave the emitted argmaxes
    untouched)."""
    cfg, params, prompt, extras = setup_family(arch)
    eng = ServingEngine(cfg, params, max_seq=16)
    want = np.asarray(eng.generate(prompt, n_new=5, extras=extras))
    got = np.asarray(eng.generate(prompt, n_new=5, extras=extras,
                                  speculate=TREE))
    assert_tokens_identical(want, got, msg=arch)
    assert eng.spec_stats["tree_fan"] == 2


@pytest.mark.parametrize("arch", PAGED_BITEXACT_ARCHS)
def test_tree_continuous_engine_greedy_parity(arch):
    """Continuous engine, every family: paged tree verify + column
    relocation == the plain paged scheduler, token-for-token."""
    cfg, params, prompt, extras = setup_family(arch)
    kw = dict(slots=2, max_seq=16, page_size=4, chunk=3)
    reqs = batch_requests(prompt, 5, extras)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    eng = ContinuousBatchingEngine(cfg, params, speculate=TREE, **kw)
    eng.debug_check_hist = True
    got = eng.serve(reqs)
    for i, (w, g) in enumerate(zip(want, got)):
        assert_tokens_identical(w, g, msg=f"{arch} req {i}")


def test_tree_fan1_degenerates_to_linear_greedy():
    """fan=1 tree == linear k=depth speculation under greedy, both
    engines (chain 0 is the linear proposer and greedy acceptance takes
    the same longest prefix)."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    fan1 = SpecConfig(k=3, tree_fan=1)
    lin = SpecConfig(k=3)
    eng = ServingEngine(cfg, params, max_seq=32)
    a = np.asarray(eng.generate(prompt, n_new=16, extras=extras,
                                speculate=lin))
    b = np.asarray(eng.generate(prompt, n_new=16, extras=extras,
                                speculate=fan1))
    assert_tokens_identical(a, b, msg="fixed fan1 vs linear")
    kw = dict(slots=2, max_seq=32, page_size=4, chunk=3)
    reqs = batch_requests(prompt, 16, extras)
    ca = ContinuousBatchingEngine(cfg, params, speculate=lin, **kw).serve(reqs)
    cb = ContinuousBatchingEngine(cfg, params, speculate=fan1, **kw).serve(reqs)
    for i, (x, y) in enumerate(zip(ca, cb)):
        assert_tokens_identical(x, y, msg=f"continuous fan1 req {i}")


def test_tree_long_horizon_paged_relocation_parity():
    """24 tokens on the paged engine with page_size=4: accepted chains
    repeatedly cross page boundaries, so every relocation path (gather
    from tree columns, scatter into canonical pages, trash-page no-op at
    a=0) runs many times — output must still equal plain decode."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    kw = dict(slots=2, max_seq=40, page_size=4, chunk=3)
    reqs = batch_requests(prompt, 24, extras)
    want = ContinuousBatchingEngine(cfg, params, **kw).serve(reqs)
    eng = ContinuousBatchingEngine(cfg, params, speculate=TREE, **kw)
    eng.debug_check_hist = True
    got = eng.serve(reqs)
    for i, (w, g) in enumerate(zip(want, got)):
        assert_tokens_identical(w, g, msg=f"req {i}")


# ------------------------------------------------------------------ sampled --
def test_tree_sampled_cross_engine_identity():
    """The tree schedule is static (window shape and draw shapes are
    compile-time constants), so sampled tree decoding is key-exact ACROSS
    engines — the stronger contract adaptive explicitly does not claim."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    assert_sampled_parity(cfg, params, prompt, extras, speculate=TREE,
                          msg="tree")


def test_tree_sampled_deterministic_and_key_sensitive():
    cfg, params, prompt, extras = setup_family("qwen2-1.5b")
    eng = ServingEngine(cfg, params, max_seq=24)
    kw = dict(extras=extras, greedy=False, temperature=0.8, top_k=8,
              speculate=TREE)
    a = np.asarray(eng.generate(prompt, n_new=12, key=jax.random.PRNGKey(1),
                                **kw))
    b = np.asarray(eng.generate(prompt, n_new=12, key=jax.random.PRNGKey(1),
                                **kw))
    c = np.asarray(eng.generate(prompt, n_new=12, key=jax.random.PRNGKey(2),
                                **kw))
    assert_tokens_identical(a, b, msg="tree sampled determinism")
    assert not np.array_equal(a, c), "different keys, identical trace"


def test_tree_sampled_distribution_matches_plain():
    """Exactness of multi-draft rejection sampling end-to-end: tree
    sampled decode's output law == plain sampled decode's, chi-square
    over seeded decodes at the last emitted position."""
    cfg, params, prompt, extras = setup_family("qwen2-1.5b", b=1, s=6)
    batch = 250
    prompt = jnp.tile(prompt, (batch, 1))
    eng = ServingEngine(cfg, params, max_seq=16)

    def gen(spec):
        def f(key):
            return eng.generate(prompt, n_new=3, extras=extras, greedy=False,
                                temperature=1.0, top_k=0, key=key,
                                speculate=spec)
        return f

    plain = histogram_decode(gen(None), cfg.vocab, 750, base_seed=100)
    tree = histogram_decode(gen(TREE), cfg.vocab, 750, base_seed=900)
    assert_distributions_match(plain, tree, msg="tree vs plain sampled")


# ------------------------------------------------- 8-device mesh identity --
TREE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax
sys.path.insert(0, os.path.join(r"{repo}", "tests"))
from helpers import setup_family, build_engine, generate_tokens, batch_requests
from repro.serving import SpecConfig, make_decode_mesh

ARCHS = sys.argv[1].split(",")
mesh = make_decode_mesh(8)
spec = SpecConfig(k=2, tree_fan=2)
out = []
for arch in ARCHS:
    cfg, params, prompt, extras = setup_family(arch)
    row = {{"arch": arch}}
    plain = build_engine("fixed", cfg, params, max_seq=16, bits=8)
    shard = build_engine("fixed", cfg, params, max_seq=16, bits=8, mesh=mesh)
    want = generate_tokens(plain, prompt, 5, extras)
    got = generate_tokens(shard, prompt, 5, extras, speculate=spec)
    row["fixed_identical"] = bool(np.array_equal(want, got))
    pl = build_engine("continuous", cfg, params, max_seq=16, bits=8,
                      page_alloc_seed=7)
    sh = build_engine("continuous", cfg, params, max_seq=16, bits=8,
                      page_alloc_seed=7, mesh=mesh, speculate=spec)
    a = pl.serve(batch_requests(prompt, 5, extras))
    b = sh.serve(batch_requests(prompt, 5, extras))
    row["paged_identical"] = bool(all(np.array_equal(x, y)
                                      for x, y in zip(a, b)))
    out.append(row)
print("RESULT " + json.dumps(out))
""".format(repo=REPO)


def test_tree_sharded_greedy_identity_all_families():
    """Acceptance: fan-2 tree speculation on a forced 8-virtual-device
    mesh == plain single-device greedy, both engines, all families (the
    tree window batches through the same sharded verify path)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", TREE_SNIPPET,
         ",".join(PAGED_BITEXACT_ARCHS)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    import json
    for row in json.loads(line[len("RESULT "):]):
        assert row["fixed_identical"], row
        assert row["paged_identical"], row
