"""Shared parity-test harness.

Every serving-side test suite asks the same two questions per model family:
"build me a reduced model with its per-family extras" and "do engine A and
engine B emit the same tokens?".  Those loops used to be duplicated across
test_serving / test_paged_serving / test_sharded_decode; they live here once
so the family x engine x bits matrices (including the speculative-vs-greedy
one in test_speculative) all drive the same fixtures.

``FAMILY_ARCHS`` is THE canonical one-arch-per-family list (moe is covered
both with and without MLA, so "six families" tests iterate seven archs).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import encode, init_params
from repro.serving import ContinuousBatchingEngine, Request, ServingEngine

# One arch per family (moe is covered both with and without MLA).
FAMILY_ARCHS = [
    "qwen2-1.5b",            # dense
    "deepseek-v2-lite-16b",  # moe + MLA (paged latent cache)
    "moonshot-v1-16b-a3b",   # moe, plain GQA
    "falcon-mamba-7b",       # ssm (per-slot dense state)
    "zamba2-1.2b",           # hybrid (paged shared-attn + dense ssm state)
    "llama-3.2-vision-90b",  # vlm
    "seamless-m4t-medium",   # encdec
]

ENGINE_KINDS = ("fixed", "continuous")


def setup_family(arch, b=2, s=8, key=0, kv_bits=0):
    """Reduced config + init params + a random prompt + the family's extras
    (vlm image embeds / encdec encoder output).  The shared fixture behind
    every per-family engine-parity loop."""
    cfg = get_reduced(arch)
    if kv_bits:
        cfg = cfg.replace(kv_cache_bits=kv_bits)
    params = init_params(cfg, jax.random.PRNGKey(key))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    extras = None
    if cfg.family == "vlm":
        extras = {"image_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.vision.n_image_tokens, cfg.d_model))}
    elif cfg.family == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.audio.n_frames, cfg.d_model))
        extras = {"enc_out": encode(params, cfg, frames)}
    return cfg, params, prompt, extras


def request_extras(extras, i):
    """Row ``i`` of batched extras as a per-request extras tree."""
    return None if extras is None else jax.tree.map(lambda a: a[i], extras)


def build_engine(kind, cfg, params, *, max_seq, bits=0, mesh=None,
                 speculate=None, slots=2, page_size=4, chunk=3,
                 page_alloc_seed=None, **kw):
    """One constructor for the parity matrices: ``kind`` is "fixed"
    (ServingEngine) or "continuous" (ContinuousBatchingEngine on the paged
    cache).  Speculation on the fixed engine is a generate-time argument, so
    it is threaded through ``generate_tokens`` instead."""
    if kind == "fixed":
        return ServingEngine(cfg, params, max_seq=max_seq, pim_bits=bits,
                             mesh=mesh, **kw)
    if kind == "continuous":
        return ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            chunk=chunk, pim_bits=bits, mesh=mesh, speculate=speculate,
            page_alloc_seed=page_alloc_seed, **kw)
    raise ValueError(kind)


def generate_tokens(engine, prompt, n_new, extras=None, speculate=None,
                    **kw) -> np.ndarray:
    """Greedy batch generation on either engine kind, as a host array."""
    if isinstance(engine, ServingEngine):
        return np.asarray(engine.generate(prompt, n_new=n_new, extras=extras,
                                          speculate=speculate, **kw))
    assert speculate is None, "continuous engines speculate via constructor"
    return np.asarray(engine.generate(prompt, n_new=n_new, extras=extras,
                                      **kw))


def assert_tokens_identical(want, got, msg=""):
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got),
                                  err_msg=msg)


def batch_requests(prompt, n_new, extras=None, stop_tokens=()):
    """Split a (B, S) prompt batch into per-row Requests (row i of batched
    extras rides on request i)."""
    prompts = np.asarray(prompt, np.int32)
    return [
        Request(prompt=row, max_new=int(n_new), stop_tokens=tuple(stop_tokens),
                extras=request_extras(extras, i))
        for i, row in enumerate(prompts)
    ]


def assert_serve_matches_solo(engine, cfg, params, requests, max_seq=None):
    """Every request served by the scheduler must emit exactly the tokens of
    a solo run on the dense fixed-batch engine — the staggered-admit/retire
    parity loop shared by the paged and speculative suites."""
    outs = engine.serve(requests)
    dense = ServingEngine(cfg, params, max_seq=max_seq or engine.max_seq)
    for i, (r, got) in enumerate(zip(requests, outs)):
        ex = None
        if r.extras is not None:
            ex = jax.tree.map(lambda a: jnp.asarray(a)[None], r.extras)
        want = np.asarray(dense.generate(
            jnp.asarray(r.prompt)[None], r.max_new, extras=ex))[0]
        if r.stop_tokens:
            hits = np.flatnonzero(np.isin(want, list(r.stop_tokens)))
            if hits.size:
                want = want[: hits[0] + 1]
        assert_tokens_identical(want, got, msg=f"request {i}")
