"""Shared parity-test harness.

Every serving-side test suite asks the same two questions per model family:
"build me a reduced model with its per-family extras" and "do engine A and
engine B emit the same tokens?".  Those loops used to be duplicated across
test_serving / test_paged_serving / test_sharded_decode; they live here once
so the family x engine x bits matrices (including the speculative-vs-greedy
one in test_speculative) all drive the same fixtures.

``FAMILY_ARCHS`` is THE canonical one-arch-per-family list (moe is covered
both with and without MLA, so "six families" tests iterate seven archs).

The sampled-decoding additions serve tests/test_sampled_speculative.py's
two-layer methodology: ``assert_sampled_parity`` is the seeded-exactness
layer (the per-row fold_in key discipline makes the same key produce
identical temperature/top-k tokens on the dense fixed engine and the paged
continuous engine), and ``histogram_decode`` + ``chi_square_homogeneity`` /
``total_variation`` are the distributional layer (empirical token
frequencies over thousands of seeded decodes, compared with a pooled-bin
chi-square homogeneity test).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import encode, init_params
from repro.serving import (
    ContinuousBatchingEngine,
    FaultInjector,
    Request,
    ResiliencePolicy,
    ServingEngine,
)

# One arch per family (moe is covered both with and without MLA).
FAMILY_ARCHS = [
    "qwen2-1.5b",            # dense
    "deepseek-v2-lite-16b",  # moe + MLA (paged latent cache)
    "moonshot-v1-16b-a3b",   # moe, plain GQA
    "falcon-mamba-7b",       # ssm (per-slot dense state)
    "zamba2-1.2b",           # hybrid (paged shared-attn + dense ssm state)
    "llama-3.2-vision-90b",  # vlm
    "seamless-m4t-medium",   # encdec
]

ENGINE_KINDS = ("fixed", "continuous")

# Dense-cache and paged-cache logits are BIT-IDENTICAL for these archs
# (measured: ``verify_step`` and ``decode_step`` agree to the last bit
# across the two cache layouts), so cross-engine SAMPLED decode is
# key-exact for them.  This now includes the two moe archs, which took a
# two-part fix in models.moe.moe_apply: (1) dispatch groups never span
# rows, so a token's capacity drops depend on its own row alone and
# batched prefill vs batch-1 admit route identically (the old
# flatten-all-rows grouping let row 0 pre-fill row 1's expert buffers,
# ~1e-2 logit swings); (2) the expert combine reduces over the fixed
# top-k axis, so its reduction tree no longer depends on the dispatch
# capacity (the old joint (E*C) combine amplified contraction-order ulps
# into ~1e-3 logit shifts that could flip sampled draws near accept
# boundaries).
PAGED_BITEXACT_ARCHS = list(FAMILY_ARCHS)


def setup_family(arch, b=2, s=8, key=0, kv_bits=0):
    """Reduced config + init params + a random prompt + the family's extras
    (vlm image embeds / encdec encoder output).  The shared fixture behind
    every per-family engine-parity loop."""
    cfg = get_reduced(arch)
    if kv_bits:
        cfg = cfg.replace(kv_cache_bits=kv_bits)
    params = init_params(cfg, jax.random.PRNGKey(key))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    extras = None
    if cfg.family == "vlm":
        extras = {"image_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.vision.n_image_tokens, cfg.d_model))}
    elif cfg.family == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.audio.n_frames, cfg.d_model))
        extras = {"enc_out": encode(params, cfg, frames)}
    return cfg, params, prompt, extras


def request_extras(extras, i):
    """Row ``i`` of batched extras as a per-request extras tree."""
    return None if extras is None else jax.tree.map(lambda a: a[i], extras)


def build_engine(kind, cfg, params, *, max_seq, bits=0, mesh=None,
                 speculate=None, slots=2, page_size=4, chunk=3,
                 page_alloc_seed=None, **kw):
    """One constructor for the parity matrices: ``kind`` is "fixed"
    (ServingEngine) or "continuous" (ContinuousBatchingEngine on the paged
    cache).  Speculation on the fixed engine is a generate-time argument, so
    it is threaded through ``generate_tokens`` instead."""
    if kind == "fixed":
        return ServingEngine(cfg, params, max_seq=max_seq, pim_bits=bits,
                             mesh=mesh, **kw)
    if kind == "continuous":
        return ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            chunk=chunk, pim_bits=bits, mesh=mesh, speculate=speculate,
            page_alloc_seed=page_alloc_seed, **kw)
    raise ValueError(kind)


def generate_tokens(engine, prompt, n_new, extras=None, speculate=None,
                    **kw) -> np.ndarray:
    """Greedy batch generation on either engine kind, as a host array."""
    if isinstance(engine, ServingEngine):
        return np.asarray(engine.generate(prompt, n_new=n_new, extras=extras,
                                          speculate=speculate, **kw))
    assert speculate is None, "continuous engines speculate via constructor"
    return np.asarray(engine.generate(prompt, n_new=n_new, extras=extras,
                                      **kw))


def assert_tokens_identical(want, got, msg=""):
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got),
                                  err_msg=msg)


def batch_requests(prompt, n_new, extras=None, stop_tokens=()):
    """Split a (B, S) prompt batch into per-row Requests (row i of batched
    extras rides on request i)."""
    prompts = np.asarray(prompt, np.int32)
    return [
        Request(prompt=row, max_new=int(n_new), stop_tokens=tuple(stop_tokens),
                extras=request_extras(extras, i))
        for i, row in enumerate(prompts)
    ]


def assert_sampled_parity(cfg, params, prompt, extras=None, *, n_new=5,
                          max_seq=24, key=None, temperature=0.8, top_k=8,
                          speculate=None, bits=0, draft=False, msg="",
                          slots=2, page_size=4, chunk=3):
    """Seeded sampling parity: the SAME key must give IDENTICAL
    temperature/top-k tokens on the dense fixed engine and the paged
    continuous engine (plain or speculative) — the key-deterministic half
    of the sampled-speculation contract.  Returns the tokens so callers
    can chain further asserts."""
    if key is None:
        key = jax.random.PRNGKey(11)
    dkw = dict(draft_cfg=cfg, draft_params=params) if draft else {}
    fixed = ServingEngine(cfg, params, max_seq=max_seq, pim_bits=bits, **dkw)
    want = np.asarray(fixed.generate(
        prompt, n_new=n_new, extras=extras, greedy=False,
        temperature=temperature, top_k=top_k, key=key, speculate=speculate))
    cont = ContinuousBatchingEngine(
        cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
        chunk=chunk, pim_bits=bits, speculate=speculate, **dkw)
    got = np.asarray(cont.generate(
        prompt, n_new=n_new, extras=extras, greedy=False,
        temperature=temperature, top_k=top_k, key=key))
    assert_tokens_identical(want, got, msg=f"dense vs paged sampled {msg}")
    return want


def histogram_decode(gen_fn, vocab: int, n_draws: int, *, position=-1,
                     base_seed: int = 1000) -> np.ndarray:
    """Empirical token frequencies at ``position`` over ``n_draws`` seeded
    decodes.  ``gen_fn(key) -> (B, n) tokens`` must derive per-row random
    streams from (key, row id) — the engines' fold_in key discipline — so
    every row of a replicated-prompt batch is an INDEPENDENT seeded decode;
    the helper feeds fresh base keys until ``n_draws`` rows accumulate."""
    counts = np.zeros(vocab, np.int64)
    got, i = 0, 0
    while got < n_draws:
        toks = np.asarray(gen_fn(jax.random.PRNGKey(base_seed + i)))
        take = min(toks.shape[0], n_draws - got)
        counts += np.bincount(toks[:take, position], minlength=vocab)
        got += take
        i += 1
    return counts


def chi_square_homogeneity(c1, c2, pool_below: float = 10.0):
    """Two-sample chi-square homogeneity test on token histograms.

    Bins whose POOLED count falls below ``pool_below`` are merged into one
    tail bin (the classic >=5-expected-per-cell validity rule for two
    same-sized samples).  Returns ``(stat, df, pvalue)``; the p-value uses
    ``scipy.stats.chi2`` when available and the Wilson-Hilferty cube-root
    normal approximation otherwise (accurate to ~1e-3 for df >= 10 — far
    tighter than the alpha=0.01 decisions made on it)."""
    c1 = np.asarray(c1, np.float64)
    c2 = np.asarray(c2, np.float64)
    assert c1.shape == c2.shape and c1.sum() > 0 and c2.sum() > 0
    tot = c1 + c2
    keep = tot >= pool_below
    b1 = np.concatenate([c1[keep], [c1[~keep].sum()]])
    b2 = np.concatenate([c2[keep], [c2[~keep].sum()]])
    if b1[-1] + b2[-1] == 0:
        b1, b2 = b1[:-1], b2[:-1]
    n1, n2 = b1.sum(), b2.sum()
    pooled = (b1 + b2) / (n1 + n2)
    e1, e2 = n1 * pooled, n2 * pooled
    stat = float(np.sum((b1 - e1) ** 2 / e1) + np.sum((b2 - e2) ** 2 / e2))
    df = int(len(b1) - 1)
    try:
        from scipy.stats import chi2

        p = float(chi2.sf(stat, df))
    except ImportError:  # pragma: no cover - scipy ships with jax
        z = (((stat / df) ** (1.0 / 3.0) - (1.0 - 2.0 / (9.0 * df)))
             / math.sqrt(2.0 / (9.0 * df)))
        p = 0.5 * math.erfc(z / math.sqrt(2.0))
    return stat, df, p


def total_variation(c1, c2) -> float:
    """TV distance between the empirical distributions of two histograms."""
    c1 = np.asarray(c1, np.float64)
    c2 = np.asarray(c2, np.float64)
    return float(0.5 * np.abs(c1 / c1.sum() - c2 / c2.sum()).sum())


def assert_distributions_match(c1, c2, alpha: float = 0.01, msg: str = ""):
    """The distributional-equivalence assert: a chi-square homogeneity test
    must NOT reject at ``alpha`` (deterministic for fixed seeds — either
    the histograms are draws from one distribution and p is comfortably
    large, or the sampler is wrong and p collapses to ~0).  The TV distance
    rides along in the failure message as the effect-size report."""
    stat, df, p = chi_square_homogeneity(c1, c2)
    assert p >= alpha, (
        f"{msg}: histograms differ (chi2={stat:.1f}, df={df}, p={p:.3g}, "
        f"tv={total_variation(c1, c2):.4f}, n1={int(np.sum(c1))}, "
        f"n2={int(np.sum(c2))})")


def assert_chaos_parity(cfg, params, requests, chaos_cfg, *, policy=None,
                        key=None, greedy=True, temperature=1.0, top_k=0,
                        engine_kw=None, msg=""):
    """The PR-6 robustness bar: serve a trace fault-free, then again under
    a seeded ``ChaosConfig`` on a fresh identical engine — every request
    the chaos run finished (not shed/rejected) must be TOKEN-IDENTICAL to
    the undisturbed run.  Returns ``(baseline_outputs, chaos_report)`` so
    callers can additionally assert on the injected-fault counters."""
    if key is None:
        key = jax.random.PRNGKey(11)
    engine_kw = {**dict(slots=2, max_seq=24, page_size=4, chunk=3),
                 **(engine_kw or {})}
    base_eng = ContinuousBatchingEngine(cfg, params, **engine_kw)
    base = base_eng.serve(requests, greedy=greedy, temperature=temperature,
                          top_k=top_k, key=key)
    eng = ContinuousBatchingEngine(cfg, params, **engine_kw)
    inj = FaultInjector(chaos_cfg)
    report = eng.serve_detailed(
        requests, greedy=greedy, temperature=temperature, top_k=top_k,
        key=key, policy=policy or ResiliencePolicy(), chaos=inj)
    for i, (want, rec) in enumerate(zip(base, report.records)):
        if rec.status != "done":
            continue
        assert_tokens_identical(
            want, rec.tokens,
            msg=f"{msg} request {i} diverged under chaos "
                f"(injected: {inj.counts})")
    eng.assert_quiescent()
    return base, report


def assert_serve_matches_solo(engine, cfg, params, requests, max_seq=None):
    """Every request served by the scheduler must emit exactly the tokens of
    a solo run on the dense fixed-batch engine — the staggered-admit/retire
    parity loop shared by the paged and speculative suites."""
    outs = engine.serve(requests)
    dense = ServingEngine(cfg, params, max_seq=max_seq or engine.max_seq)
    for i, (r, got) in enumerate(zip(requests, outs)):
        ex = None
        if r.extras is not None:
            ex = jax.tree.map(lambda a: jnp.asarray(a)[None], r.extras)
        want = np.asarray(dense.generate(
            jnp.asarray(r.prompt)[None], r.max_new, extras=ex))[0]
        if r.stop_tokens:
            hits = np.flatnonzero(np.isin(want, list(r.stop_tokens)))
            if hits.size:
                want = want[: hits[0] + 1]
        assert_tokens_identical(want, got, msg=f"request {i}")
