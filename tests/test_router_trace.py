"""Replica routing + chrome-trace telemetry + bench schema validation.

``ReplicaRouter`` must serve every request TOKEN-IDENTICALLY to a solo
engine (greedy and sampled — the rid-pinning contract), concentrate
shared system prompts onto one replica (prefix affinity) while spreading
load, and the exported chrome-trace JSON must be deterministic,
Perfetto-structurally valid, and round-trippable.  The last tests pin the
``BENCH_*.json`` schema contract the CI validator enforces.
"""
import importlib.util
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from helpers import (
    assert_tokens_identical,
    build_engine,
    setup_family,
)
from repro.serving import ReplicaRouter, Request, ResiliencePolicy, VirtualClock

ROOT = Path(__file__).resolve().parents[1]


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


trace_export = _load_tool("trace_export")
validate_bench = _load_tool("validate_bench")

PS = 4


def _fleet_requests(prompt, vocab, n_per_group=3, n_new=5):
    """Two system-prompt groups: each group shares its row's first page
    (and beyond) with per-request perturbed tails — the trace shape that
    makes prefix affinity matter."""
    rows = np.asarray(prompt, np.int32)
    reqs = []
    for g in range(2):
        for j in range(n_per_group):
            tail = rows[g].copy()
            if j:
                tail[-2:] = (tail[-2:] + j) % vocab
            reqs.append(Request(prompt=tail, max_new=n_new))
    return reqs


def _mk(cfg, params, **kw):
    base = dict(max_seq=24, page_size=PS, chunk=3, num_pages=20,
                prefix_cache=True)
    base.update(kw)
    return build_engine("continuous", cfg, params, **base)


def test_router_token_identical_to_solo_greedy_and_sampled():
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = _fleet_requests(prompt, cfg.vocab)
    key = jax.random.PRNGKey(3)
    for skw in (dict(), dict(greedy=False, temperature=0.8, top_k=8,
                             key=key)):
        want = _mk(cfg, params).serve(reqs, **skw)
        router = ReplicaRouter([_mk(cfg, params) for _ in range(2)])
        rep = router.serve_detailed(reqs, **skw)
        for i in range(len(reqs)):
            assert rep.records[i].status == "done"
            assert_tokens_identical(
                want[i], rep.records[i].tokens,
                msg=f"req {i} diverged routed ({'sampled' if skw else 'greedy'})")


def test_router_prefix_affinity_concentrates_and_spreads():
    """Both system-prompt groups land wholly on one replica each (affinity),
    the two groups land on DIFFERENT replicas (load tiebreak), and every
    non-first group member is an affinity hit."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = _fleet_requests(prompt, cfg.vocab)
    router = ReplicaRouter([_mk(cfg, params) for _ in range(2)])
    assign = router.route(reqs)
    g0, g1 = set(assign[:3]), set(assign[3:])
    assert len(g0) == 1 and len(g1) == 1, \
        f"groups must concentrate on one replica each, got {assign}"
    assert g0 != g1, f"least-load tiebreak must spread groups, got {assign}"
    rep = router.serve_detailed(reqs)
    assert rep.assignments == assign
    assert rep.affinity_hits == 4  # requests 1,2 and 4,5
    assert rep.prefix_hits >= 4    # the replicas' REAL tries hit too
    assert len(rep.done()) == len(reqs)


def test_trace_export_deterministic_and_perfetto_valid(tmp_path):
    """Same trace + policy + VirtualClock => byte-identical exported JSON,
    passing the structural validator, for both solo and router reports."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = _fleet_requests(prompt, cfg.vocab)
    pol = ResiliencePolicy(round_time=0.5)

    def solo_trace():
        eng = _mk(cfg, params, clock=VirtualClock())
        return trace_export.report_to_trace(
            eng.serve_detailed(reqs, policy=pol))

    t1, t2 = solo_trace(), solo_trace()
    s1 = json.dumps(trace_export._jsonable(t1), sort_keys=True)
    s2 = json.dumps(trace_export._jsonable(t2), sort_keys=True)
    assert s1 == s2, "trace export must be deterministic under VirtualClock"
    n = trace_export.validate_trace(json.loads(s1))
    assert n > len(reqs)  # at least admit+finish per request plus metas
    names = {e["name"].split()[0] for e in t1["traceEvents"]}
    assert {"admit", "decode", "finish", "free_pages"} <= names

    router = ReplicaRouter(
        [_mk(cfg, params, clock=VirtualClock()) for _ in range(2)])
    rrep = router.serve_detailed(reqs, policy=pol)
    rtrace = trace_export.router_report_to_trace(rrep)
    path = tmp_path / "router.trace.json"
    n = trace_export.write_trace(rtrace, str(path))
    assert n == len(rtrace["traceEvents"])
    reloaded = json.loads(path.read_text())
    assert trace_export.validate_trace(reloaded) == n
    assert {e["pid"] for e in reloaded["traceEvents"]} == {0, 1}
    assert reloaded["otherData"]["assignments"] == rrep.assignments


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        trace_export.validate_trace({"events": []})
    with pytest.raises(ValueError, match="phase"):
        trace_export.validate_trace(
            {"traceEvents": [{"name": "x", "ph": "Z", "pid": 0, "ts": 0}]})
    with pytest.raises(ValueError, match="dur"):
        trace_export.validate_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "pid": 0, "ts": 0}]})
    with pytest.raises(ValueError, match="counter"):
        trace_export.validate_trace(
            {"traceEvents": [{"name": "c", "ph": "C", "pid": 0, "ts": 0,
                              "args": {"v": "high"}}]})


def test_committed_bench_artifacts_match_schema():
    """The repo's committed BENCH_*.json must satisfy the CI validator —
    a bench refactor that renames/drops a field fails here, not in a
    downstream consumer PR."""
    for name in ("BENCH_serving.json", "BENCH_decode.json"):
        path = ROOT / name
        if not path.exists():
            pytest.skip(f"{name} not committed")
        errors = validate_bench.validate_bench(json.loads(path.read_text()))
        assert not errors, f"{name}: {errors}"


def test_validate_bench_catches_drift():
    obj = json.loads((ROOT / "BENCH_serving.json").read_text())
    ok = validate_bench.validate_bench(obj)
    assert not ok
    del obj["continuous"]
    obj["page_size"] = "four"
    errors = validate_bench.validate_bench(obj)
    assert any("continuous" in e for e in errors)
    assert any("page_size" in e for e in errors)
