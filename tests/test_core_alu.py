"""Bit-level machine: FA/S ALU, Booth multiplier, OpMux folds, network."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    OpCode,
    booth_decode,
    booth_multiply,
    booth_nop_fraction,
    fold_operand,
    fold_reduce_block,
    fold_source_index,
    from_bits,
    network_reduce_bits,
    node_roles,
    serial_alu,
    sign_extend_bits,
    to_bits,
)


def _rng(seed=0):
    return np.random.default_rng(seed)


def _rand_ints(rng, n, width):
    lo, hi = -(1 << (width - 1)), 1 << (width - 1)
    return rng.integers(lo, hi, size=n, dtype=np.int64)


# ------------------------------------------------------------------ bitops --
@given(st.integers(-(2**15), 2**15 - 1), st.integers(2, 8))
def test_bits_roundtrip(v, extra):
    width = 16
    bits = to_bits(jnp.array([v]), width)
    assert int(from_bits(bits)[0]) == v
    ext = sign_extend_bits(bits, width + extra)
    assert int(from_bits(ext)[0]) == v


# --------------------------------------------------------------------- ALU --
@pytest.mark.parametrize("width", [4, 8, 16])
@pytest.mark.parametrize("op", [OpCode.ADD, OpCode.SUB, OpCode.CPX, OpCode.CPY])
def test_serial_alu_ops(width, op):
    rng = _rng(width * 10 + int(op))
    x = _rand_ints(rng, 64, width)
    y = _rand_ints(rng, 64, width)
    xb, yb = to_bits(jnp.asarray(x), width), to_bits(jnp.asarray(y), width)
    ops = jnp.full((64,), int(op), dtype=jnp.int32)
    s, _ = serial_alu(xb, yb, ops)
    got = np.asarray(from_bits(s))
    mod = 1 << width
    if op == OpCode.ADD:
        want = (x + y) % mod
    elif op == OpCode.SUB:
        want = (x - y) % mod
    elif op == OpCode.CPX:
        want = x % mod
    else:
        want = y % mod
    np.testing.assert_array_equal(got % mod, want % mod)


def test_serial_alu_mixed_lane_opcodes():
    """Per-lane op-codes (as Booth's encoder issues them) work in one pass."""
    width = 8
    x = jnp.array([10, 10, 10, 10])
    y = jnp.array([3, 3, 3, 3])
    ops = jnp.array([OpCode.ADD, OpCode.SUB, OpCode.CPX, OpCode.CPY], dtype=jnp.int32)
    s, _ = serial_alu(to_bits(x, width), to_bits(y, width), ops)
    np.testing.assert_array_equal(np.asarray(from_bits(s)), [13, 7, 10, 3])


# ------------------------------------------------------------------- Booth --
def test_booth_decode_table2():
    pairs = jnp.array([0b00, 0b01, 0b10, 0b11])
    got = [int(v) for v in booth_decode(pairs)]
    assert got == [OpCode.CPX, OpCode.ADD, OpCode.SUB, OpCode.CPX]


@pytest.mark.parametrize("width", [4, 6, 8, 12, 16])
def test_booth_multiply_matches_integer_product(width):
    rng = _rng(width)
    x = _rand_ints(rng, 128, width)
    y = _rand_ints(rng, 128, width)
    got = np.asarray(booth_multiply(jnp.asarray(x), jnp.asarray(y), width))
    np.testing.assert_array_equal(got, (x * y).astype(np.int64))


@settings(max_examples=30, deadline=None)
@given(
    st.integers(-128, 127),
    st.integers(-128, 127),
)
def test_booth_multiply_property(a, b):
    got = int(booth_multiply(jnp.array([a]), jnp.array([b]), 8)[0])
    assert got == a * b


def test_booth_nop_fraction_near_half():
    """§V-B: on average ~half the Booth steps are NOPs."""
    rng = _rng(7)
    y = jnp.asarray(_rand_ints(rng, 4096, 8))
    frac = float(booth_nop_fraction(y, 8))
    assert 0.40 < frac < 0.60


# ------------------------------------------------------------------- OpMux --
def test_fold_source_index_16_pattern_a():
    """A-FOLD-1..4 for a 16-PE block (Table III: H2, Q2, HQ2, HHQ2)."""
    assert list(fold_source_index(16, 1)[:8]) == list(range(8, 16))
    assert list(fold_source_index(16, 2)[:4]) == list(range(4, 8))
    assert list(fold_source_index(16, 3)[:2]) == [2, 3]
    assert list(fold_source_index(16, 4)[:1]) == [1]
    assert all(s == -1 for s in fold_source_index(16, 4)[1:])


def test_fold_pattern_b_adjacent():
    """Fig 2(b): after fold-1, PE 2i holds PE 2i + PE 2i+1."""
    src = fold_source_index(8, 1, pattern="b")
    assert list(src[::2]) == [1, 3, 5, 7]


@pytest.mark.parametrize("block", [8, 16, 32])
@pytest.mark.parametrize("pattern", ["a", "b"])
def test_fold_reduce_sums_block(block, pattern):
    rng = _rng(block)
    width = 16  # headroom included
    vals = rng.integers(-200, 200, size=block)
    bits = to_bits(jnp.asarray(vals), width)
    out = fold_reduce_block(bits, pattern=pattern)
    assert int(from_bits(out)[0]) == int(vals.sum())


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=16, max_size=16))
def test_fold_reduce_property(vals):
    bits = to_bits(jnp.asarray(vals), 16)
    out = fold_reduce_block(bits)
    assert int(from_bits(out)[0]) == sum(vals)


def test_fold_operand_zero_fill():
    bits = to_bits(jnp.arange(16), 8)
    y = fold_operand(bits, 1)
    # lanes 8..15 must read 0 (Table III: Y = {0, A[H2]})
    np.testing.assert_array_equal(np.asarray(from_bits(y[8:])), np.zeros(8))
    np.testing.assert_array_equal(np.asarray(from_bits(y[:8])), np.arange(8, 16))


# ----------------------------------------------------------------- network --
def test_node_roles_level0_fig3():
    roles = node_roles(8, 0)
    assert roles[0] == "R" and roles[1] == "T"
    assert roles[2] == "R" and roles[3] == "T"


def test_node_roles_level1_passthrough():
    roles = node_roles(8, 1)
    assert roles[0] == "R" and roles[2] == "T" and roles[1] == "P"


@pytest.mark.parametrize("n_blocks", [2, 4, 8, 16])
def test_network_reduce_sums_blocks(n_blocks):
    rng = _rng(n_blocks)
    width = 20
    vals = rng.integers(-1000, 1000, size=n_blocks)
    out = network_reduce_bits(to_bits(jnp.asarray(vals), width))
    assert int(from_bits(out)[0]) == int(vals.sum())
