"""Shared-prefix KV page cache: correctness and lifecycle adversarial suite.

Layer one is the bit-exactness bar: serving with ``prefix_cache=True`` must
be TOKEN-IDENTICAL to uncached serving for every family, greedy and
sampled — eligible families (dense / vlm / encdec, non-MLA, non-draft)
with real cache hits, ineligible families trivially (the cache gates
itself off).  Layer two attacks the allocator lifecycle: copy-on-write
fork isolation with a live sibling, refcount quiescence through
preemption / eviction / crash replay, pool poisoning on abnormal serve
exit, the strict pending sweep, and per-slot completion granularity.
"""
import jax
import numpy as np
import pytest

from helpers import (
    FAMILY_ARCHS,
    assert_chaos_parity,
    assert_tokens_identical,
    batch_requests,
    build_engine,
    request_extras,
    setup_family,
)
from repro.serving import (
    ChaosConfig,
    EngineCrash,
    FaultInjector,
    Request,
    ResiliencePolicy,
    ServingSupervisor,
    VirtualClock,
)

PS = 4  # page size used throughout: prompts of 8 tokens = 2 full pages


def _shared_prefix_requests(prompt, extras, n_new=6, vocab=101):
    """Two requests per prompt row: the row itself plus a variant sharing
    its first full page (tokens [0, PS)) but with a perturbed tail — so an
    eligible cache serves the variant's first page from the trie."""
    reqs = []
    prompt = np.asarray(prompt, np.int32)
    for i, row in enumerate(prompt):
        ex = request_extras(extras, i)
        reqs.append(Request(prompt=row.copy(), max_new=n_new, extras=ex))
        tail = row.copy()
        tail[-2:] = (tail[-2:] + 1 + i) % vocab
        reqs.append(Request(prompt=tail, max_new=n_new, extras=ex))
    return reqs


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prefix_cache_token_identity_all_families(arch):
    """The hard bar: cached == uncached tokens, greedy AND sampled, with
    hits > 0 where the family is eligible and hits == 0 where the cache
    must gate itself off (moe window ragging, ssm dense state, MLA latent
    pages)."""
    cfg, params, prompt, extras = setup_family(arch)
    reqs = _shared_prefix_requests(prompt, extras, vocab=cfg.vocab)
    kw = dict(max_seq=24, page_size=PS, chunk=3, num_pages=20)
    key = jax.random.PRNGKey(5)
    skw = dict(greedy=False, temperature=0.8, top_k=8, key=key)

    base = build_engine("continuous", cfg, params, **kw)
    want_g = base.serve(reqs)
    want_s = base.serve(reqs, **skw)

    eng = build_engine("continuous", cfg, params, prefix_cache=True, **kw)
    got_g = eng.serve(reqs)
    hits_g = eng.prefix_hits
    got_s = eng.serve(reqs, **skw)
    hits_s = eng.prefix_hits

    for i in range(len(reqs)):
        assert_tokens_identical(want_g[i], got_g[i],
                                msg=f"{arch} greedy req {i} diverged cached")
        assert_tokens_identical(want_s[i], got_s[i],
                                msg=f"{arch} sampled req {i} diverged cached")
    eligible = (cfg.family in ("dense", "vlm", "encdec")
                and not getattr(cfg, "mla", None))
    if eligible:
        assert hits_g > 0 and hits_s > 0, \
            f"{arch} eligible but served no prefix hits"
        assert eng.prefix_hit_tokens > 0
        assert eng.prefill_tokens < sum(len(r.prompt) for r in reqs)
    else:
        assert hits_g == 0 and hits_s == 0, \
            f"{arch} ineligible family must not alias pages"
    eng.assert_quiescent()


def test_cow_fork_isolation_with_live_sibling():
    """Two requests with an IDENTICAL fully-page-aligned prompt: the second
    admit aliases every prompt page and must copy-on-write fork the last
    one before decoding into it.  Sampled decode gives the two requests
    different continuations (per-rid draw keys), so a missing fork would
    cross-corrupt the sibling's KV — both must match uncached serving."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    row = np.asarray(prompt, np.int32)[0]
    assert len(row) % PS == 0  # full pages: forces the CoW branch
    reqs = [Request(prompt=row.copy(), max_new=6) for _ in range(2)]
    kw = dict(max_seq=24, page_size=PS, chunk=3, num_pages=20)
    skw = dict(greedy=False, temperature=0.8, top_k=8,
               key=jax.random.PRNGKey(7))

    want = build_engine("continuous", cfg, params, **kw).serve(reqs, **skw)
    eng = build_engine("continuous", cfg, params, prefix_cache=True, **kw)
    got = eng.serve(reqs, **skw)

    assert eng.prefix_hits >= 1
    assert eng.cow_forks >= 1, "full-prefix hit must fork the write page"
    for i in range(2):
        assert_tokens_identical(want[i], got[i], msg=f"req {i}")
    # Sanity that isolation was actually load-bearing: the rid-keyed
    # streams diverge, so the two slots wrote different tokens into what
    # started as the same page.
    assert not np.array_equal(got[0], got[1])
    eng.assert_quiescent()


def test_refcount_quiescent_under_preemption_and_eviction():
    """A pool tight enough to force recompute preemption AND LRU eviction
    of retained cache pages: after the trace drains, every page must be
    refcount-0 and on exactly one of free/LRU (assert_quiescent), and the
    outputs still match an uncached roomy-pool engine."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = _shared_prefix_requests(prompt, None, n_new=8, vocab=cfg.vocab)
    want = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                        chunk=3, num_pages=20).serve(reqs)
    eng = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                       chunk=3, num_pages=9, prefix_cache=True)
    got = eng.serve(reqs)
    for i in range(len(reqs)):
        assert_tokens_identical(want[i], got[i], msg=f"req {i}")
    assert eng.preemptions > 0 or eng._pool.evictions > 0, \
        "pool was not actually tight — test exercises nothing"
    eng.assert_quiescent()
    pool = eng._pool
    assert len(pool.free) + len(pool.lru) == eng.num_pages - 1
    assert set(pool.lru) <= pool.cached


def test_prefix_cache_crash_replay_token_identical_and_quiescent():
    """Supervisor crash replay on a cached engine: the replacement trace
    rebuilds pool + trie from scratch (device pages died with the crash),
    replays token-identically, and leaves a quiescent pool."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = _shared_prefix_requests(prompt, None, vocab=cfg.vocab)
    kw = dict(max_seq=24, page_size=PS, chunk=3, num_pages=20)
    want = build_engine("continuous", cfg, params, **kw).serve(reqs)
    eng = build_engine("continuous", cfg, params, prefix_cache=True, **kw)
    sup = ServingSupervisor(
        eng, policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(crash_rounds=(1,))))
    report = sup.run(reqs)
    assert report.restarts == 1
    for i, rec in enumerate(report.records):
        assert rec.status == "done"
        assert_tokens_identical(want[i], rec.tokens, msg=f"req {i}")
    eng.assert_quiescent()


def test_eviction_under_squeeze_chaos_parity():
    """PR 6 integration: scripted page squeezes on a tight cached pool —
    retained cache pages are opportunistic capacity and must yield without
    perturbing any finished request's tokens."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = _shared_prefix_requests(prompt, None, n_new=8, vocab=cfg.vocab)
    _, report = assert_chaos_parity(
        cfg, params, reqs,
        ChaosConfig(squeeze_rounds=(1, 2), squeeze_frac=0.5),
        engine_kw=dict(prefix_cache=True, num_pages=12, max_seq=24,
                       page_size=PS, chunk=3),
        msg="prefix cache under squeeze")
    assert report.squeezed_pages > 0


def test_abnormal_exit_poisons_pool_until_next_serve():
    """serve_detailed exception safety: an escaped EngineCrash (no
    supervisor) leaves allocator state mid-flight — assert_quiescent must
    refuse to certify it until the next serve's _reset rebuilds the pool."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = batch_requests(prompt, 6)
    eng = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                       chunk=3, num_pages=20, prefix_cache=True)
    with pytest.raises(EngineCrash):
        eng.serve_detailed(reqs, policy=ResiliencePolicy(),
                           chaos=FaultInjector(ChaosConfig(crash_rounds=(1,))))
    with pytest.raises(AssertionError, match="poisoned"):
        eng.assert_quiescent()
    # A fresh serve on the SAME engine recovers: _reset clears the poison.
    eng.serve(reqs)
    eng.assert_quiescent()


def test_strict_sweep_raises_on_dropped_request(monkeypatch):
    """A scheduler that silently loses a request (simulated via the
    _debug_drop_rids hook) must raise in strict mode — the old
    unconditional pending->done coercion hid exactly this bug class."""
    monkeypatch.setenv("REPRO_STRICT_SERVE", "1")
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = batch_requests(prompt, 4)
    eng = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                       chunk=3, num_pages=20)
    eng._debug_drop_rids = {1}
    with pytest.raises(RuntimeError, match="scheduler dropped requests"):
        eng.serve_detailed(reqs, policy=ResiliencePolicy())


def test_hardened_sweep_coerces_only_when_opted_in():
    """Hardened serving may opt back into coercion (strict_pending=False):
    the lost request surfaces as an auditable "coerced-pending" done
    record.  Without a policy (non-hardened) the raise is unconditional —
    coercion is a production-degradation choice, never a default."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    reqs = batch_requests(prompt, 4)
    eng = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                       chunk=3, num_pages=20)
    eng.strict_pending = False
    eng._debug_drop_rids = {1}
    report = eng.serve_detailed(reqs, policy=ResiliencePolicy())
    assert report.records[1].status == "done"
    assert report.records[1].reason == "coerced-pending"
    assert report.records[0].status == "done"
    assert report.records[0].reason == ""

    eng2 = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                        chunk=3, num_pages=20)
    eng2.strict_pending = False
    eng2._debug_drop_rids = {1}
    with pytest.raises(RuntimeError, match="scheduler dropped requests"):
        eng2.serve_detailed(reqs)


def test_finish_granularity_within_one_round():
    """Per-slot completion at chunk granularity: two requests that finish
    in DIFFERENT chunk iterations of the same scheduling round get
    different t_done stamps (round boundary interpolated to the finishing
    iteration), instead of the old shared round-end timestamp."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    rows = np.asarray(prompt, np.int32)
    reqs = [Request(prompt=rows[0], max_new=2),
            Request(prompt=rows[1], max_new=5)]
    eng = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                       chunk=6, num_pages=20, clock=VirtualClock())
    report = eng.serve_detailed(reqs, policy=ResiliencePolicy(round_time=1.0))
    recs = report.records
    assert all(r.status == "done" for r in recs)
    # Both admit in round 0 (prefill emits token 1) and finish inside the
    # same chunk=6 decode round — at iterations 0 and 3 respectively.
    assert recs[0].t_done < recs[1].t_done, \
        "slots finishing at different chunk iterations must not share t_done"
    for rec in recs:
        names = [e["name"] for e in rec.events]
        assert names[0] == "admit" and names[-1] == "finish"
        ts = [e["ts"] for e in rec.events]
        assert ts == sorted(ts)


def test_prefix_hit_skips_recompute_but_keeps_arrival_admissibility():
    """Cache hits must not break hardened admission ordering: requests with
    future arrivals still wait, and a hit on admission aliases rather than
    recomputes (prefill_tokens counts only the computed tail)."""
    cfg, params, prompt, _ = setup_family("qwen2-1.5b")
    row = np.asarray(prompt, np.int32)[0]
    reqs = [Request(prompt=row.copy(), max_new=4),
            Request(prompt=row.copy(), max_new=4, arrival=3.0)]
    eng = build_engine("continuous", cfg, params, max_seq=24, page_size=PS,
                       chunk=3, num_pages=20, prefix_cache=True,
                       clock=VirtualClock())
    report = eng.serve_detailed(reqs, policy=ResiliencePolicy(round_time=1.0))
    assert all(r.status == "done" for r in report.records)
    assert report.records[1].t_admit >= 3.0
    assert eng.prefix_hits >= 1
    assert eng.prefill_tokens < 2 * len(row)
