"""Flash-attention Pallas kernel vs oracle (interpret mode), shape sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention, flash_attention_ref
from repro.models.attention import _direct_attention


def _qkv(bh, sq, sk, d, seed=0, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (bh, sq, d), dtype)
    k = jax.random.normal(kk, (bh, sk, d), dtype)
    v = jax.random.normal(kv, (bh, sk, d), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "bh,sq,sk,d,bq,bkv",
    [
        (2, 64, 64, 32, 32, 32),
        (4, 128, 128, 16, 64, 32),
        (1, 256, 256, 64, 128, 128),
        (2, 64, 128, 32, 64, 64),  # cross-attn (non-causal, longer kv)
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(bh, sq, sk, d, bq, bkv, causal):
    if causal and sq != sk:
        pytest.skip("causal requires square")
    q, k, v = _qkv(bh, sq, sk, d, seed=sq + sk)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    q, k, v = _qkv(2, 128, 128, 32, seed=9, dtype=dtype)
    got = flash_attention(q, k, v, causal=True, bq=64, bkv=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * 10,
    )


def test_flash_matches_model_attention_path():
    """Kernel agrees with the model's GQA direct-attention path (G=1)."""
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    want = _direct_attention(q, k, v, causal=True)  # (b,s,h,1,d)
    qf = q[:, :, :, 0].transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    got = flash_attention(qf, kf, vf, causal=True, bq=32, bkv=32, interpret=True)
    got = got.reshape(b, h, s, d).transpose(0, 2, 1, 3)[:, :, :, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_fully_masked_rows_no_nan():
    """Non-causal with sk block all -inf never NaNs (first block masked)."""
    q, k, v = _qkv(1, 64, 64, 16, seed=3)
    got = flash_attention(q, k, v, causal=True, bq=32, bkv=32, interpret=True)
    assert not bool(jnp.any(jnp.isnan(got)))
