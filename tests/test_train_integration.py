"""End-to-end integration: train loop, checkpoint-resume equivalence."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.train import train_loop
from repro.optim import AdamWConfig


def test_train_loop_reduces_loss(tmp_path):
    cfg = get_reduced("llama3.2-3b")
    _, _, log = train_loop(
        cfg, steps=30, batch=4, seq=32, ckpt_dir=str(tmp_path), ckpt_every=10,
        log_every=5,
    )
    losses = [m["loss"] for m in log]
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]


def test_resume_equals_continuous(tmp_path):
    """Training 10+10 steps with a restart must equal 20 continuous steps
    (stateless data pipeline + full optimizer state in the checkpoint)."""
    cfg = get_reduced("qwen2-1.5b")
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=20)

    # continuous
    p_cont, _, _ = train_loop(cfg, steps=20, batch=4, seq=32, seed=3,
                              opt_cfg=opt, log_every=100)

    # interrupted: 10 steps, checkpoint, then resume to 20
    d = str(tmp_path / "ck")
    train_loop(cfg, steps=10, batch=4, seq=32, seed=3, opt_cfg=opt,
               ckpt_dir=d, ckpt_every=10, log_every=100)
    p_res, _, _ = train_loop(cfg, steps=20, batch=4, seq=32, seed=3,
                             opt_cfg=opt, ckpt_dir=d, ckpt_every=10,
                             log_every=100)

    for a, b in zip(jax.tree.leaves(p_cont), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-4,
        )


def test_moe_train_integration():
    cfg = get_reduced("moonshot-v1-16b-a3b")
    _, _, log = train_loop(cfg, steps=12, batch=4, seq=32, log_every=4)
    losses = [m["loss"] for m in log]
    assert all(np.isfinite(l) for l in losses)
    # aux losses present and bounded (lb is at most n_experts by construction)
    assert 0.0 < log[-1]["load_balance"] <= cfg.moe.n_experts + 1
