"""Tensor-sharded decode (serving.sharded).

Spec derivation: decode-time PartitionSpecs are DERIVED from the train-time
``launch.sharding.param_spec`` rules (cross-checked per family below, so the
two rule sets cannot silently diverge), with 'model' always on the output
dim — the only placement whose all-gather is a pure concatenation and
therefore token-exact.

Parity: sharded greedy decode must be TOKEN-IDENTICAL to the single-device
engines for all six families, in both ``ServingEngine.generate`` and
``ContinuousBatchingEngine.serve`` (paged cache included).  The 8-device
checks run in a subprocess with ``--xla_force_host_platform_device_count=8``
(the repo's established multi-device test idiom — see test_launch.py);
mesh-size-1 parity runs in-process so the shard_map plumbing is exercised in
every tier-1 run regardless of device count.

Admit path: the direct page-write prefill must produce byte-identical caches
to the retired dense round-trip, for which ``models.paged_insert`` survives
as the reference implementation.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import FAMILY_ARCHS
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.launch.sharding import (
    paged_cache_pspecs,
    paged_cache_shardings,
    param_spec,
)
from repro.models import (
    init_cache,
    init_paged_cache,
    init_params,
    paged_insert,
    prefill,
)
from repro.quant import decode_partition_spec
from repro.serving import (
    ContinuousBatchingEngine,
    ServingEngine,
    make_decode_mesh,
    pim_bytes,
    quantize_tree,
    shard_quantized_tree,
    tree_pspecs,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Which quantized leaves the decode rule distributes, per family — the
# leaves the TRAIN rule shards somewhere (TP or FSDP).  x_proj is the one
# quantized-but-replicated leaf: param_spec replicates it at train time too.
SHARDED_LEAVES = {
    "qwen2-1.5b": {"wq", "wk", "wv", "wo", "gate", "up", "down"},
    "deepseek-v2-lite-16b": {"wq", "wk", "wv", "wo", "gate", "up", "down",
                             "head", "w_dkv", "w_uk", "w_uv"},
    "moonshot-v1-16b-a3b": {"wq", "wk", "wv", "wo", "gate", "up", "down",
                            "head"},
    "falcon-mamba-7b": {"in_proj", "out_proj", "head"},
    "zamba2-1.2b": {"wq", "wk", "wv", "wo", "gate", "up", "down", "head",
                    "in_proj", "out_proj"},
    "llama-3.2-vision-90b": {"wq", "wk", "wv", "wo", "gate", "up", "down",
                             "head"},
    "seamless-m4t-medium": {"wq", "wk", "wv", "wo", "gate", "up", "down",
                            "head"},
}
REPLICATED_QUANTIZED = {"falcon-mamba-7b": {"x_proj"}}


def _qleaves(arch):
    cfg = get_reduced(arch)
    q = quantize_tree(init_params(cfg, jax.random.PRNGKey(0)), 8)
    out = []

    def walk(t, names):
        if isinstance(t, dict) and "codes" in t:
            out.append((names, t))
        elif isinstance(t, dict):
            for k, v in t.items():
                walk(v, names + [k])

    walk(q, [])
    return out


# ------------------------------------------------------- spec derivation ----
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_decode_spec_cross_checks_train_rule(arch):
    """Per family: the decode rule shards exactly the leaves the train rule
    shards somewhere (golden set), always on the last dim; every other
    quantized leaf replicates.  Drift in param_spec shows up here."""
    sharded, repl = set(), set()
    for names, leaf in _qleaves(arch):
        spec = decode_partition_spec(names, leaf["codes"].ndim)
        if "model" in spec:
            assert spec[-1] == "model" and spec[:-1] == (None,) * (len(spec) - 1)
            sharded.add(names[-1])
        else:
            repl.add(names[-1])
        # cross-check: sharded at decode <=> train-time spec is non-trivial
        train = param_spec(names, leaf["codes"].ndim, "fsdp")
        assert ("model" in spec) == any(e is not None for e in train)
    assert sharded == SHARDED_LEAVES[arch]
    assert repl == REPLICATED_QUANTIZED.get(arch, set())


def test_decode_spec_replicates_non_weight_leaves():
    for name in ("router", "x_proj", "dt_proj", "conv_w", "ln1"):
        assert decode_partition_spec(["layers", name], 2) == P(None, None)


# ------------------------------------------------- marker / pspec plumbing --
def test_shard_tree_markers_and_pspecs():
    """codes+scale+markers travel together: tp-marked leaves shard codes AND
    scale on their last dim, markers replicate and carry the stack dims so
    lax.scan can slice them; pim_bytes never counts markers."""
    mesh = make_decode_mesh(1)
    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    q4 = shard_quantized_tree(quantize_tree(params, 4), mesh)
    wq = q4["layers"]["attn"]["wq"]
    assert "tp" in wq and "nibbles" in wq
    assert wq["tp"].shape == wq["codes"].shape[:-2]  # scan-sliceable
    specs = tree_pspecs(q4)
    swq = specs["layers"]["attn"]["wq"]
    assert swq["codes"][-1] == "model" and swq["scale"][-1] == "model"
    assert swq["tp"] == P() and swq["nibbles"] == P()
    assert specs["embed"] == P()  # dense leaves replicate
    # markers excluded from byte accounting; 1-device mesh: per-device == total
    assert pim_bytes(q4) == pim_bytes(q4, per_device=True)
    n_markers = sum(leaf.size for path, leaf in
                    jax.tree_util.tree_leaves_with_path(q4)
                    if str(getattr(path[-1], "key", "")) in
                    ("tp", "nibbles", "nibbles_odd"))
    assert n_markers > 0
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(q4))
    assert pim_bytes(q4) < total  # markers really were excluded


def test_mesh1_trivially_divides():
    """On the 1-device mesh every output dim divides, so even an odd-width
    rule-shardable leaf gets marked (the true indivisible branch needs a
    wider mesh — asserted on 8 devices in the subprocess extras test)."""
    mesh = make_decode_mesh(1)
    q = quantize_tree({"layers": {"attn": {"wq": jnp.zeros((16, 9))}}}, 8)
    t = shard_quantized_tree(q, mesh)
    assert "tp" in t["layers"]["attn"]["wq"]  # 9 % 1 == 0: mesh-1 shards


# ------------------------------------------------------ mesh-size-1 parity --
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "falcon-mamba-7b"])
def test_mesh1_parity_both_engines(arch):
    """shard_map plumbing end-to-end on the always-available 1-device mesh:
    tokens identical to the plain engines (the 8-device version of this
    runs in the subprocess tests below)."""
    mesh = make_decode_mesh(1)
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    plain = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
    shard = ServingEngine(cfg, params, max_seq=16, pim_bits=8, mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(plain.generate(prompt, n_new=5)),
        np.asarray(shard.generate(prompt, n_new=5)))
    pc = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                  page_size=4, chunk=4, pim_bits=8)
    sc = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                  page_size=4, chunk=4, pim_bits=8, mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(pc.generate(prompt, n_new=5)),
        np.asarray(sc.generate(prompt, n_new=5)))


def test_reference_loop_refuses_mesh():
    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=16, pim_bits=8,
                        mesh=make_decode_mesh(1))
    with pytest.raises(NotImplementedError, match="single-device"):
        eng.generate_reference(jnp.zeros((1, 4), jnp.int32), 2)


# ------------------------------------------------------- direct admit path --
@pytest.mark.parametrize("arch,kv_bits", [
    ("qwen2-1.5b", 0), ("qwen2-1.5b", 8), ("deepseek-v2-lite-16b", 0),
    ("falcon-mamba-7b", 0), ("zamba2-1.2b", 0),
])
def test_direct_admit_matches_paged_insert_reference(arch, kv_bits):
    """prefill(pages=, slot=) writes the pool pages / per-slot state rows
    byte-identically to the retired dense round-trip (batch-1 dense prefill
    + models.paged_insert), under a permuted page list."""
    cfg = get_reduced(arch)
    if kv_bits:
        cfg = cfg.replace(kv_cache_bits=kv_bits)
    params = init_params(cfg, jax.random.PRNGKey(0))
    spad, ps, length, slot = 8, 4, 6, 1
    prompt = np.zeros((1, spad), np.int32)
    prompt[0, :length] = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (length,), 0, cfg.vocab))
    prompt = jnp.asarray(prompt)
    pages = jnp.asarray([3, 1], jnp.int32)  # non-contiguous on purpose

    paged = init_paged_cache(cfg, 2, 16, 6, ps)
    tmp = init_cache(cfg, 1, spad)
    logits_ref, tmp = prefill(params, cfg, prompt, tmp, None,
                              length=jnp.int32(length))
    ref = paged_insert(cfg, paged, tmp, jnp.int32(slot), pages)

    logits_new, got = prefill(params, cfg, prompt,
                              init_paged_cache(cfg, 2, 16, 6, ps), None,
                              length=jnp.int32(length), pages=pages,
                              slot=jnp.int32(slot))
    np.testing.assert_array_equal(np.asarray(logits_ref),
                                  np.asarray(logits_new))
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(ref),
            jax.tree_util.tree_leaves_with_path(got)):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(pa))


# ---------------------------------------------------- paged cache specs -----
def test_paged_cache_pspecs_table():
    cfg = get_reduced("zamba2-1.2b")  # hybrid: pools + per-slot state + tail
    shape = jax.eval_shape(lambda: init_paged_cache(cfg, 2, 16, 9, 4))
    specs = paged_cache_pspecs(shape, cfg)
    assert specs["block_tables"] == P(None, None)  # replicated
    k = specs["groups_attn"]["k"]
    assert k[-4] == "data" and all(e is None for i, e in enumerate(k)
                                   if i != len(k) - 4)  # pages over data
    # per-slot mamba2 state: 'data' on the BATCH dim, not the head dim
    h = specs["tail"]["h"]  # (tail, B, nh, hd, sd)
    assert h == P(None, "data", None, None, None)
    gh = specs["groups_ssm"]["h"]  # (G, attn_every, B, nh, hd, sd)
    assert gh == P(None, None, "data", None, None, None)
    # mamba1 payload is rank-3: batch still resolved via cfg
    cfg1 = get_reduced("falcon-mamba-7b")
    specs1 = paged_cache_pspecs(
        jax.eval_shape(lambda: init_paged_cache(cfg1, 2, 16, 9, 4)), cfg1)
    assert specs1["layers"]["h"] == P(None, "data", None, None)
    # NamedSharding wrapper: on a mesh WITHOUT a data axis (the engines'
    # 1-D model mesh) every cache leaf degenerates to replication
    named = paged_cache_shardings(make_decode_mesh(1), shape, cfg)
    assert all(all(e is None for e in sh.spec)
               for sh in jax.tree.leaves(named))


# ----------------------------------------------- 8-device token identity ----
SHARDED_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.models import init_params, encode
from repro.serving import (ServingEngine, ContinuousBatchingEngine, Request,
                           make_decode_mesh, pim_bytes, tree_pspecs)
from repro.models.common import set_matvec_dispatch

MODE = sys.argv[1]
ARCHS = sys.argv[2].split(",")
mesh = make_decode_mesh(8)
out = []
for arch in ARCHS:
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    extras = None
    if cfg.family == "vlm":
        extras = {"image_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.vision.n_image_tokens, cfg.d_model))}
    elif cfg.family == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(3), (2, cfg.audio.n_frames, cfg.d_model))
        extras = {"enc_out": encode(params, cfg, frames)}
    row = {"arch": arch}
    if MODE == "fixed":
        plain = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
        shard = ServingEngine(cfg, params, max_seq=16, pim_bits=8, mesh=mesh)
        row["identical"] = bool(np.array_equal(
            np.asarray(plain.generate(prompt, n_new=5, extras=extras)),
            np.asarray(shard.generate(prompt, n_new=5, extras=extras))))
        row["per_device_lt_total"] = bool(
            pim_bytes(shard.params, per_device=True) < pim_bytes(shard.params))
    elif MODE == "paged":
        plain = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                         page_size=4, chunk=4, pim_bits=8,
                                         page_alloc_seed=7)
        shard = ContinuousBatchingEngine(cfg, params, slots=2, max_seq=16,
                                         page_size=4, chunk=4, pim_bits=8,
                                         page_alloc_seed=7, mesh=mesh)
        reqs_a = [Request(prompt=np.asarray(prompt[i]), max_new=4 + i,
                          extras=(None if extras is None else
                                  jax.tree.map(lambda a: a[i], extras)))
                  for i in range(2)]
        reqs_b = [Request(prompt=r.prompt, max_new=r.max_new, extras=r.extras)
                  for r in reqs_a]
        a, b = plain.serve(reqs_a), shard.serve(reqs_b)
        row["identical"] = bool(all(np.array_equal(x, y)
                                    for x, y in zip(a, b)))
    elif MODE == "extras":
        # int4 odd-K packing under sharding
        a = ServingEngine(cfg, params, max_seq=16, pim_bits=4)
        b = ServingEngine(cfg, params, max_seq=16, pim_bits=4, mesh=mesh)
        row["int4_identical"] = bool(np.array_equal(
            np.asarray(a.generate(prompt, n_new=5)),
            np.asarray(b.generate(prompt, n_new=5))))
        # the pim_matvec kernel dispatch applies per-shard (one arch is
        # enough: interpret-mode pallas inside the scan is slow)
        if arch == "qwen2-1.5b":
            set_matvec_dispatch("force")
            a = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
            b = ServingEngine(cfg, params, max_seq=16, pim_bits=8, mesh=mesh)
            row["matvec_identical"] = bool(np.array_equal(
                np.asarray(a.generate(prompt, n_new=3)),
                np.asarray(b.generate(prompt, n_new=3))))
            set_matvec_dispatch("auto")
        # a dense tree over a multi-device mesh distributes nothing: refuse
        try:
            ServingEngine(cfg, params, max_seq=16, mesh=mesh)
            row["dense_mesh_raises"] = False
        except ValueError:
            row["dense_mesh_raises"] = True
        if cfg.family == "ssm":
            from repro.serving import shard_quantized_tree, quantize_tree
            t = shard_quantized_tree(quantize_tree(params, 8), mesh)
            # x_proj is replicated by the RULE itself (train spec is
            # trivial); in_proj is rule-sharded and divides
            row["indivisible_replicated"] = (
                "tp" not in t["layers"]["ssm"]["x_proj"]
                and "tp" in t["layers"]["ssm"]["in_proj"])
            # the DIVISIBILITY branch: a rule-sharded leaf (wq) whose
            # output width 12 does not divide 8 devices must stay
            # unmarked, while its divisible sibling shards
            import jax.numpy as jnp
            fake = quantize_tree({"layers": {"attn": {
                "wq": jnp.zeros((16, 12)), "wk": jnp.zeros((16, 16))}}}, 8)
            ft = shard_quantized_tree(fake, mesh)
            row["indivisible_replicated"] = (
                row["indivisible_replicated"]
                and "tp" not in ft["layers"]["attn"]["wq"]
                and "tp" in ft["layers"]["attn"]["wk"])
    out.append(row)
print("RESULT " + json.dumps(out))
"""


def _run_sharded(mode: str, archs: str):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_SNIPPET, mode, archs],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_sharded_fixed_engine_token_identity_all_families():
    """Acceptance: greedy ServingEngine.generate on a forced 8-virtual-
    device mesh is token-identical to single-device, all six families, and
    per-device weight bytes really shrink."""
    rows = _run_sharded("fixed", ",".join(FAMILY_ARCHS))
    for r in rows:
        assert r["identical"], r
        assert r["per_device_lt_total"], r


def test_sharded_paged_engine_token_identity_all_families():
    """Acceptance: the continuous-batching scheduler on the paged cache,
    serving staggered per-request budgets under shard_map, stays
    token-identical to its single-device run for all six families."""
    rows = _run_sharded("paged", ",".join(FAMILY_ARCHS))
    for r in rows:
        assert r["identical"], r


def test_sharded_int4_matvec_and_divisibility():
    rows = _run_sharded("extras", "qwen2-1.5b,falcon-mamba-7b")
    for r in rows:
        assert r["int4_identical"], r
        assert r["dense_mesh_raises"], r
    assert [r for r in rows
            if r["arch"] == "qwen2-1.5b"][0]["matvec_identical"]
    ssm = [r for r in rows if r["arch"] == "falcon-mamba-7b"][0]
    assert ssm["indivisible_replicated"], ssm
