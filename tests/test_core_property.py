"""Hypothesis property tests on system invariants (cost models, quant,
mapping, schedules)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core.archmodels import ARCHS
from repro.core.mapping import matvec_cycles
from repro.optim import cosine_schedule
from repro.quant import dequantize, quantize_symmetric
from repro.runtime import plan_elastic_remesh

pow2 = st.integers(1, 7).map(lambda e: 2**e)
widths = st.sampled_from([4, 8, 16, 32])


@given(q=st.integers(1, 6).map(lambda e: 2 ** (e + 4)), n=widths)
def test_picaso_accumulation_never_slower_than_spar2(q, n):
    assert cm.accum_cycles_picaso(q, n) < cm.accum_cycles_spar2(q, n)


@given(q=st.integers(1, 6).map(lambda e: 2 ** (e + 4)), n=widths)
def test_amod_accum_faster_than_custom(q, n):
    """The paper's §V-A claim holds at every (q, N): OpMux removes copies."""
    assert cm.accum_cycles_amod(q, n) < cm.accum_cycles_custom(q, n)


@given(n=widths)
def test_memory_efficiency_ordering(n):
    """Fig 7 ordering CCB < CoMeFa < A-Mod <= PiCaSO at every precision."""
    ccb = ARCHS["CCB"].memory_efficiency(n)
    comefa = ARCHS["CoMeFa-A"].memory_efficiency(n)
    amod = ARCHS["A-Mod"].memory_efficiency(n)
    picaso = ARCHS["PiCaSO-F"].memory_efficiency(n)
    assert ccb < comefa < amod <= picaso


@given(n=widths)
def test_accum_formulas_positive_monotone(n):
    prev = 0
    for q in (16, 32, 64, 128, 256):
        c = cm.accum_cycles_picaso(q, n)
        assert c > prev
        prev = c


@given(m=st.integers(1, 64), k=pow2.map(lambda v: v * 16), n=widths)
def test_matvec_cycles_scales_with_waves(m, k, n):
    one = matvec_cycles(1, k, n, total_pes=k)
    many = matvec_cycles(m, k, n, total_pes=k)
    assert many == m * one


@settings(max_examples=20)
@given(
    seed=st.integers(0, 10_000),
    bits=st.sampled_from([4, 8]),
    rows=st.integers(2, 32),
    cols=st.integers(2, 16),
)
def test_quantize_error_bounded_by_half_step(seed, bits, rows, cols):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32))
    q = quantize_symmetric(w, bits=bits, axis=0)
    err = jnp.abs(dequantize(q) - w)
    assert float(jnp.max(err / (q.scale / 2 + 1e-12))) <= 1.0 + 1e-3


@given(step=st.integers(0, 2000))
def test_cosine_schedule_bounded(step):
    s = float(cosine_schedule(step, 100, 1000))
    assert 0.0 <= s <= 1.0 + 1e-6


@given(hosts=st.integers(16, 512))
def test_elastic_plan_invariants(hosts):
    plan = plan_elastic_remesh(hosts, model_parallel=16, nominal_data=32)
    assert plan.hosts_used <= hosts
    assert plan.model == 16
    total_rows = plan.pods * plan.data
    assert total_rows & (total_rows - 1) == 0  # power of two
    assert 0 < plan.batch_scale <= 1.0
