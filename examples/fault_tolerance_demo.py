"""Fault-tolerance demo: train, kill a host, recover, shrink the mesh.

Drives REAL training steps (reduced qwen2 on CPU) under the
TrainingSupervisor: a simulated host death mid-run triggers checkpoint
restore + elastic re-planning from a (2,16,16) multi-pod mesh down to a
single-pod (16,16) mesh, then training completes.  The exact control path a
1000-node deployment runs — with the device fleet simulated.

  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, make_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import HeartbeatMonitor, TrainingSupervisor, plan_elastic_remesh


class FakeClock:
    t = 0.0

    def __call__(self):
        return self.t


def main():
    cfg = get_reduced("qwen2-1.5b")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=120)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    dc = DataConfig(seed=0, vocab=cfg.vocab)
    shape = ShapeConfig("demo", 64, 8, "train")

    with tempfile.TemporaryDirectory() as root:
        mgr = CheckpointManager(root, keep_last=2)
        clock = FakeClock()
        mon = HeartbeatMonitor(512, timeout_s=10.0, clock=clock)
        state = {"params": params, "opt": opt_state}
        losses = {}

        def run_step(step, plan):
            clock.t += 1.0
            for h in mon.healthy:
                mon.beat(h)
            if step == 40 and 300 not in mon.dead:
                # a whole host rack drops
                for h in range(300, 364):
                    mon.dead.add(h)
                raise RuntimeError("rack 300-363 unreachable")
            batch = make_batch(cfg, shape, step=step, data_cfg=dc,
                               batch_override=8, seq_override=64)
            state["params"], state["opt"], m = step_fn(
                state["params"], state["opt"], batch
            )
            losses[step] = float(m["loss"])
            return 1.0

        def save(step):
            mgr.save(step, state)
            print(f"  [ckpt] saved step {step}")

        def restore():
            got, restored = mgr.restore_latest(state)
            if got is not None:
                state.update(restored)
                print(f"  [ckpt] restored step {got}")
            return got

        sup = TrainingSupervisor(
            512, run_step, save, restore,
            replan=lambda n: plan_elastic_remesh(n, model_parallel=16,
                                                 nominal_data=32),
            monitor=mon, ckpt_every=20, max_restarts=4,
        )
        print("== training 80 steps; a rack dies at step 40 ==")
        result = sup.run(total_steps=80)
        print(f"\nsteps completed : {result.step}")
        print(f"restarts        : {result.restarts}")
        print(f"mesh plans      : {[p.shape for p in result.plans]}")
        first = losses[min(losses)]
        last = losses[max(losses)]
        print(f"loss            : {first:.4f} -> {last:.4f}")
        assert result.restarts == 1 and result.step == 80 and last < first
        print("OK: recovered from rack failure with elastic re-mesh")


if __name__ == "__main__":
    main()
