"""PiCaSO PIM array walk-through: the paper's machine, end to end.

Runs a 128-wide dot product on the simulated bit-serial overlay exactly the
way the hardware does it — corner-turn, Booth multiply, OpMux folds, binary-
hopping network reduction — validates the value against numpy, and prints
the cycle count next to the paper's Table V formulas and the SPAR-2 baseline.

  PYTHONPATH=src python examples/pim_array_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import costmodel as cm
from repro.core import archmodels, simulate_dot_product
from repro.core.devices import ALVEO_U55


def main():
    rng = np.random.default_rng(0)
    q, width = 128, 8
    x = rng.integers(-128, 128, size=q)
    w = rng.integers(-128, 128, size=q)

    print(f"== {q}-element dot product, {width}-bit operands ==")
    val, cycles = simulate_dot_product(x, w, width)
    ref = int(np.dot(x.astype(np.int64), w.astype(np.int64)))
    print(f"simulated PiCaSO value : {val}")
    print(f"numpy reference        : {ref}")
    assert val == ref
    print(f"cycle count            : {cycles}")

    acc_w = 2 * width + cm.log2i(q) + 1
    spar2 = cm.mult_cycles_overlay(width) + cm.accum_cycles_spar2(q, acc_w)
    print(f"SPAR-2 (NEWS) cycles   : {spar2}  "
          f"({spar2 / cycles:.1f}x slower accumulation)")

    print("\n== Table V headline (q=128, N=32) ==")
    print(f"SPAR-2 accumulation  : {cm.accum_cycles_spar2(128, 32)} cycles")
    print(f"PiCaSO-F accumulation: {cm.accum_cycles_picaso(128, 32)} cycles "
          f"(17x faster)")

    print("\n== paper Fig 5/6/7 at 8-bit on Alveo U55 ==")
    rel = archmodels.relative_mac_latency(8)
    thr = archmodels.peak_throughput_table(8)
    eff = archmodels.memory_efficiency_table(8)
    for name in ("CCB", "CoMeFa-D", "CoMeFa-A", "PiCaSO-F", "A-Mod"):
        print(f"  {name:9s} rel-latency {rel[name]:5.2f}x   "
              f"peak {thr[name]:6.3f} TMAC/s   mem-eff {eff[name]*100:5.1f}%")
    print(f"\nPiCaSO/CoMeFa-A throughput: "
          f"{thr['PiCaSO-F']/thr['CoMeFa-A']*100:.0f}% (paper: 75-80%)")


if __name__ == "__main__":
    main()
