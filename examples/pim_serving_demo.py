"""PIM-mode serving demo: batched generation with int8 weight storage.

Quantizes a trained (here: randomly-initialised reduced llama3.2) model into
PIM storage (int8 codes + scales), serves a batch of requests, and reports
the weight-bytes saved — the memory-bound decode regime the paper's PIM
architecture targets (§I).

  PYTHONPATH=src python examples/pim_serving_demo.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import forward, init_params
from repro.serving import ServingEngine, quantize_tree
from repro.serving.engine import pim_bytes


def main():
    cfg = get_reduced("llama3.2-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    dense_b = pim_bytes(params)
    qparams = quantize_tree(params, bits=8)
    quant_b = pim_bytes(qparams)
    print(f"weight bytes  dense : {dense_b:,}")
    print(f"weight bytes  PIM-8 : {quant_b:,}  ({dense_b / quant_b:.2f}x smaller)")

    # top-1 agreement between dense and PIM-mode logits
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    d, _ = forward(params, cfg, {"tokens": toks})
    q, _ = forward(qparams, cfg, {"tokens": toks})
    agree = (np.asarray(d).argmax(-1) == np.asarray(q).argmax(-1)).mean()
    print(f"top-1 agreement dense vs PIM: {agree * 100:.1f}%")

    engine = ServingEngine(cfg, params, max_seq=40, pim_bits=8)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, cfg.vocab)
    t0 = time.time()
    out = engine.generate(prompts, n_new=24)
    dt = time.time() - t0
    print(f"served 4 requests x 24 tokens in {dt:.2f}s "
          f"({4 * 24 / dt:.1f} tok/s on CPU)")
    print("sample:", out[0][:12].tolist())
    assert agree > 0.9
    print("OK")


if __name__ == "__main__":
    main()
