"""PIM-mode serving demo: batched generation with int8 weight storage.

Quantizes a trained (here: randomly-initialised reduced llama3.2) model into
PIM storage (int8 codes + scales), serves a batch of requests, and reports
the weight-bytes saved — the memory-bound decode regime the paper's PIM
architecture targets (§I).  The speculation section then amortises that
weight stream over several tokens per step (``speculate=SpecConfig(k=...)``)
while emitting exactly the same greedy tokens.  The chaos section at the
end kills the engine mid-trace under seeded fault injection and lets the
``ServingSupervisor`` replay it from its snapshot — finishing with
token-identical outputs.

  PYTHONPATH=src python examples/pim_serving_demo.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import forward, init_params
from repro.serving import (ContinuousBatchingEngine, ServingEngine,
                           SpecConfig, quantize_tree)
from repro.serving.engine import pim_bytes


def main():
    cfg = get_reduced("llama3.2-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    dense_b = pim_bytes(params)
    qparams = quantize_tree(params, bits=8)
    quant_b = pim_bytes(qparams)
    print(f"weight bytes  dense : {dense_b:,}")
    print(f"weight bytes  PIM-8 : {quant_b:,}  ({dense_b / quant_b:.2f}x smaller)")

    # top-1 agreement between dense and PIM-mode logits
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    d, _ = forward(params, cfg, {"tokens": toks})
    q, _ = forward(qparams, cfg, {"tokens": toks})
    agree = (np.asarray(d).argmax(-1) == np.asarray(q).argmax(-1)).mean()
    print(f"top-1 agreement dense vs PIM: {agree * 100:.1f}%")

    engine = ServingEngine(cfg, params, max_seq=40, pim_bits=8)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, cfg.vocab)
    t0 = time.time()
    out = engine.generate(prompts, n_new=24)
    dt = time.time() - t0
    print(f"served 4 requests x 24 tokens in {dt:.2f}s "
          f"({4 * 24 / dt:.1f} tok/s on CPU)")
    print("sample:", out[0][:12].tolist())

    # Speculative multi-token decode: propose k tokens by prompt-lookup,
    # verify the whole window with ONE weight stream, keep the longest
    # greedy-matching prefix — same tokens, fewer weight streams.
    t0 = time.time()
    out_spec = engine.generate(prompts, n_new=24, speculate=SpecConfig(k=4))
    dt_spec = time.time() - t0
    st = engine.spec_stats
    print(f"speculative (k=4): {4 * 24 / dt_spec:.1f} tok/s, "
          f"{st['emitted_per_step']:.2f} tokens per weight stream "
          f"({st['verify_steps']} verify steps)")
    assert np.array_equal(np.asarray(out), np.asarray(out_spec)), \
        "speculative decode must be token-identical to greedy"
    print("speculative tokens identical to plain greedy: True")

    # SAMPLED speculation: temperature/top-k requests ride the fast path
    # too, verified by rejection sampling — accept draft d with probability
    # min(1, p(d)/q(d)), resample rejections from norm(max(p-q, 0)).  The
    # output DISTRIBUTION equals plain sampled decode exactly (the tokens
    # differ: speculation consumes the PRNG stream differently), and
    # because draws are keyed per (request, counter) rather than per batch
    # step, the same key gives the SAME tokens on the paged
    # continuous-batching engine — a different scheduler, cache layout,
    # and chunking entirely.
    key = jax.random.PRNGKey(42)
    out_fixed = engine.generate(prompts, n_new=24, greedy=False,
                                temperature=0.8, top_k=40, key=key,
                                speculate=SpecConfig(k=4))
    st = engine.spec_stats
    paged = ContinuousBatchingEngine(cfg, params, slots=4, max_seq=40,
                                     page_size=8, chunk=3, pim_bits=8,
                                     speculate=SpecConfig(k=4))
    out_paged = paged.generate(prompts, n_new=24, greedy=False,
                               temperature=0.8, top_k=40, key=key)
    assert np.array_equal(np.asarray(out_fixed), np.asarray(out_paged)), \
        "sampled speculation must be key-deterministic across engines"
    print(f"sampled speculation (T=0.8, top-k 40): fixed and paged engines "
          f"token-identical for one key, "
          f"{st['emitted_per_step']:.2f} tokens per weight stream, "
          f"acceptance {st['acceptance_per_live_row']:.2f} tok/window")

    # Making speculation PAY under load — three shapes beyond the fixed
    # window.  ADAPTIVE: a per-request acceptance EMA drives a bucketed
    # cost model that re-picks the window width every round, all the way
    # down to k=0 (plain decode + a free probe) when the proposer is
    # losing — greedy tokens stay identical at ANY window schedule.
    t0 = time.time()
    out_ad = engine.generate(prompts, n_new=24,
                             speculate=SpecConfig(k=4, adaptive=True))
    dt_ad = time.time() - t0
    st = engine.spec_stats
    assert np.array_equal(np.asarray(out), np.asarray(out_ad)), \
        "adaptive speculation must be token-identical to greedy"
    print(f"adaptive (k<=4): {4 * 24 / dt_ad:.1f} tok/s, "
          f"{st['emitted_per_step']:.2f} tokens per weight stream — "
          f"controller tunes k from measured acceptance, same tokens")

    # TREE: fan-2 multi-candidate drafts (the top-2 n-gram history
    # matches), verified in ONE pass via shared-prefix tree attention;
    # the winning chain's cache columns are relocated into canonical
    # positions before commit.  Static schedule, so sampled tree decode
    # is even key-identical across engines.
    out_tr = engine.generate(prompts, n_new=24,
                             speculate=SpecConfig(k=2, tree_fan=2))
    assert np.array_equal(np.asarray(out), np.asarray(out_tr)), \
        "tree speculation must be token-identical to greedy"
    print("tree (fan=2, depth=2): token-identical to plain greedy, "
          f"{engine.spec_stats['emitted_per_step']:.2f} tokens per stream")

    # TYPICAL: the explicitly LOSSY entropy-band acceptance — a draft is
    # accepted deterministically once the target puts enough mass on it
    # (min(eps, delta*exp(-H)) threshold), trading exactness for
    # acceptance on hard text.  Opt-in via accept="typical".
    out_ty = engine.generate(prompts, n_new=24, greedy=False,
                             temperature=0.8, top_k=40, key=key,
                             speculate=SpecConfig(k=4, accept="typical"))
    print(f"typical acceptance (lossy, T=0.8): "
          f"{engine.spec_stats['emitted_per_step']:.2f} tokens per stream — "
          f"biased toward the proposer, deterministic per key")
    del out_ty

    # Chaos: the same trace with the engine KILLED twice mid-flight (seeded
    # injection) plus transient chunk faults.  The supervisor detects each
    # death via the heartbeat monitor, restores the last snapshot (prompt +
    # emitted tokens + draw counters — two integers of sampling state per
    # request), and replays.  Because every PRNG draw is keyed by
    # (request, counter), the replayed streams CONTINUE where the dead
    # engine stopped: the final tokens match the undisturbed run exactly.
    from repro.serving import (ChaosConfig, FaultInjector, Request,
                               ResiliencePolicy, ServingSupervisor)

    reqs = [Request(prompt=np.asarray(p), max_new=24)
            for p in np.asarray(prompts)]
    fresh = lambda: ContinuousBatchingEngine(
        cfg, params, slots=4, max_seq=40, page_size=8, chunk=3, pim_bits=8,
        speculate=SpecConfig(k=4))
    calm = fresh().serve(reqs, greedy=False, temperature=0.8, top_k=40,
                         key=key)
    sup = ServingSupervisor(
        fresh(), policy=ResiliencePolicy(),
        chaos=FaultInjector(ChaosConfig(seed=0, fault_rate=0.2,
                                        crash_rounds=(1, 4))))
    report = sup.run(reqs, greedy=False, temperature=0.8, top_k=40, key=key)
    assert all(np.array_equal(a, r.tokens)
               for a, r in zip(calm, report.records)), \
        "crash replay must be token-identical"
    print(f"chaos: {report.restarts} engine crashes replayed, "
          f"{report.retries} chunk retries — all {len(reqs)} requests "
          f"token-identical to the fault-free run")
    assert agree > 0.9
    print("OK")


if __name__ == "__main__":
    main()
