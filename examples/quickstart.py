"""Quickstart: train a small LM end-to-end with checkpoint/restart.

Runs on CPU in ~2 minutes: a reduced qwen2-style GQA model on the synthetic
Markov data pipeline, with AdamW + cosine schedule, checkpointing every 50
steps, and a demonstration that killing + resuming mid-run is lossless.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_reduced
from repro.launch.train import train_loop


def main():
    cfg = get_reduced("qwen2-1.5b")
    with tempfile.TemporaryDirectory() as ckpt:
        print("== phase 1: train 60 steps (checkpoint every 25) ==")
        _, _, log1 = train_loop(
            cfg, steps=60, batch=8, seq=64, ckpt_dir=ckpt, ckpt_every=25,
        )
        print("\n== phase 2: simulate restart — resume from latest ckpt ==")
        _, _, log2 = train_loop(
            cfg, steps=120, batch=8, seq=64, ckpt_dir=ckpt, ckpt_every=25,
        )
        first, last = log1[0]["loss"], log2[-1]["loss"]
        print(f"\nloss {first:.4f} -> {last:.4f}")
        assert last < first, "training must reduce the loss"
        print("OK: end-to-end training + restart works")


if __name__ == "__main__":
    main()
