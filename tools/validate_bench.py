"""Schema validator for the repo's ``BENCH_*.json`` artifacts.

The benches (benchmarks/decode_bench.py, benchmarks/serving_bench.py)
write structured result files that downstream tooling — the paper tables,
the CI no-regression guards, the README claims — read field-by-field.  A
bench refactor that silently renames or drops a field only surfaces when
a consumer breaks, usually in a different PR.  This checker pins the
contract: every committed/CI-generated ``BENCH_*.json`` must carry its
required sections with sanely-typed values.

Deliberately stdlib-only (no jsonschema dependency): the "schema" is a
nested dict of ``field -> type | sub-schema | callable predicate``, which
is enough to catch renames, dropped sections, and type drift.  It is NOT
a values regression guard — CI has a separate tolerance check for that.

CLI::

    python tools/validate_bench.py BENCH_serving.json [more.json ...]

exits non-zero listing every violation.  Files are matched to a schema by
their ``bench`` field (``serving_continuous_batching`` / ``decode_fastpath``);
unknown bench kinds only get the generic envelope check.
"""
from __future__ import annotations

import argparse
import json
import numbers
import sys

NUM = numbers.Real


def _is_grid(v):
    return isinstance(v, list) and all(isinstance(e, dict) for e in v)


# field -> expected: a type/tuple-of-types, a nested dict (sub-object), or
# a callable predicate.  Fields prefixed "?" are optional when present.
ENVELOPE = {"bench": str, "backend": str}

SERVING = {
    "bench": str,
    "backend": str,
    "arch": str,
    "trace": {"requests": NUM, "slots": NUM, "seed": NUM},
    "page_size": NUM,
    "chunk": NUM,
    "num_pages": NUM,
    "max_seq": NUM,
    "fixed_batch": {"wall_sec": NUM, "useful_tokens": NUM,
                    "tokens_per_sec": NUM},
    "continuous": {"wall_sec": NUM, "useful_tokens": NUM,
                   "tokens_per_sec": NUM, "peak_pages_in_use": NUM},
    "speedup_tokens_per_sec": NUM,
    "speculative": {"k": NUM, "grid": _is_grid},
    "chaos": {"grid": _is_grid},
    "sharded": {"devices": NUM, "grid": _is_grid},
    "?speculative_repetitive": {"grid": _is_grid},
    "?prefix_router": {
        "requests": NUM,
        "system_prompts": NUM,
        "page_size": NUM,
        "prefix_hit_rate": NUM,
        "prefill_tokens_uncached": NUM,
        "prefill_tokens_cached": NUM,
        "prefill_savings_frac": NUM,
        "admit_to_first_uncached_s": NUM,
        "admit_to_first_cached_s": NUM,
        "cow_forks": NUM,
        "evictions": NUM,
        "token_identical_greedy": bool,
        "token_identical_sampled": bool,
        "router": {"replicas": NUM, "affinity_hits": NUM,
                   "token_identical": bool},
        "trace_file": str,
        "trace_events": NUM,
    },
}

DECODE = {
    "bench": str,
    "backend": str,
    "grid": _is_grid,
    "fastpath_vs_seed": {"speedup": NUM, "tokens_match_seed": bool},
    "speculative": {"k": NUM, "grid": _is_grid},
    "sharded": {"devices": NUM, "grid": _is_grid},
}

SCHEMAS = {"serving_continuous_batching": SERVING,
           "decode_fastpath": DECODE}


def _check(obj, schema, path, errors):
    for field, want in schema.items():
        optional = field.startswith("?")
        name = field[1:] if optional else field
        here = f"{path}.{name}" if path else name
        if name not in obj:
            if not optional:
                errors.append(f"missing field: {here}")
            continue
        val = obj[name]
        if isinstance(want, dict):
            if not isinstance(val, dict):
                errors.append(f"{here}: expected object, got "
                              f"{type(val).__name__}")
            else:
                _check(val, want, here, errors)
        elif callable(want) and not isinstance(want, type):
            if not want(val):
                errors.append(f"{here}: failed {want.__name__} "
                              f"(got {type(val).__name__})")
        else:
            # bool is an int subclass; demand exact bools where asked
            if want is bool:
                ok = isinstance(val, bool)
            elif want is NUM or want is numbers.Real:
                ok = isinstance(val, numbers.Real) and not isinstance(
                    val, bool)
            else:
                ok = isinstance(val, want)
            if not ok:
                errors.append(f"{here}: expected "
                              f"{getattr(want, '__name__', want)}, got "
                              f"{type(val).__name__} ({val!r:.60})")


def validate_bench(obj, kind: str = "") -> list[str]:
    """Return a list of violations (empty == valid).  ``kind`` overrides
    the ``bench`` field when validating partial/smoke outputs."""
    errors: list[str] = []
    if not isinstance(obj, dict):
        return ["top level must be a JSON object"]
    _check(obj, ENVELOPE, "", errors)
    schema = SCHEMAS.get(kind or obj.get("bench", ""))
    if schema is not None:
        errors = []
        _check(obj, schema, "", errors)
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="BENCH_*.json files to check")
    ap.add_argument("--kind", default="",
                    help="force a schema (serving_continuous_batching / "
                         "decode_fastpath) instead of reading 'bench'")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.paths:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"INVALID {path}: {e}", file=sys.stderr)
            bad += 1
            continue
        errors = validate_bench(obj, args.kind)
        if errors:
            bad += 1
            print(f"INVALID {path}:", file=sys.stderr)
            for e in errors:
                print(f"  - {e}", file=sys.stderr)
        else:
            print(f"OK {path} ({obj.get('bench', '?')})")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
