"""Top byte/flop contributors of a cached HLO — the dry-run 'profiler'."""
import gzip
import sys

sys.path.insert(0, "src")

from repro.launch.hlo_cost import (
    _MEM_OPS,
    compute_multipliers,
    _find_entry,
    instr_bytes,
    parse_module,
)


def main(path, top=20):
    with gzip.open(path, "rt") as f:
        hlo = f.read()
    comps = parse_module(hlo)
    entry = _find_entry(hlo, comps)
    mult, trips = compute_multipliers(comps, entry)

    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        symtab = {i.name: i.shape_str for i in comp.instrs}
        for ins in comp.instrs:
            if ins.opcode not in _MEM_OPS:
                continue
            b = instr_bytes(ins, symtab, trips.get(cname, 0))
            rows.append((m * b, m, b, cname, ins.name, ins.opcode,
                         ins.shape_str[:60]))

    rows.sort(reverse=True)
    print(f"{'m*bytes':>14s} {'mult':>8s} {'bytes':>12s}  comp/instr (op) shape")
    for mb, m, b, cname, iname, op, shape in rows[:int(top)]:
        print(f"{mb:14.3e} {m:8.0f} {b:12.3e}  {cname[:28]}/{iname[:40]} "
              f"({op}) {shape}")


if __name__ == "__main__":
    main(*sys.argv[1:])
