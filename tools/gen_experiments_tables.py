"""Generate the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from
results/dryrun_all.json."""
import json
import sys


def main(path="results/dryrun_all.json"):
    with open(path) as f:
        data = json.load(f)
    ok = data["ok"]
    print(f"## cells: {len(ok)} ok, {len(data['failed'])} failed\n")

    print("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
          "bottleneck | useful | roofline frac | mem/dev (GiB) |")
    print("|---|---|---|---:|---:|---:|---|---:|---:|---:|")
    for c in ok:
        print(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['t_compute_s']*1e3:.2f} | {c['t_memory_s']*1e3:.2f} "
            f"| {c['t_collective_s']*1e3:.2f} | {c['bottleneck']} "
            f"| {c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} "
            f"| {c['bytes_per_device_gb']:.2f} |"
        )

    # summary stats
    from collections import Counter
    bn = Counter(c["bottleneck"] for c in ok)
    print(f"\nbottleneck distribution: {dict(bn)}")
    worst = sorted(ok, key=lambda c: c["roofline_fraction"])[:6]
    print("\nworst roofline fractions:")
    for c in worst:
        print(f"  {c['arch']} {c['shape']} {c['mesh']}: "
              f"{c['roofline_fraction']:.4f} ({c['bottleneck']})")
    collbound = sorted(ok, key=lambda c: -(c["t_collective_s"] /
                       max(c["t_compute_s"] + c["t_memory_s"], 1e-12)))[:6]
    print("\nmost collective-bound:")
    for c in collbound:
        print(f"  {c['arch']} {c['shape']} {c['mesh']}: "
              f"x/{'{c+m}'}={c['t_collective_s']/max(c['t_compute_s']+c['t_memory_s'],1e-12):.2f}")


if __name__ == "__main__":
    main(*sys.argv[1:])
