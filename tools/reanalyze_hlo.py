"""Re-derive roofline terms for every cached HLO (results/hlo/*.hlo.gz)
with the CURRENT hlo_cost analyzer — no recompilation.

Merges with the existing dryrun json (keeps mem/dev + compile times) and
rewrites results/dryrun_all.json.
"""
import glob
import gzip
import json
import os
import re
import sys

sys.path.insert(0, "src")

from repro.configs import SHAPES, get_config
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_for


def main(hlo_dir="results/hlo", json_path="results/dryrun_all.json",
         extra_jsons=("results/dry_vlm.json",)):
    old = {}
    for path in (json_path,) + tuple(extra_jsons):
        if os.path.exists(path):
            with open(path) as f:
                for c in json.load(f).get("ok", []):
                    old[(c["arch"], c["shape"], c["mesh"])] = c

    rows = []
    for fn in sorted(glob.glob(os.path.join(hlo_dir, "*.hlo.gz"))):
        base = os.path.basename(fn)[: -len(".hlo.gz")]
        m = re.match(r"(.+)_(train_4k|prefill_32k|decode_32k|long_500k)_(.+)$", base)
        if not m:
            print("skip", base)
            continue
        arch, shape_name, mesh = m.groups()
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        with gzip.open(fn, "rt") as f:
            cost = analyze_hlo(f.read())
        n_dev = 512 if mesh == "2x16x16" else 256
        t_c = cost.flops / PEAK_FLOPS
        t_m = cost.bytes_accessed / HBM_BW
        t_x = cost.collective_bytes / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        prev = old.get((arch, shape_name, mesh), {})
        mf = model_flops_for(cfg, shape)
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": mesh,
            "kind": prev.get("kind", shape.kind), "ok": True,
            "lower_s": prev.get("lower_s"), "compile_s": prev.get("compile_s"),
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": max(terms, key=terms.get),
            "hlo_gflops": cost.flops / 1e9,
            "hlo_gbytes": cost.bytes_accessed / 1e9,
            "coll_gbytes": cost.collective_bytes / 1e9,
            "model_gflops": mf / 1e9,
            "useful_ratio": mf / (cost.flops * n_dev) if cost.flops else 0.0,
            "roofline_fraction": t_c / max(terms.values()) if max(terms.values()) else 0.0,
            "bytes_per_device_gb": prev.get("bytes_per_device_gb", 0.0),
            "collectives": {k: {"bytes": int(v)} for k, v in
                            cost.collective_by_kind.items()},
        })
        print(f"{arch:24s} {shape_name:12s} {mesh:8s} "
              f"c={t_c*1e3:10.2f} m={t_m*1e3:12.2f} x={t_x*1e3:10.2f} ms "
              f"useful={rows[-1]['useful_ratio']:.2f}")

    with open(json_path, "w") as f:
        json.dump({"ok": rows, "failed": []}, f, indent=1)
    print(f"\nwrote {json_path} with {len(rows)} cells")


if __name__ == "__main__":
    main(*sys.argv[1:])
