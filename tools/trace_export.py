"""Export serving telemetry as chrome-tracing (Perfetto-loadable) JSON.

``serve_detailed`` stamps span events on each ``RequestRecord`` (admit /
decode / preempt / shed / finish; see resilience.RequestRecord) and one
counter sample per dispatched round on ``ServeReport.counters``.  This
module renders them in the Trace Event Format that chrome://tracing and
https://ui.perfetto.dev load directly:

* one process (pid) per engine replica, one thread track (tid) per batch
  slot — ``ph:"X"`` complete events for decode rounds (they have extent),
  ``ph:"i"`` instants for admit/preempt/shed/finish;
* counter tracks (``ph:"C"``) for free/retained pages, pages in use,
  cumulative prefix-hit tokens, effective speculation k, queue depth and
  retries.

Timestamps are the engine clock (VirtualClock under the benches) in
seconds, scaled to the format's microseconds — so traces are
deterministic: same trace + policy + seed => byte-identical JSON.

CLI: ``python tools/trace_export.py --validate trace.json`` exits
non-zero unless the file parses and passes ``validate_trace`` (used by CI
before uploading the bench smoke's trace artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

_US = 1e6   # engine-clock seconds -> trace microseconds

_COUNTER_KEYS = ("free_pages", "retained_pages", "pages_in_use",
                 "prefix_hit_tokens", "eff_k", "queued", "retries")
_INSTANT = ("admit", "preempt", "shed", "finish")


def _meta(pid: int, name: str, tid: int = 0, kind: str = "process_name"):
    return {"name": kind, "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}}


def report_to_trace(report, pid: int = 0, process_name: str = "engine",
                    request_offset: int = 0) -> dict:
    """Render one ``ServeReport`` as a trace dict (``{"traceEvents": []}``).

    ``request_offset`` shifts the request indices baked into event names
    (the router passes each replica's global indices through per-record
    ``rid`` events instead, so it leaves this at 0 and relies on pids)."""
    ev: list[dict] = [_meta(pid, process_name)]
    tids = set()
    for i, rec in enumerate(report.records):
        label = f"req{request_offset + i}"
        for e in rec.events:
            tid = int(e.get("slot", rec.slot if rec.slot is not None else 0)
                      or 0)
            tids.add(tid)
            args = {k: v for k, v in e.items()
                    if k not in ("name", "ts", "dur", "slot")}
            args["request"] = request_offset + i
            if e["name"] == "decode":
                ev.append({"name": f"decode {label}", "ph": "X",
                           "pid": pid, "tid": tid,
                           "ts": e["ts"] * _US,
                           "dur": max(e.get("dur", 0.0), 0.0) * _US,
                           "cat": "decode", "args": args})
            elif e["name"] in _INSTANT:
                ev.append({"name": f"{e['name']} {label}", "ph": "i",
                           "pid": pid, "tid": tid, "ts": e["ts"] * _US,
                           "s": "t", "cat": e["name"], "args": args})
    for tid in sorted(tids):
        ev.append(_meta(pid, f"slot {tid}", tid, "thread_name"))
    for c in report.counters:
        ts = c.get("ts", 0.0) * _US
        for k in _COUNTER_KEYS:
            if k in c:
                ev.append({"name": k, "ph": "C", "pid": pid, "tid": 0,
                           "ts": ts, "args": {k: c[k]}})
    return {"traceEvents": ev,
            "displayTimeUnit": "ms",
            "otherData": {"rounds": report.rounds,
                          "prefix_hits": report.prefix_hits,
                          "prefix_hit_tokens": report.prefix_hit_tokens,
                          "prefill_tokens": report.prefill_tokens,
                          "cow_forks": report.cow_forks,
                          "evictions": report.evictions}}


def router_report_to_trace(router_report) -> dict:
    """Render a ``RouterReport``: one pid per replica, merged into a
    single trace so Perfetto shows the fleet side by side."""
    events: list[dict] = []
    other = {}
    for r, rep in enumerate(router_report.replica_reports):
        sub = report_to_trace(rep, pid=r, process_name=f"replica {r}")
        events.extend(sub["traceEvents"])
        other[f"replica{r}"] = sub["otherData"]
    other["assignments"] = list(map(int, router_report.assignments))
    other["affinity_hits"] = int(router_report.affinity_hits)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


# ------------------------------------------------------------------ checks --
_PH_KNOWN = {"X", "B", "E", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


def validate_trace(obj) -> int:
    """Structural check that ``obj`` is Perfetto-loadable Trace Event JSON.
    Returns the event count; raises ``ValueError`` with a pointed message
    otherwise (CI gates the bench-smoke artifact upload on this)."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    evs = obj["traceEvents"]
    if not isinstance(evs, list) or not evs:
        raise ValueError("'traceEvents' must be a non-empty array")
    for n, e in enumerate(evs):
        where = f"traceEvents[{n}]"
        if not isinstance(e, dict):
            raise ValueError(f"{where}: not an object")
        ph = e.get("ph")
        if ph not in _PH_KNOWN:
            raise ValueError(f"{where}: unknown phase {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"{where}: missing event name")
        if not isinstance(e.get("pid"), int):
            raise ValueError(f"{where}: pid must be an integer")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"{where}: ts must be a number >= 0")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: complete event needs dur >= 0")
        if ph == "C":
            args = e.get("args")
            if (not isinstance(args, dict) or not args or
                    not all(isinstance(v, (int, float))
                            for v in args.values())):
                raise ValueError(
                    f"{where}: counter args must be numeric values")
    json.dumps(obj)   # must round-trip: no numpy scalars etc. left inside
    return len(evs)


def _jsonable(obj):
    """Coerce numpy scalars so ``json.dump`` (and Perfetto) accept them."""
    import numpy as np
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def write_trace(trace: dict, path: str) -> int:
    """Validate then write ``trace`` to ``path``; returns event count."""
    trace = _jsonable(trace)
    n = validate_trace(trace)
    with open(path, "w") as f:
        json.dump(trace, f, separators=(",", ":"))
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--validate", metavar="TRACE_JSON", required=True,
                    help="validate an exported chrome-trace JSON file")
    args = ap.parse_args(argv)
    try:
        with open(args.validate) as f:
            obj = json.load(f)
        n = validate_trace(obj)
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"INVALID {args.validate}: {e}", file=sys.stderr)
        return 1
    print(f"OK {args.validate}: {n} events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
