"""Decode fast-path benchmark: scan-compiled generation vs the seed loop.

Measures, on the registry's reduced configs (CPU proxy — the relative
numbers are what matter; the roofline report converts HBM-byte counts into
TPU time):

  * tokens/sec of the scan-compiled ``ServingEngine.generate`` (single-pass
    prefill + ``lax.scan`` decode, ONE XLA program) for dense / INT8 / INT4
    weight storage;
  * weight-bytes/token — the HBM bytes streamed per decode step, the
    quantity PIM storage actually improves (paper Fig 7);
  * the head-to-head vs the seed per-token Python loop
    (``generate_reference``) at batch 4, prompt 64, 32 new tokens — the
    dispatch-overhead tax the tentpole removes;
  * the ``--devices N`` axis: the INT8 engine single-device vs
    tensor-sharded over an N-virtual-device ``"model"`` mesh
    (``--xla_force_host_platform_device_count``), recording tokens/sec AND
    weight-bytes-streamed-per-device — on real hardware the per-device
    weight stream is what bounds memory-bound decode, so its 1/N drop is
    the PiCaSO scaling story (virtual CPU devices share one socket, so the
    tokens/sec column is a collectives-overhead proxy, not a speedup);
  * the ``--speculate K`` axis: plain vs speculative multi-token decode
    (n-gram proposer + one verify forward per window), under greedy decode
    AND ``--temperature T`` sampling (rejection-sampling verification),
    recording tokens/sec, emitted-tokens-per-verify-step and the
    per-window acceptance rate — each verify step streams the weights
    ONCE, so emitted/step is tokens-per-weight-stream, the multiplier on
    the weight-bytes-per-token win.

Writes ``BENCH_decode.json`` (repo root) for the PR-over-PR perf trajectory.
Run: ``python benchmarks/decode_bench.py`` (add ``--quick`` for CI smoke).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

ARCHS = ["qwen2-1.5b", "llama3.2-3b", "starcoder2-7b"]
BITS = [0, 8, 4]  # dense / INT8 / INT4 PIM storage


def _timed(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def bench_grid(archs, batch: int, prompt_len: int, n_new: int, reps: int):
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.engine import pim_bytes

    rows = []
    for arch in archs:
        cfg = get_reduced(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)
        for bits in BITS:
            eng = ServingEngine(cfg, params, max_seq=prompt_len + n_new,
                                pim_bits=bits)
            dt = _timed(lambda: eng.generate(prompt, n_new=n_new), reps)
            wbytes = pim_bytes(eng.params)
            rows.append({
                "arch": arch,
                "bits": bits,
                "batch": batch,
                "prompt": prompt_len,
                "new_tokens": n_new,
                "sec_per_call": dt,
                "tokens_per_sec": batch * n_new / dt,
                # every matmul weight is streamed once per decode step
                "weight_bytes_per_token": wbytes,
            })
            print(f"{arch:16s} bits={bits}  {rows[-1]['tokens_per_sec']:10.1f} tok/s"
                  f"  {wbytes/1e6:8.3f} MB weights/token")
    return rows


def bench_fastpath_vs_seed(arch: str, batch: int, prompt_len: int, n_new: int,
                           reps: int):
    """The acceptance comparison: scan-compiled generate vs the seed
    per-token loop, identical model and greedy decoding."""
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)
    eng = ServingEngine(cfg, params, max_seq=prompt_len + n_new, pim_bits=8)

    fast = _timed(lambda: eng.generate(prompt, n_new=n_new), reps)
    seed = _timed(lambda: eng.generate_reference(prompt, n_new=n_new),
                  max(1, reps // 2))
    same = bool(np.array_equal(np.asarray(eng.generate(prompt, n_new=n_new)),
                               np.asarray(eng.generate_reference(prompt, n_new=n_new))))
    out = {
        "arch": arch,
        "batch": batch,
        "prompt": prompt_len,
        "new_tokens": n_new,
        "seed_loop_tokens_per_sec": batch * n_new / seed,
        "fastpath_tokens_per_sec": batch * n_new / fast,
        "speedup": seed / fast,
        "tokens_match_seed": same,
    }
    print(f"fastpath vs seed ({arch}, b={batch}, s={prompt_len}, n={n_new}): "
          f"{out['speedup']:.1f}x  (seed {out['seed_loop_tokens_per_sec']:.1f} -> "
          f"fast {out['fastpath_tokens_per_sec']:.1f} tok/s, "
          f"tokens match: {same})")
    return out


def bench_speculative(archs, batch: int, prompt_len: int, n_new: int,
                      reps: int, speculate: int, temperature: float):
    """The speculation axis: INT8 engine, ``--speculate K`` vs the plain
    scan (K=0), under greedy decode AND temperature sampling
    (``--temperature T``: rejection-sampling verification).  Records
    tokens/sec, the realised emitted-tokens-per-verify-step (each verify
    step streams the weight tree ONCE, so emitted/step is the
    tokens-per-weight-stream multiplier on the weight-bytes-per-token
    bound the grid section records) and the per-window acceptance rate
    (``acceptance_per_live_row`` — per-row tokens per live verify window,
    the proposer-quality number sampling moves)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServingEngine, SpecConfig

    rows = []
    for arch in archs:
        cfg = get_reduced(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)
        eng = ServingEngine(cfg, params, max_seq=prompt_len + n_new,
                            pim_bits=8)
        modes = [(True, 0.0)]
        if temperature > 0:
            modes.append((False, temperature))
        for greedy, temp in modes:
            for k in (0, speculate):
                spec = SpecConfig(k=k) if k else None
                dt = _timed(lambda: eng.generate(
                    prompt, n_new=n_new, speculate=spec, greedy=greedy,
                    temperature=temp or 1.0,
                    key=jax.random.PRNGKey(2)), reps)
                row = {
                    "arch": arch,
                    "speculate_k": k,
                    "greedy": greedy,
                    "temperature": None if greedy else temp,
                    "tokens_per_sec": batch * n_new / dt,
                    "emitted_per_step": (eng.spec_stats["emitted_per_step"]
                                         if k else 1.0),
                    "acceptance_per_live_row": (
                        eng.spec_stats["acceptance_per_live_row"]
                        if k else 1.0),
                }
                if k:
                    base = [r for r in rows
                            if r["arch"] == arch and r["speculate_k"] == 0
                            and r["greedy"] == greedy][0]
                    row["speedup_vs_plain"] = (row["tokens_per_sec"]
                                               / base["tokens_per_sec"])
                rows.append(row)
                tag = "greedy" if greedy else f"T={temp}"
                extra = (f"  {row.get('speedup_vs_plain', 1.0):5.2f}x, "
                         f"{row['emitted_per_step']:.2f} tok/stream, "
                         f"{row['acceptance_per_live_row']:.2f} acc/window"
                         if k else "")
                print(f"{arch:16s} speculate={k} {tag:8s} "
                      f"{row['tokens_per_sec']:10.1f} tok/s{extra}")
    return rows


def bench_sharded(archs, batch: int, prompt_len: int, n_new: int, reps: int,
                  devices: int):
    """The multi-device axis: the INT8 engine on one device vs tensor-
    sharded over a ``devices``-wide 'model' mesh — tokens/sec plus the
    weight bytes ONE device holds/streams per token (total and per-device
    must differ by ~devices x for the distributed leaves)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServingEngine, make_decode_mesh, pim_bytes

    if len(jax.devices()) < devices:
        print(f"only {len(jax.devices())} devices visible; skipping the "
              f"--devices {devices} axis (set XLA_FLAGS before any jax import)")
        return []
    mesh = make_decode_mesh(devices)
    rows = []
    for arch in archs:
        cfg = get_reduced(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)
        for dc in (1, devices):
            eng = ServingEngine(cfg, params, max_seq=prompt_len + n_new,
                                pim_bits=8, mesh=None if dc == 1 else mesh)
            dt = _timed(lambda: eng.generate(prompt, n_new=n_new), reps)
            rows.append({
                "arch": arch,
                "devices": dc,
                "tokens_per_sec": batch * n_new / dt,
                "weight_bytes_total": pim_bytes(eng.params),
                "weight_bytes_per_device": pim_bytes(eng.params,
                                                     per_device=True),
            })
            r = rows[-1]
            print(f"{arch:16s} devices={dc}  {r['tokens_per_sec']:10.1f} tok/s"
                  f"  {r['weight_bytes_per_device']/1e6:8.3f} MB weights/device")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--devices", type=int, default=8,
                    help="width of the sharded-decode mesh axis (runs in a "
                    "subprocess with that many virtual host devices; "
                    "0/1 disables)")
    ap.add_argument("--speculate", type=int, default=4,
                    help="speculation window K for the --speculate axis "
                    "(K=0 plain vs K, n-gram proposer; 0 disables)")
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="adds a sampled leg to the --speculate axis: "
                    "rejection-sampling verification at this temperature, "
                    "recording acceptance rate and tokens-per-weight-"
                    "stream under sampling (0 disables)")
    ap.add_argument("--out", default=str(_ROOT / "BENCH_decode.json"))
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: one arch, tiny shapes")
    ap.add_argument("--sharded-only", action="store_true",
                    help=argparse.SUPPRESS)  # subprocess entry point
    args = ap.parse_args(argv)

    if args.quick:
        archs, batch, prompt, new, reps = ARCHS[:1], 2, 8, 4, 1
    else:
        archs, batch, prompt, new, reps = (ARCHS, args.batch, args.prompt,
                                           args.new_tokens, args.reps)

    if args.sharded_only:
        rows = bench_sharded(archs, batch, prompt, new, reps, args.devices)
        print("RESULT " + json.dumps(rows))
        return

    import jax

    result = {
        "bench": "decode_fastpath",
        "backend": jax.default_backend(),
        "note": ("reduced configs on CPU are a dispatch-overhead proxy; "
                 "weight_bytes_per_token is the HBM quantity PIM improves; "
                 "sharded.weight_bytes_per_device is what the mesh divides"),
        "grid": bench_grid(archs, batch, prompt, new, reps),
        "fastpath_vs_seed": bench_fastpath_vs_seed(
            archs[0], batch, prompt, new, reps),
    }
    if args.speculate > 0:
        result["speculative"] = {
            "k": args.speculate,
            "temperature": args.temperature,
            "grid": bench_speculative(archs, batch, prompt, new, reps,
                                      args.speculate, args.temperature),
        }
    if args.devices > 1:
        from bench_subproc import run_sharded_subprocess

        sub_args = ["--devices", str(args.devices), "--batch", str(args.batch),
                    "--prompt", str(args.prompt),
                    "--new-tokens", str(args.new_tokens),
                    "--reps", str(args.reps)] + (
                        ["--quick"] if args.quick else [])
        rows = run_sharded_subprocess(__file__, sub_args, args.devices)
        if rows:
            result["sharded"] = {"devices": args.devices, "grid": rows}
    out_path = Path(args.out)
    out_path.write_text(json.dumps(result, indent=2))
    print(f"wrote {out_path}")


# ------------------------------------------------------- run.py smoke hook --
def decode_smoke():
    """Tiny decode fast-path row set for the aggregate benchmark harness."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = get_reduced("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    rows = []
    for bits in (0, 8):
        eng = ServingEngine(cfg, params, max_seq=16, pim_bits=bits)
        dt = _timed(lambda: eng.generate(prompt, n_new=4), 2)
        rows.append((f"decode/scan_generate_bits{bits}", dt * 1e6,
                     f"{2 * 4 / dt:.1f} tok/s"))
    eng = ServingEngine(cfg, params, max_seq=16, pim_bits=8)
    dt = _timed(lambda: eng.generate_reference(prompt, n_new=4), 1)
    rows.append(("decode/seed_token_loop_bits8", dt * 1e6,
                 f"{2 * 4 / dt:.1f} tok/s (dispatch-bound baseline)"))
    return rows


ALL = [decode_smoke]


if __name__ == "__main__":
    main()
