"""Benchmark harness: one function per paper table/figure + kernel micro +
roofline report.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")


def main() -> None:
    from benchmarks import kernel_bench, paper_tables, roofline_report

    suites = paper_tables.ALL + kernel_bench.ALL + roofline_report.ALL
    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{suite.__name__}/ERROR,0.00,{type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
