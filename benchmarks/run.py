"""Benchmark harness: one function per paper table/figure + kernel micro +
roofline report.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))  # make `benchmarks` importable as a package


def main() -> None:
    from benchmarks import decode_bench, kernel_bench, paper_tables, roofline_report

    suites = (paper_tables.ALL + kernel_bench.ALL + roofline_report.ALL
              + decode_bench.ALL)
    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{suite.__name__}/ERROR,0.00,{type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
