"""Continuous-batching serving benchmark: paged scheduler vs fixed batch.

Drives a mixed-length Poisson request trace (prompt lengths and decode
budgets drawn from Poisson distributions — the arrival mix a real serving
queue sees) through both engines on a reduced config (CPU proxy; relative
numbers are what matter):

  * **fixed batch** — the scan-compiled ``ServingEngine.generate``: requests
    are grouped into batches of ``slots`` in arrival order, prompts padded
    to the global max, and every group decodes until its *longest* request
    finishes — short requests ride along, the dense cache preallocates
    ``slots * max_seq`` tokens.
  * **continuous** — ``ContinuousBatchingEngine``: finished requests retire
    at chunk boundaries and free their pages, queued requests admit into the
    freed slots, so wall-clock scales with *useful* tokens and peak cache
    memory scales with live tokens (pages in use), not ``slots * max_seq``.

Writes ``BENCH_serving.json`` (repo root): tokens/sec for both engines, the
speedup, and the cache-memory comparison (dense preallocation vs pool bytes
vs peak live page bytes).  ``--devices N`` adds the tensor-sharded axis: the
INT8 continuous engine on one device vs sharded over an N-virtual-device
``"model"`` mesh, recording tokens/sec and weight-bytes-per-device (the
quantity the mesh divides; virtual CPU devices share one socket, so
tokens/sec is a collectives-overhead proxy).  ``--speculate K`` adds the
speculation axis: the same trace through plain decode chunks vs n-gram
verify windows, under greedy decode and ``--temperature T`` sampling
(rejection-sampling verification — distribution-preserving), recording
useful tokens/sec, tokens-per-weight-stream (chunk iterations paid), and
per-slot window acceptance.  ``--fault-rate R1,R2,...`` adds the chaos
axis: the same trace under seeded fault injection (chunk faults,
stragglers, page squeezes at each rate) through the hardened
``serve_detailed`` path, recording goodput, SLO attainment, p50/p99
completion latency (virtual clock), shed/retried counts, and a
``non_shed_token_identical`` flag against the fault-free run —
``--deadline D`` additionally stamps every request with a D-virtual-
second deadline so load shedding and goodput-vs-throughput divergence
show up.  The ``prefix_router`` axis always runs: a repeated-system-prompt
Poisson trace through an uncached engine, a ``prefix_cache=True`` engine,
and a ``ReplicaRouter`` over ``--prefix-replicas`` cached replicas,
recording prefix hit rate, prefill-token savings, admission-to-first-token
(virtual seconds), and greedy+sampled token-identity to the uncached solo
baseline — plus a validated chrome-trace JSON of the router leg next to
``--out``.  Run ``python benchmarks/serving_bench.py`` (``--smoke`` for CI).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import numpy as np

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))


def make_trace(n_requests: int, mean_prompt: int, mean_new: int,
               max_prompt: int, max_new_cap: int, vocab: int, seed: int,
               long_frac: float = 0.25, mean_new_long: int = 0):
    """Mixed-length Poisson trace: prompt lengths and decode budgets are
    Poisson draws; a ``long_frac`` fraction of requests draws its budget
    from a long-tail Poisson (``mean_new_long``) — the short/long request
    mix where fixed batching makes short requests ride along with the
    longest group member."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        mean = (mean_new_long
                if mean_new_long and rng.random() < long_frac else mean_new)
        plen = int(np.clip(rng.poisson(mean_prompt), 2, max_prompt))
        max_new = int(np.clip(rng.poisson(mean), 2, max_new_cap))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append(Request(prompt=prompt, max_new=max_new))
    return reqs


def scaled_config(cfg):
    """The scaled-up smoke config: the raw reduced config is so small that
    per-step compute is dwarfed by dispatch; ONE definition so every
    section of BENCH_serving.json measures the same model."""
    return cfg.replace(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                       head_dim=32, d_ff=1024)


def trace_for(kw: dict, arch: str):
    """The benchmark trace for a parsed ``kw`` dict — one construction
    shared by the main comparison, the --sharded axis, and the --speculate
    axis, so all three measure the same workload."""
    from repro.configs import get_reduced

    return make_trace(
        kw["n_requests"], kw["mean_prompt"], kw["mean_new"],
        kw["max_prompt"], kw["max_new_cap"], get_reduced(arch).vocab,
        kw["seed"], long_frac=kw["long_frac"],
        mean_new_long=kw["mean_new_long"])


def pool_geometry(slots: int, page_size: int, max_prompt: int,
                  max_new_cap: int, pool_frac: float) -> tuple[int, int]:
    """(max_seq, num_pages) — ONE formula for the main comparison and the
    --devices axis, so the two sections of BENCH_serving.json always
    benchmark the same pool."""
    max_seq = max_prompt + max_new_cap
    max_seq += -max_seq % page_size
    width = max_seq // page_size
    num_pages = max(width + 2, int(pool_frac * slots * width)) + 1
    return max_seq, num_pages


def tree_bytes(shape_tree) -> int:
    import jax

    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(shape_tree)))


def run_fixed(engine, requests, slots: int, max_prompt: int) -> int:
    """The fixed-batch server: arrival-order groups of ``slots``, prompts
    padded to the global max prompt, decode until the group's longest
    request is done.  Returns useful (kept) tokens."""
    import jax
    import jax.numpy as jnp

    useful = 0
    for i in range(0, len(requests), slots):
        group = requests[i : i + slots]
        prompts = np.zeros((len(group), max_prompt), np.int32)
        for j, r in enumerate(group):
            prompts[j, : len(r.prompt)] = r.prompt
        n_new = max(r.max_new for r in group)
        out = engine.generate(jnp.asarray(prompts), n_new=n_new)
        jax.block_until_ready(out)
        useful += sum(min(r.max_new, n_new) for r in group)
    return useful


def run_continuous(engine, requests) -> int:
    outs = engine.serve(requests)
    return sum(len(o) for o in outs)


def bench(arch: str, n_requests: int, slots: int, page_size: int, chunk: int,
          mean_prompt: int, mean_new: int, mean_new_long: int,
          long_frac: float, max_prompt: int, max_new_cap: int,
          pool_frac: float, seed: int, scale: bool) -> dict:
    import jax
    from repro.configs import get_reduced
    from repro.models import init_cache, init_paged_cache, init_params
    from repro.serving import ContinuousBatchingEngine, ServingEngine

    cfg = get_reduced(arch)
    if scale:
        # dispatch would dwarf the raw reduced config's per-step compute,
        # flattering the zero-dispatch fixed scan; see scaled_config.
        cfg = scaled_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    requests = trace_for(
        dict(n_requests=n_requests, mean_prompt=mean_prompt,
             mean_new=mean_new, max_prompt=max_prompt,
             max_new_cap=max_new_cap, seed=seed, long_frac=long_frac,
             mean_new_long=mean_new_long), arch)
    max_seq, num_pages = pool_geometry(slots, page_size, max_prompt,
                                       max_new_cap, pool_frac)

    fixed = ServingEngine(cfg, params, max_seq=max_seq)
    cont = ContinuousBatchingEngine(
        cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
        num_pages=num_pages, chunk=chunk)

    # Warm (compile) both paths, then time a second identical run.
    run_fixed(fixed, requests, slots, max_prompt)
    run_continuous(cont, requests)

    t0 = time.perf_counter()
    useful_fixed = run_fixed(fixed, requests, slots, max_prompt)
    t_fixed = time.perf_counter() - t0
    t0 = time.perf_counter()
    useful_cont = run_continuous(cont, requests)
    t_cont = time.perf_counter() - t0

    # Cache memory: dense preallocation vs pool vs peak live pages.
    dense_cache = jax.eval_shape(lambda: init_cache(cfg, slots, max_seq))
    pool = jax.eval_shape(lambda: init_paged_cache(
        cfg, slots, max_seq, num_pages, page_size))
    pool1 = jax.eval_shape(lambda: init_paged_cache(
        cfg, slots, max_seq, num_pages + 1, page_size))
    page_bytes = tree_bytes(pool1) - tree_bytes(pool)  # one page, all layers
    peak_live_bytes = cont.peak_pages_in_use * page_bytes

    fixed_tps = useful_fixed / t_fixed
    cont_tps = useful_cont / t_cont
    return {
        "arch": arch,
        "trace": {
            "requests": n_requests, "slots": slots,
            "mean_prompt": mean_prompt, "mean_new": mean_new,
            "mean_new_long": mean_new_long, "long_frac": long_frac,
            "max_prompt": max_prompt, "max_new_cap": max_new_cap,
            "seed": seed,
            "prompt_lens": [len(r.prompt) for r in requests],
            "max_new": [r.max_new for r in requests],
        },
        "page_size": page_size, "chunk": chunk, "num_pages": num_pages,
        "max_seq": max_seq,
        "fixed_batch": {
            "wall_sec": t_fixed,
            "useful_tokens": useful_fixed,
            "tokens_per_sec": fixed_tps,
            "cache_bytes": tree_bytes(dense_cache),
        },
        "continuous": {
            "wall_sec": t_cont,
            "useful_tokens": useful_cont,
            "tokens_per_sec": cont_tps,
            "pool_bytes": tree_bytes(pool),
            "page_bytes": page_bytes,
            "peak_pages_in_use": cont.peak_pages_in_use,
            "peak_live_cache_bytes": peak_live_bytes,
            "preemptions": cont.preemptions,
        },
        "speedup_tokens_per_sec": cont_tps / fixed_tps,
        "peak_cache_vs_dense": peak_live_bytes / tree_bytes(dense_cache),
    }


def spec_config_for(mode: str, k: int):
    """``--spec-mode`` name -> SpecConfig.  ``fixed`` is the static
    window, ``adaptive`` the acceptance-EMA controller (collapses to
    plain decode when speculation is losing), ``tree`` a fan-2 depth-k/2
    multi-candidate draft with the same verify-node budget as ``fixed``
    (1 + fan*depth == k + 1 nodes), ``typical`` the lossy entropy-band
    acceptance on the fixed window."""
    from repro.serving import SpecConfig

    if mode == "fixed":
        return SpecConfig(k=k)
    if mode == "adaptive":
        return SpecConfig(k=k, adaptive=True)
    if mode == "tree":
        return SpecConfig(k=max(k // 2, 1), tree_fan=2)
    if mode == "typical":
        return SpecConfig(k=k, accept="typical")
    raise ValueError(f"unknown --spec-mode {mode!r}")


def bench_speculative(arch: str, requests, slots: int, page_size: int,
                      chunk: int, max_seq: int, num_pages: int,
                      speculate: int, temperature: float, scale: bool,
                      spec_modes=("fixed", "adaptive")) -> dict:
    """The speculation axis on the continuous engine: the SAME trace with
    ``speculate=0`` (plain chunks) vs each requested ``--spec-mode``
    (fixed / adaptive / tree / typical verify windows), under greedy
    decode AND ``--temperature T`` sampling (rejection-sampling
    verification), recording useful tokens/sec, ``emitted_per_stream``
    (batch-aggregate tokens per chunk iteration — each iteration streams
    the weight tree once, and it is computed for the plain row too, so the
    spec-row / 0-row ratio is the weight streams saved), and
    ``acceptance_per_live_window`` (per-slot window acceptance — the
    proposer-quality number that sampling moves).  ``typical`` is LOSSY
    and only meaningful under sampling, so its greedy leg is skipped
    (typical-with-greedy IS greedy acceptance)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ContinuousBatchingEngine

    cfg = get_reduced(arch)
    if scale:
        cfg = scaled_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    modes = [(True, 0.0)]
    if temperature > 0:
        modes.append((False, temperature))
    for greedy, temp in modes:
        for mode in (None, *spec_modes):
            if mode == "typical" and greedy:
                continue
            spec = spec_config_for(mode, speculate) if mode else None
            eng = ContinuousBatchingEngine(
                cfg, params, slots=slots, max_seq=max_seq,
                page_size=page_size, num_pages=num_pages, chunk=chunk,
                speculate=spec)
            serve = lambda: sum(len(o) for o in eng.serve(
                requests, greedy=greedy, temperature=temp or 1.0,
                key=jax.random.PRNGKey(2)))
            serve()  # warm/compile
            t0 = time.perf_counter()
            useful = serve()
            dt = time.perf_counter() - t0
            # every chunk iteration streams the weights once; admit tok0s
            # come from prefill, so chunk-emitted tokens exclude one per
            # request
            chunk_emitted = useful - len(requests)
            rows.append({
                "spec_mode": mode or "plain",
                "speculate_k": spec.k if spec else 0,
                "greedy": greedy,
                "temperature": None if greedy else temp,
                "useful_tokens": useful,
                "tokens_per_sec": useful / dt,
                "emitted_per_stream": chunk_emitted
                / max(eng.decode_chunk_iters, 1),
                "acceptance_per_live_window": (eng.spec_emitted
                                               / max(eng.spec_live_steps, 1)
                                               if mode else 1.0),
            })
            if mode:
                base = [r for r in rows if r["spec_mode"] == "plain"
                        and r["greedy"] == greedy][0]
                rows[-1]["speedup_vs_plain"] = (rows[-1]["tokens_per_sec"]
                                                / base["tokens_per_sec"])
            r = rows[-1]
            tag = "greedy" if greedy else f"T={temp}"
            print(f"spec={r['spec_mode']:8s} {tag}: "
                  f"{r['tokens_per_sec']:10.1f} useful tok/s, "
                  f"{r['emitted_per_stream']:.2f} tok/stream, "
                  f"{r['acceptance_per_live_window']:.2f} tok/live-window"
                  + (f", {r.get('speedup_vs_plain', 1.0):.2f}x"
                     if mode else ""))
    return {"k": speculate, "temperature": temperature,
            "modes": list(spec_modes), "grid": rows}


def make_repetitive_trace(n_requests: int, mean_new: int, vocab: int,
                          seed: int, period: int = 4, plen: int = 24):
    """The proposer-friendly counterpart of ``make_trace``: every prompt
    is a short random pattern tiled out to ``plen``, so the trailing
    n-gram always has an earlier occurrence and the continuation is
    genuinely predictable — structured/templated generation (code, JSON,
    retrieval-echo) rather than open-ended prose."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        pat = rng.integers(0, vocab, size=period).astype(np.int32)
        prompt = np.tile(pat, plen // period + 1)[:plen]
        max_new = int(np.clip(rng.poisson(mean_new), 2, 4 * mean_new))
        reqs.append(Request(prompt=prompt, max_new=max_new))
    return reqs


def bench_repetitive(arch: str, slots: int, page_size: int, chunk: int,
                     speculate: int, seed: int, scale: bool,
                     n_requests: int = 16, mean_new: int = 48) -> dict:
    """The workload speculation exists for: repetitive/templated text
    where the n-gram proposer is near-perfect.  Plain decode vs the
    adaptive controller on the SAME repetitive trace, greedy — the
    controller must discover the high acceptance rate and hold the window
    wide (the acceptance bar: >= 1.5x plain wall-clock).  The window cap
    is ``2 * speculate``: with a measured per-extra-token window cost of
    ~ctrl_cost decode steps, the achievable speedup is roughly
    ``(a + 1) / (1 + ctrl_cost * k)``, so near-perfect acceptance wants
    DEEP windows — exactly the asymmetry the controller exploits (deep
    when winning, k=0 when losing) that a fixed k cannot."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ContinuousBatchingEngine

    cfg = get_reduced(arch)
    if scale:
        cfg = scaled_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    requests = make_repetitive_trace(n_requests, mean_new, cfg.vocab, seed)
    max_seq, num_pages = pool_geometry(slots, page_size, 24,
                                       4 * mean_new, 1.0)
    rows = []
    for mode in (None, "adaptive"):
        spec = spec_config_for(mode, 2 * speculate) if mode else None
        eng = ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            num_pages=num_pages, chunk=chunk, speculate=spec)
        serve = lambda: sum(len(o) for o in eng.serve(requests))
        serve()  # warm/compile
        t0 = time.perf_counter()
        useful = serve()
        dt = time.perf_counter() - t0
        rows.append({
            "spec_mode": mode or "plain",
            "useful_tokens": useful,
            "tokens_per_sec": useful / dt,
            "acceptance_per_live_window": (eng.spec_emitted
                                           / max(eng.spec_live_steps, 1)
                                           if mode else 1.0),
        })
        if mode:
            rows[-1]["speedup_vs_plain"] = (rows[-1]["tokens_per_sec"]
                                            / rows[0]["tokens_per_sec"])
        r = rows[-1]
        print(f"repetitive spec={r['spec_mode']:8s}: "
              f"{r['tokens_per_sec']:10.1f} useful tok/s, "
              f"{r['acceptance_per_live_window']:.2f} tok/live-window"
              + (f", {r.get('speedup_vs_plain', 1.0):.2f}x" if mode else ""))
    return {"k": speculate, "requests": n_requests, "mean_new": mean_new,
            "grid": rows}


def bench_chaos(arch: str, requests, slots: int, page_size: int, chunk: int,
                max_seq: int, num_pages: int, fault_rates, deadline: float,
                seed: int, scale: bool) -> dict:
    """The robustness axis: the SAME trace through the hardened
    ``serve_detailed`` path at each injected fault rate (chunk faults +
    stragglers + page squeezes, all at rate R, one seeded injector per
    run).  Time runs on a virtual clock with ``round_time=1.0`` so
    deadlines, latency percentiles, and SLO attainment are DETERMINISTIC
    scheduling quantities (in virtual seconds ~ scheduling rounds), while
    goodput tokens/sec uses the wall clock.  Every row checks that all
    non-shed requests emitted exactly the fault-free run's tokens
    (``non_shed_token_identical`` — the PR-6 robustness bar, same
    assertion tests/test_chaos.py makes)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import (ChaosConfig, ContinuousBatchingEngine,
                               FaultInjector, ResiliencePolicy, VirtualClock)

    cfg = get_reduced(arch)
    if scale:
        cfg = scaled_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if deadline > 0:
        requests = [dataclasses.replace(r, deadline=deadline)
                    for r in requests]
    policy = ResiliencePolicy(round_time=1.0)
    key = jax.random.PRNGKey(2)
    base_outputs = None
    rows = []
    for rate in fault_rates:
        eng = ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            num_pages=num_pages, chunk=chunk, clock=VirtualClock())
        eng.serve_detailed(requests, policy=policy, key=key)  # warm/compile
        chaos = (FaultInjector(ChaosConfig(
            seed=seed, fault_rate=rate, straggle_rate=rate,
            squeeze_rate=rate)) if rate > 0 else None)
        eng2 = ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            num_pages=num_pages, chunk=chunk, clock=VirtualClock())
        t0 = time.perf_counter()
        report = eng2.serve_detailed(requests, policy=policy, chaos=chaos,
                                     key=key)
        dt = time.perf_counter() - t0
        if base_outputs is None:  # first row must be the fault-free run
            assert rate == 0
            base_outputs = [r.tokens for r in report.records]
        parity = all(
            np.array_equal(base_outputs[i], rec.tokens)
            for i, rec in enumerate(report.records) if rec.status == "done")
        lat = sorted(report.latencies())
        pct = lambda q: (float(lat[min(len(lat) - 1,
                                       int(q * (len(lat) - 1)))])
                         if lat else None)
        statuses = [r.status for r in report.records]
        rows.append({
            "fault_rate": rate,
            "goodput_tokens": report.goodput_tokens(),
            "goodput_tokens_per_sec": report.goodput_tokens() / dt,
            "slo_attainment": report.slo_attainment(),
            "p50_latency_vsec": pct(0.50),
            "p99_latency_vsec": pct(0.99),
            "done": statuses.count("done"),
            "shed": report.sheds,
            "rejected": report.rejects,
            "retried_chunks": report.retries,
            "straggle_vsec": report.straggle_s,
            "squeezed_pages": report.squeezed_pages,
            "max_ladder_level": report.max_ladder_level,
            "rounds": report.rounds,
            "non_shed_token_identical": parity,
        })
        r = rows[-1]
        print(f"fault_rate={rate}: {r['goodput_tokens_per_sec']:10.1f} "
              f"goodput tok/s, SLO {r['slo_attainment']:.2f}, "
              f"p50/p99 {r['p50_latency_vsec']}/{r['p99_latency_vsec']} "
              f"vsec, {r['retried_chunks']} retries, {r['shed']} shed, "
              f"parity={r['non_shed_token_identical']}")
    return {"fault_rates": list(fault_rates), "deadline": deadline or None,
            "round_time_vsec": 1.0, "chaos_seed": seed, "grid": rows}


def bench_sharded(arch: str, requests, slots: int, page_size: int, chunk: int,
                  max_seq: int, num_pages: int, devices: int) -> dict:
    """Continuous engine, INT8 weights, single-device vs mesh-sharded on the
    SAME trace: tokens/sec per device count + the per-device weight bytes."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import (ContinuousBatchingEngine, make_decode_mesh,
                               pim_bytes)

    if len(jax.devices()) < devices:
        print(f"only {len(jax.devices())} devices visible; skipping the "
              f"--devices {devices} axis")
        return {}
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_decode_mesh(devices)
    rows = []
    for dc in (1, devices):
        eng = ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            num_pages=num_pages, chunk=chunk, pim_bits=8,
            mesh=None if dc == 1 else mesh)
        run_continuous(eng, requests)  # warm/compile
        t0 = time.perf_counter()
        useful = run_continuous(eng, requests)
        dt = time.perf_counter() - t0
        rows.append({
            "devices": dc,
            "useful_tokens": useful,
            "tokens_per_sec": useful / dt,
            "weight_bytes_total": pim_bytes(eng.params),
            "weight_bytes_per_device": pim_bytes(eng.params, per_device=True),
        })
        print(f"sharded devices={dc}: {rows[-1]['tokens_per_sec']:10.1f} "
              f"useful tok/s, "
              f"{rows[-1]['weight_bytes_per_device']/1e6:.3f} MB/device")
    return {"devices": devices, "grid": rows}


def make_system_prompt_trace(n_requests: int, n_system: int, sys_len: int,
                             max_tail: int, mean_new: int, max_new_cap: int,
                             vocab: int, seed: int, arrival_rate: float,
                             deadline_slack: float = 30.0):
    """The prefix-cache workload: a Poisson arrival process where every
    prompt is one of ``n_system`` repeated system prompts (page-aligned,
    ``sys_len`` tokens) plus a short random user tail — the
    few-templates/many-users mix where shared-prefix deduplication pays.
    Mixed SLOs: roughly half the requests carry a ``deadline_slack``
    deadline and a random priority class, so load shedding and SLO
    attainment stay live quantities on this axis too."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, vocab, size=sys_len).astype(np.int32)
                   for _ in range(n_system)]
    t, reqs = 0.0, []
    for _ in range(n_requests):
        t += float(rng.exponential(1.0 / arrival_rate))
        sp = sys_prompts[int(rng.integers(n_system))]
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(0, max_tail + 1))
                            ).astype(np.int32)
        max_new = int(np.clip(rng.poisson(mean_new), 2, max_new_cap))
        deadline = (t + deadline_slack
                    if deadline_slack and rng.random() < 0.5 else None)
        reqs.append(Request(prompt=np.concatenate([sp, tail]),
                            max_new=max_new, arrival=t, deadline=deadline,
                            slo=int(rng.integers(1, 4))))
    return reqs


def bench_prefix_router(arch: str, slots: int, page_size: int, chunk: int,
                        seed: int, n_requests: int, n_system: int,
                        replicas: int, temperature: float,
                        out_path: str) -> dict:
    """The prefix-cache + fleet axis: the SAME repeated-system-prompt
    Poisson trace through (a) an uncached solo engine, (b) a
    ``prefix_cache=True`` solo engine, and (c) a ``ReplicaRouter`` over
    ``replicas`` cached engines — greedy AND sampled legs, all on a
    virtual clock with ``round_time=1.0`` and a pool sized to HALF the
    dense worst case so page pressure binds (uncached admission blocks on
    pages; cached admission aliases the shared prefix and fits).
    Records the prefix hit rate, prefill-token savings (the >=30%
    acceptance bar), mean admission-to-first-token (``t_first`` minus
    arrival, virtual seconds — deterministic), CoW/eviction counts, and
    token-identity flags of every leg against the uncached solo baseline
    (done-in-both requests).  Uses the RAW reduced config: every metric
    on this axis is a token count or virtual-time scheduling quantity,
    not wall throughput.  The router (greedy) leg's telemetry is exported
    as chrome-trace JSON next to ``out_path`` and validated before the
    bench reports it (tools/trace_export.py)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import (ContinuousBatchingEngine, ReplicaRouter,
                               ResiliencePolicy, VirtualClock)

    sys.path.insert(0, str(_ROOT / "tools"))
    import trace_export

    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    sys_len, max_tail = 4 * page_size, 2 * page_size
    mean_new, max_new_cap = 6, 12
    requests = make_system_prompt_trace(
        n_requests, n_system, sys_len, max_tail, mean_new, max_new_cap,
        cfg.vocab, seed, arrival_rate=2.0)
    max_seq, num_pages = pool_geometry(slots, page_size, sys_len + max_tail,
                                       max_new_cap, 0.5)
    policy = ResiliencePolicy(round_time=1.0)
    key = jax.random.PRNGKey(2)

    def mk(prefix: bool):
        return ContinuousBatchingEngine(
            cfg, params, slots=slots, max_seq=max_seq, page_size=page_size,
            num_pages=num_pages, chunk=chunk, prefix_cache=prefix,
            clock=VirtualClock())

    def ident(base, test) -> bool:
        return all(np.array_equal(b.tokens, t.tokens)
                   for b, t in zip(base.records, test.records)
                   if b.status == "done" and t.status == "done")

    def admit_to_first(report):
        vals = [rec.t_first - req.arrival
                for rec, req in zip(report.records, requests)
                if rec.status == "done" and rec.t_first is not None]
        return float(np.mean(vals)) if vals else None

    legs = {}
    for tag, greedy in (("greedy", True), ("sampled", False)):
        kwd = dict(greedy=greedy, temperature=temperature or 0.8, top_k=20,
                   key=key, policy=policy)
        t0 = time.perf_counter()
        un = mk(False).serve_detailed(requests, **kwd)
        t_un = time.perf_counter() - t0
        t0 = time.perf_counter()
        ca = mk(True).serve_detailed(requests, **kwd)
        t_ca = time.perf_counter() - t0
        router = ReplicaRouter([mk(True) for _ in range(replicas)])
        rr = router.serve_detailed(requests, **kwd)
        legs[tag] = dict(un=un, ca=ca, rr=rr, t_un=t_un, t_ca=t_ca,
                         ident_cached=ident(un, ca),
                         ident_router=ident(un, rr))
        print(f"prefix {tag}: hits {ca.prefix_hits}/{n_requests}, prefill "
              f"{ca.prefill_tokens} vs {un.prefill_tokens} uncached tokens, "
              f"cow {ca.cow_forks}, evict {ca.evictions}, "
              f"identical cached={legs[tag]['ident_cached']} "
              f"router={legs[tag]['ident_router']}")

    g = legs["greedy"]
    un, ca, rr = g["un"], g["ca"], g["rr"]
    savings = 1.0 - ca.prefill_tokens / max(un.prefill_tokens, 1)
    a2f_un, a2f_ca = admit_to_first(un), admit_to_first(ca)
    trace_path = str(Path(out_path).with_suffix("")) + ".trace.json"
    n_events = trace_export.write_trace(
        trace_export.router_report_to_trace(rr), trace_path)
    print(f"prefix hit rate {ca.prefix_hits / n_requests:.2f}, prefill "
          f"savings {100 * savings:.0f}%, admit-to-first "
          f"{a2f_un:.2f} -> {a2f_ca:.2f} vsec, trace {trace_path} "
          f"({n_events} events)")
    return {
        "requests": n_requests,
        "system_prompts": n_system,
        "page_size": page_size,
        "num_pages": num_pages,
        "replica_count": replicas,
        "round_time_vsec": 1.0,
        "prefix_hit_rate": ca.prefix_hits / n_requests,
        "prefix_hit_tokens": ca.prefix_hit_tokens,
        "prefill_tokens_uncached": un.prefill_tokens,
        "prefill_tokens_cached": ca.prefill_tokens,
        "prefill_savings_frac": savings,
        "admit_to_first_uncached_s": a2f_un,
        "admit_to_first_cached_s": a2f_ca,
        "wall_sec_uncached": g["t_un"],
        "wall_sec_cached": g["t_ca"],
        "done_uncached": len(un.done()),
        "done_cached": len(ca.done()),
        "shed_uncached": un.sheds,
        "shed_cached": ca.sheds,
        "cow_forks": ca.cow_forks,
        "evictions": ca.evictions,
        "token_identical_greedy": bool(g["ident_cached"]
                                       and g["ident_router"]),
        "token_identical_sampled": bool(legs["sampled"]["ident_cached"]
                                        and legs["sampled"]["ident_router"]),
        "router": {
            "replicas": replicas,
            "assignments": list(map(int, rr.assignments)),
            "affinity_hits": int(rr.affinity_hits),
            "prefix_hits": rr.prefix_hits,
            "prefill_tokens": rr.prefill_tokens,
            "token_identical": bool(g["ident_router"]
                                    and legs["sampled"]["ident_router"]),
        },
        "trace_file": Path(trace_path).name,
        "trace_events": n_events,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=12)
    ap.add_argument("--mean-prompt", type=int, default=24)
    ap.add_argument("--mean-new", type=int, default=8)
    ap.add_argument("--mean-new-long", type=int, default=48)
    ap.add_argument("--long-frac", type=float, default=0.25)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-new-cap", type=int, default=64)
    ap.add_argument("--pool-frac", type=float, default=0.6,
                    help="pool size as a fraction of the dense worst case")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-scale", action="store_true",
                    help="use the raw reduced config (per-step compute "
                    "too small to be representative)")
    ap.add_argument("--devices", type=int, default=8,
                    help="width of the sharded-decode mesh axis (runs in a "
                    "subprocess with that many virtual host devices; "
                    "0/1 disables)")
    ap.add_argument("--speculate", type=int, default=4,
                    help="speculation window K for the --speculate axis "
                    "(plain vs K on the same trace; 0 disables)")
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="adds a sampled leg to the --speculate axis: "
                    "rejection-sampling verification at this temperature, "
                    "recording acceptance rate and tokens-per-weight-"
                    "stream under sampling (0 disables)")
    ap.add_argument("--spec-mode", default="fixed,adaptive,tree,typical",
                    help="comma list from {fixed,adaptive,tree,typical}: "
                    "which speculation shapes the --speculate axis runs "
                    "against the plain baseline (typical is lossy and only "
                    "runs on the sampled leg)")
    ap.add_argument("--fault-rate", default="0,0.05",
                    help="comma list of injected fault rates for the chaos "
                    "axis (chunk faults + stragglers + page squeezes, "
                    "seeded); 0 is always run first as the parity/goodput "
                    "reference; empty string disables")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="stamp every request with this deadline in virtual "
                    "seconds (~scheduling rounds) on the chaos axis, so "
                    "shedding and SLO attainment bite (0 disables)")
    ap.add_argument("--prefix-replicas", type=int, default=2,
                    help="replica count for the prefix_router axis")
    ap.add_argument("--out", default=str(_ROOT / "BENCH_serving.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny trace, tiny shapes")
    ap.add_argument("--sharded-only", action="store_true",
                    help=argparse.SUPPRESS)  # subprocess entry point
    args = ap.parse_args(argv)

    if args.smoke:
        kw = dict(n_requests=6, slots=2, page_size=4, chunk=4,
                  mean_prompt=8, mean_new=6, mean_new_long=0, long_frac=0.0,
                  max_prompt=16, max_new_cap=12, pool_frac=0.75,
                  seed=args.seed, scale=False)
    else:
        kw = dict(n_requests=args.requests, slots=args.slots,
                  page_size=args.page_size, chunk=args.chunk,
                  mean_prompt=args.mean_prompt, mean_new=args.mean_new,
                  mean_new_long=args.mean_new_long, long_frac=args.long_frac,
                  max_prompt=args.max_prompt, max_new_cap=args.max_new_cap,
                  pool_frac=args.pool_frac, seed=args.seed,
                  scale=not args.no_scale)

    if args.sharded_only:
        from repro.configs import get_reduced

        max_seq, num_pages = pool_geometry(kw["slots"], kw["page_size"],
                                           kw["max_prompt"], kw["max_new_cap"],
                                           kw["pool_frac"])

        # Same trace as the main comparison, on the raw reduced config
        # (the scaled-up config exists to drown dispatch overhead, which
        # the 1-vs-N comparison does not need).
        requests = trace_for(kw, args.arch)
        sharded = bench_sharded(
            args.arch, requests, kw["slots"], kw["page_size"], kw["chunk"],
            max_seq, num_pages, args.devices)
        print("RESULT " + json.dumps(sharded))
        return

    import jax

    row = bench(args.arch, **kw)
    result = {
        "bench": "serving_continuous_batching",
        "backend": jax.default_backend(),
    }
    if args.speculate > 0:
        sp_max_seq, sp_num_pages = pool_geometry(
            kw["slots"], kw["page_size"], kw["max_prompt"],
            kw["max_new_cap"], kw["pool_frac"])
        spec_requests = trace_for(kw, args.arch)
        spec_modes = tuple(m for m in args.spec_mode.split(",") if m)
        result["speculative"] = bench_speculative(
            args.arch, spec_requests, kw["slots"], kw["page_size"],
            kw["chunk"], sp_max_seq, sp_num_pages, args.speculate,
            args.temperature, kw["scale"], spec_modes=spec_modes)
        result["speculative_repetitive"] = bench_repetitive(
            args.arch, kw["slots"], kw["page_size"], kw["chunk"],
            args.speculate, kw["seed"], kw["scale"],
            n_requests=4 if args.smoke else 16,
            mean_new=12 if args.smoke else 48)
    if args.fault_rate.strip():
        rates = sorted({float(r) for r in args.fault_rate.split(",")} | {0.0})
        ch_max_seq, ch_num_pages = pool_geometry(
            kw["slots"], kw["page_size"], kw["max_prompt"],
            kw["max_new_cap"], kw["pool_frac"])
        result["chaos"] = bench_chaos(
            args.arch, trace_for(kw, args.arch), kw["slots"],
            kw["page_size"], kw["chunk"], ch_max_seq, ch_num_pages, rates,
            args.deadline, kw["seed"], kw["scale"])
    result["prefix_router"] = bench_prefix_router(
        args.arch, kw["slots"], kw["page_size"], kw["chunk"], kw["seed"],
        n_requests=12 if args.smoke else 200,
        n_system=2 if args.smoke else 6,
        replicas=args.prefix_replicas, temperature=args.temperature,
        out_path=args.out)
    result.update({
        "note": ("reduced config on CPU: tokens/sec measures scheduling "
                 "efficiency (useful tokens vs ride-along waste); "
                 "peak_live_cache_bytes is the paged pool's high-water mark "
                 "vs the dense B*max_seq preallocation; "
                 "sharded.weight_bytes_per_device is what the mesh divides; "
                 "speculative.emitted_per_stream is batch-aggregate tokens "
                 "per weight stream (chunk iteration) for BOTH rows — the "
                 "K/0 ratio is the streams saved; acceptance_per_live_window "
                 "is the per-slot proposer acceptance"),
        **row,
    })
    if args.devices > 1:
        from bench_subproc import run_sharded_subprocess

        sub_args = ["--arch", args.arch, "--devices", str(args.devices),
                    "--seed", str(args.seed)] + (
                        ["--smoke"] if args.smoke else [
                            "--requests", str(args.requests),
                            "--slots", str(args.slots),
                            "--page-size", str(args.page_size),
                            "--chunk", str(args.chunk),
                            "--mean-prompt", str(args.mean_prompt),
                            "--mean-new", str(args.mean_new),
                            "--mean-new-long", str(args.mean_new_long),
                            "--long-frac", str(args.long_frac),
                            "--max-prompt", str(args.max_prompt),
                            "--max-new-cap", str(args.max_new_cap),
                            "--pool-frac", str(args.pool_frac)])
        sharded = run_sharded_subprocess(__file__, sub_args, args.devices)
        if sharded:  # None/{} when the subprocess saw too few devices
            result["sharded"] = sharded
    Path(args.out).write_text(json.dumps(result, indent=2))
    fx, ct = result["fixed_batch"], result["continuous"]
    print(f"fixed batch : {fx['tokens_per_sec']:10.1f} useful tok/s "
          f"({fx['useful_tokens']} tokens, cache {fx['cache_bytes']/1e6:.2f} MB)")
    print(f"continuous  : {ct['tokens_per_sec']:10.1f} useful tok/s "
          f"({ct['useful_tokens']} tokens, peak live cache "
          f"{ct['peak_live_cache_bytes']/1e6:.2f} MB, "
          f"{ct['preemptions']} preemptions)")
    print(f"speedup {result['speedup_tokens_per_sec']:.2f}x, peak cache "
          f"{100 * result['peak_cache_vs_dense']:.0f}% of dense")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
