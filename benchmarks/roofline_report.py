"""Roofline report: reads results/dryrun_all.json (written by the multi-pod
dry-run) and emits the per-cell roofline terms as CSV rows.  If the dry-run
results are absent it says so rather than recomputing (the 512-device
dry-run must not run inside the 1-device bench process)."""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun_all.json")
OPTIMIZED = "results/dryrun_optimized.json"


def roofline_rows():
    rows = []
    if not os.path.exists(RESULTS):
        rows.append(("roofline/missing", 0.0,
                     f"run `python -m repro.launch.dryrun --out {RESULTS}` first"))
        return rows
    with open(RESULTS) as f:
        data = json.load(f)
    for cell in data.get("ok", []):
        tag = f"{cell['arch']}/{cell['shape']}/{cell['mesh']}"
        rows.append((f"roofline/{tag}/t_compute_ms", 0.0,
                     round(cell["t_compute_s"] * 1e3, 4)))
        rows.append((f"roofline/{tag}/t_memory_ms", 0.0,
                     round(cell["t_memory_s"] * 1e3, 4)))
        rows.append((f"roofline/{tag}/t_collective_ms", 0.0,
                     round(cell["t_collective_s"] * 1e3, 4)))
        rows.append((f"roofline/{tag}/bottleneck", 0.0, cell["bottleneck"]))
        rows.append((f"roofline/{tag}/useful_ratio", 0.0,
                     round(cell["useful_ratio"], 3)))
        rows.append((f"roofline/{tag}/roofline_fraction", 0.0,
                     round(cell["roofline_fraction"], 3)))
    n_fail = len(data.get("failed", []))
    rows.append(("roofline/cells_ok", 0.0, len(data.get("ok", []))))
    rows.append(("roofline/cells_failed", 0.0, n_fail))
    if os.path.exists(OPTIMIZED):
        with open(OPTIMIZED) as f:
            opt = json.load(f)
        base = {(c["arch"], c["shape"], c["mesh"]): c for c in data.get("ok", [])}
        gains = []
        for c in opt.get("ok", []):
            b = base.get((c["arch"], c["shape"], c["mesh"]))
            if not b:
                continue
            tb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
            to = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
            if to > 0:
                gains.append((tb / to, c["arch"], c["shape"], c["mesh"]))
        gains.sort(reverse=True)
        for g, a, sh, m in gains[:10]:
            rows.append((f"roofline/optimized_gain/{a}/{sh}/{m}", 0.0, round(g, 2)))
        rows.append(("roofline/optimized_cells", 0.0, len(opt.get("ok", []))))
    return rows


ALL = [roofline_rows]
