"""One benchmark per paper table/figure (deliverable d).

Each function returns CSV rows: (name, us_per_call, derived) where
``us_per_call`` is a measured wall-time microbenchmark of the artifact that
produces the number (simulator / model evaluation) and ``derived`` is the
reproduced quantity compared against the paper's published value.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import costmodel as cm
from repro.core.archmodels import (
    ARCHS,
    TABLE_IV,
    memory_efficiency_table,
    peak_throughput_table,
    relative_mac_latency,
)
from repro.core.devices import ALVEO_U55, TABLE_VII, VIRTEX7_485
from repro.core.scalability import max_array, scaling_study
from repro.core.simulator import simulate_dot_product


def _timeit(fn, n=3):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def table4_overlay_configs():
    rows = []
    for (name, dev), cfg in TABLE_IV.items():
        rows.append((f"table4/{name}/{dev}/fmax_mhz", 0.0, cfg.fmax_mhz))
        rows.append((f"table4/{name}/{dev}/slice_tile", 0.0, cfg.slice_tile))
    v7 = TABLE_IV[("full-pipe", "V7")].fmax_mhz / TABLE_IV[("benchmark", "V7")].fmax_mhz
    u55 = TABLE_IV[("full-pipe", "U55")].fmax_mhz / TABLE_IV[("benchmark", "U55")].fmax_mhz
    rows.append(("table4/speedup_vs_spar2/V7 (paper 2.25x)", 0.0, round(v7, 3)))
    rows.append(("table4/speedup_vs_spar2/U55 (paper 1.67x)", 0.0, round(u55, 3)))
    return rows


def table5_cycle_latency():
    rows = []
    q, n = 128, 32
    rows.append(("table5/addsub_2N", 0.0, cm.add_sub_cycles(n)))
    rows.append(("table5/mult_2N2+2N", 0.0, cm.mult_cycles_overlay(n)))
    rows.append(("table5/accum_spar2 (paper 4512)", 0.0, cm.accum_cycles_spar2(q, n)))
    rows.append(("table5/accum_picaso (paper 259)", 0.0, cm.accum_cycles_picaso(q, n)))
    rows.append(
        ("table5/accum_improvement (paper 17x)", 0.0,
         round(cm.accum_cycles_spar2(q, n) / cm.accum_cycles_picaso(q, n), 2))
    )
    # functional cross-check: simulate a real dot product, time it
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, size=64)
    w = rng.integers(-128, 128, size=64)
    us = _timeit(lambda: simulate_dot_product(x, w, 8), n=1)
    val, cycles = simulate_dot_product(x, w, 8)
    ref = int(np.dot(x.astype(np.int64), w.astype(np.int64)))
    rows.append(("table5/sim_dot64_correct", us, int(val == ref)))
    rows.append(("table5/sim_dot64_cycles", us, cycles))
    return rows


def table6_fig4_scalability():
    rows = []
    for overlay, dev, paper_pes in (
        ("spar2", VIRTEX7_485, 24_000), ("picaso", VIRTEX7_485, 33_000),
        ("spar2", ALVEO_U55, 63_000), ("picaso", ALVEO_U55, 64_000),
    ):
        us = _timeit(lambda: max_array(overlay, dev))
        rep = max_array(overlay, dev)
        rows.append(
            (f"table6/{overlay}/{dev.short_id}/max_pes (paper {paper_pes})", us, rep.pes)
        )
        rows.append(
            (f"table6/{overlay}/{dev.short_id}/limited_by", 0.0, rep.limited_by)
        )
    study = scaling_study(TABLE_VII)
    for dev_id, reports in study.items():
        rows.append(
            (f"fig4/picaso/{dev_id}/bram_util", 0.0,
             round(reports["picaso"].bram_util, 3))
        )
    return rows


def fig5_mac_latency():
    rows = []
    for n in (4, 8, 16):
        rel = relative_mac_latency(n)
        for name, r in rel.items():
            rows.append((f"fig5/rel_latency/N{n}/{name}", 0.0, round(r, 3)))
    r4 = relative_mac_latency(4)["CoMeFa-A"]
    rows.append(("fig5/comefa_a_max (paper 2.56x)", 0.0, round(r4, 2)))
    return rows


def fig6_throughput():
    rows = []
    for n in (4, 8, 16):
        thr = peak_throughput_table(n)
        for name, t in thr.items():
            rows.append((f"fig6/tmacs/N{n}/{name}", 0.0, round(t, 4)))
        frac = thr["PiCaSO-F"] / thr["CoMeFa-A"]
        rows.append((f"fig6/picaso_vs_comefa_a/N{n} (paper 0.75-0.80)", 0.0,
                     round(frac, 3)))
        # without Booth NOP-skip credit
        no_booth = ARCHS["PiCaSO-F"].peak_tmacs(n, ALVEO_U55, booth_avg=False)
        rows.append((f"fig6/picaso_no_booth/N{n}", 0.0, round(no_booth, 4)))
    return rows


def fig7_memory_efficiency():
    rows = []
    for n in (4, 8, 16, 32):
        eff = memory_efficiency_table(n)
        for name, e in eff.items():
            rows.append((f"fig7/mem_eff/N{n}/{name}", 0.0, round(e, 4)))
    e16 = memory_efficiency_table(16)
    rows.append(("fig7/ccb_16b (paper 0.50)", 0.0, round(e16["CCB"], 3)))
    rows.append(("fig7/comefa_16b (paper 0.688)", 0.0, round(e16["CoMeFa-A"], 3)))
    rows.append(("fig7/picaso_16b (paper 0.938)", 0.0, round(e16["PiCaSO-F"], 3)))
    rows.append(
        ("fig7/amod_gain_16b (paper +0.062)", 0.0,
         round(e16["A-Mod"] - e16["CoMeFa-A"], 4))
    )
    return rows


def table8_summary():
    rows = []
    for name, arch in ARCHS.items():
        rows.append((f"table8/{name}/clock_overhead", 0.0, arch.clock_overhead))
        rows.append((f"table8/{name}/parallel_macs", 0.0, arch.parallel_macs_per_bram36))
        rows.append((f"table8/{name}/mult_cycles_N8", 0.0, arch.mult_cycles(8)))
        rows.append((f"table8/{name}/accum_cycles_q16_N8", 0.0, arch.accum_cycles(16, 8)))
        rows.append((f"table8/{name}/booth", 0.0, arch.booth))
    # A-Mod improvements over CoMeFa-A (paper: lat -19.5%, thr +18%, mem +6.2pp)
    base = ARCHS["CoMeFa-A"].mac16_latency_us(16, ALVEO_U55)
    mod = ARCHS["A-Mod"].mac16_latency_us(16, ALVEO_U55)
    rows.append(("table8/amod_latency_gain_N16 (paper ~0.195)", 0.0,
                 round(1 - mod / base, 3)))
    return rows


ALL = [
    table4_overlay_configs,
    table5_cycle_latency,
    table6_fig4_scalability,
    fig5_mac_latency,
    fig6_throughput,
    fig7_memory_efficiency,
    table8_summary,
]
