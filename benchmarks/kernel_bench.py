"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle vs dense.

On CPU the Pallas interpreter is NOT representative of TPU perf; the number
that matters here is the oracle path (XLA-compiled 'overlay' path) and the
relative HBM-bytes saved by PIM storage, which the roofline report converts
into TPU time.  We report both so the CSV is honest about what was measured.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fold_reduce import fold_reduce
from repro.kernels.pim_matmul import pim_matmul
from repro.quant import pack_int4, quantize_symmetric


def _timeit(fn, n=5):
    fn()  # warm / compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def kernel_micro():
    rows = []
    m, k, n = 128, 1024, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    q8 = quantize_symmetric(w, bits=8, axis=0)
    q4 = quantize_symmetric(w, bits=4, axis=0)
    p4 = pack_int4(q4.codes)

    dense = jax.jit(lambda a, b: a @ b)
    rows.append((f"kernel/dense_f32_{m}x{k}x{n}", _timeit(lambda: dense(x, w)), "xla"))

    oracle8 = jax.jit(ref.pim_matmul_int8_ref)
    rows.append(
        (f"kernel/pim_int8_overlay_{m}x{k}x{n}",
         _timeit(lambda: oracle8(x, q8.codes, q8.scale)), "xla-dequant-fused")
    )
    rows.append(
        (f"kernel/pim_int8_pallas_interp_{m}x{k}x{n}",
         _timeit(lambda: pim_matmul(x, q8.codes, q8.scale, bits=8, interpret=True), n=2),
         "interpret-mode (not TPU-representative)")
    )
    oracle4 = jax.jit(ref.pim_matmul_int4_ref)
    rows.append(
        (f"kernel/pim_int4_overlay_{m}x{k}x{n}",
         _timeit(lambda: oracle4(x, p4, q4.scale)), "xla-dequant-fused")
    )
    # weight HBM bytes: the quantity PIM actually improves
    rows.append(("kernel/weight_bytes_f32", 0.0, w.size * 4))
    rows.append(("kernel/weight_bytes_int8", 0.0, q8.codes.size * 1 + q8.scale.size * 4))
    rows.append(("kernel/weight_bytes_int4", 0.0, p4.size * 1 + q4.scale.size * 4))

    xr = jax.random.normal(jax.random.PRNGKey(2), (512, 128))
    fold_x = jax.jit(lambda a: jnp.sum(a, axis=-1))
    rows.append(("kernel/fold_reduce_xla_sum", _timeit(lambda: fold_x(xr)), "oracle"))
    rows.append(
        ("kernel/fold_reduce_pallas_interp",
         _timeit(lambda: fold_reduce(xr, br=256, interpret=True), n=2),
         "interpret-mode")
    )
    return rows


ALL = [kernel_micro]
