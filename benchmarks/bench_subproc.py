"""Shared runner for the benches' --devices axis.

``--xla_force_host_platform_device_count`` must precede the first jax
import, and forcing it in the parent process would also split the CPU
across the virtual devices for the single-device sections — silently
skewing the PR-over-PR trajectory of the main numbers.  So the sharded
section re-runs the calling script in a SUBPROCESS (its ``--sharded-only``
mode) with the flag in the environment and reads one ``RESULT <json>``
line back.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys


def run_sharded_subprocess(script_file: str, script_args: list[str],
                           devices: int):
    """Re-invoke ``script_file --sharded-only *script_args`` under a forced
    ``devices``-wide virtual host platform; returns the parsed RESULT
    payload, or None on failure / nothing measured.  A device count already
    forced in the parent's XLA_FLAGS is respected, not duplicated."""
    env = dict(os.environ)
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}").strip()
    r = subprocess.run([sys.executable, os.path.abspath(script_file),
                        "--sharded-only"] + script_args,
                       capture_output=True, text=True, env=env)
    if r.returncode != 0:
        print(f"sharded axis failed:\n{r.stderr[-2000:]}")
        return None
    print("\n".join(l for l in r.stdout.splitlines()
                    if not l.startswith("RESULT ")))
    lines = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    if not lines:
        print("sharded axis produced no RESULT line")
        return None
    return json.loads(lines[0][len("RESULT "):]) or None
