"""Serving substrate: PIM weight conversion + fixed-batch and
continuous-batching (paged KV cache) engines."""
from .engine import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    mask_after_stop,
    pim_bytes,
    quantize_tree,
)

__all__ = [
    "ServingEngine", "ContinuousBatchingEngine", "Request", "quantize_tree",
    "pim_bytes", "mask_after_stop",
]
