"""Serving substrate: PIM weight conversion + fixed-batch and
continuous-batching (paged KV cache) engines, both optionally tensor-sharded
over a 1-D ``"model"`` mesh (``serving.sharded``).

Speculative multi-token decode (``serving.speculative``): pass
``speculate=SpecConfig(k=...)`` (or a bare int ``k``) to
``ServingEngine.generate`` or to the ``ContinuousBatchingEngine``
constructor to amortise each weight stream over up to ``k+1`` emitted
tokens.  Proposals come from prompt-lookup n-grams (``mode="ngram"``,
both engines) or a small draft model (``mode="draft"``, constructed with
``draft_cfg``/``draft_params``; the continuous engine keeps the draft's
state in its own paged pool).  The target verifies the whole window in
one ``models.verify_step`` forward.  Under greedy decode, acceptance is
longest greedy-matching prefix and output stays token-identical to plain
greedy decode; under temperature/top-k sampling, acceptance is rejection
sampling (``serving.sampling.rejection_sample``), which preserves the
plain sampled output distribution exactly, with every draw keyed per
(request, counter) so the same ``key`` gives identical tokens on either
engine and any mesh width.  Realised acceptance lands in
``ServingEngine.spec_stats`` / ``ContinuousBatchingEngine.spec_emitted``
/ ``spec_live_steps``."""
from .engine import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    mask_after_stop,
    pim_bytes,
    quantize_tree,
)
from .sampling import (
    acceptance_probs,
    draw_keys,
    rejection_sample,
    residual_dist,
    sample_rows,
    warp_logits,
)
from .sharded import make_decode_mesh, shard_quantized_tree, tree_pspecs
from .speculative import SpecConfig, propose_ngram

__all__ = [
    "ServingEngine", "ContinuousBatchingEngine", "Request", "quantize_tree",
    "pim_bytes", "mask_after_stop", "make_decode_mesh",
    "shard_quantized_tree", "tree_pspecs", "SpecConfig", "propose_ngram",
    "acceptance_probs", "residual_dist", "rejection_sample", "sample_rows",
    "warp_logits", "draw_keys",
]
