"""Serving substrate: PIM weight conversion + batched prefill/decode engine."""
from .engine import ServingEngine, prefill_cache, quantize_tree

__all__ = ["ServingEngine", "quantize_tree", "prefill_cache"]
