"""Serving substrate: PIM weight conversion + fixed-batch and
continuous-batching (paged KV cache) engines, both optionally tensor-sharded
over a 1-D ``"model"`` mesh (``serving.sharded``).

Speculative multi-token decode (``serving.speculative``): pass
``speculate=SpecConfig(k=...)`` (or a bare int ``k``) to
``ServingEngine.generate`` or to the ``ContinuousBatchingEngine``
constructor to amortise each weight stream over up to ``k+1`` emitted
tokens.  Proposals come from prompt-lookup n-grams (``mode="ngram"``,
both engines) or a small draft model (``mode="draft"``, fixed engine,
constructed with ``draft_cfg``/``draft_params``); the target verifies the
whole window in one ``models.verify_step`` forward and accepts the longest
greedy-matching prefix, so output stays token-identical to plain greedy
decode.  Realised acceptance lands in ``ServingEngine.spec_stats`` /
``ContinuousBatchingEngine.spec_emitted``/``spec_live_steps``."""
from .engine import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    mask_after_stop,
    pim_bytes,
    quantize_tree,
)
from .sharded import make_decode_mesh, shard_quantized_tree, tree_pspecs
from .speculative import SpecConfig, propose_ngram

__all__ = [
    "ServingEngine", "ContinuousBatchingEngine", "Request", "quantize_tree",
    "pim_bytes", "mask_after_stop", "make_decode_mesh",
    "shard_quantized_tree", "tree_pspecs", "SpecConfig", "propose_ngram",
]
