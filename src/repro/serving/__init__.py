"""Serving substrate: PIM weight conversion + fixed-batch and
continuous-batching (paged KV cache) engines, both optionally tensor-sharded
over a 1-D ``"model"`` mesh (``serving.sharded``)."""
from .engine import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    mask_after_stop,
    pim_bytes,
    quantize_tree,
)
from .sharded import make_decode_mesh, shard_quantized_tree, tree_pspecs

__all__ = [
    "ServingEngine", "ContinuousBatchingEngine", "Request", "quantize_tree",
    "pim_bytes", "mask_after_stop", "make_decode_mesh",
    "shard_quantized_tree", "tree_pspecs",
]
