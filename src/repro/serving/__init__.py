"""Serving substrate: PIM weight conversion + fixed-batch and
continuous-batching (paged KV cache) engines, both optionally tensor-sharded
over a 1-D ``"model"`` mesh (``serving.sharded``).

Speculative multi-token decode (``serving.speculative``): pass
``speculate=SpecConfig(k=...)`` (or a bare int ``k``) to
``ServingEngine.generate`` or to the ``ContinuousBatchingEngine``
constructor to amortise each weight stream over up to ``k+1`` emitted
tokens.  Proposals come from prompt-lookup n-grams (``mode="ngram"``,
both engines) or a small draft model (``mode="draft"``, constructed with
``draft_cfg``/``draft_params``; the continuous engine keeps the draft's
state in its own paged pool).  The target verifies the whole window in
one ``models.verify_step`` forward.  Under greedy decode, acceptance is
longest greedy-matching prefix and output stays token-identical to plain
greedy decode; under temperature/top-k sampling, acceptance is rejection
sampling (``serving.sampling.rejection_sample``), which preserves the
plain sampled output distribution exactly, with every draw keyed per
(request, counter) so the same ``key`` gives identical tokens on either
engine and any mesh width.  Realised acceptance lands in
``ServingEngine.spec_stats`` / ``ContinuousBatchingEngine.spec_emitted``
/ ``spec_live_steps``.

Failure semantics (``serving.resilience`` + ``serving.chaos``): the
continuous engine's ``serve_detailed`` accepts a ``ResiliencePolicy``
(per-request deadlines/SLO classes, bounded admission queue with load
shedding, retry-with-backoff for transient chunk faults, a graceful-
degradation ladder, periodic crash-replay snapshots) and a seeded
``FaultInjector`` that makes every failure mode reproducible.  Transient
chunk faults are RETRIED (the failed attempt never ran); expired,
overflowing, or unschedulable requests are SHED (lowest SLO class first,
partial tokens kept); corrupt/invalid payloads are REJECTED at admission;
under sustained pressure service DEGRADES one ladder rung at a time
(shrink the speculative window → disable speculation → halve the chunk →
shed low-SLO queue entries — token-preserving for greedy decode); and
after a crash the ``ServingSupervisor`` (built on
``runtime.fault.HeartbeatMonitor``) restores the last ``ServeSnapshot``
and REPLAYS in-flight requests token-identically — the fold_in
(request, counter) draw keys continue the exact random stream.  See the
``serving.resilience`` module docstring for the full contract.

Shared-prefix KV cache + fleet routing (``serving.prefix`` +
``serving.router``): constructing the continuous engine with
``prefix_cache=True`` deduplicates page-aligned prompt prefixes across
requests — a refcounted ``PagePool`` plus a host-side ``PrefixTrie`` alias
matching read-only pages through the block tables, prefill only the
unmatched tail (one ``models.verify_step`` window), fork copy-on-write
when a write frontier lands inside a shared page, and retain/evict
refcount-0 cached pages LRU under pool pressure.  Cache hits are
token-identical to uncached serving (greedy and sampled).
``ReplicaRouter`` spreads a request stream over N engines (least-loaded
with prefix-affinity), token-identical per request to a solo engine.
Per-request span events land on ``RequestRecord.events`` and export as
deterministic chrome-tracing JSON via ``tools/trace_export.py``."""
from .chaos import (
    ChaosConfig,
    ChunkFault,
    EngineCrash,
    FaultInjector,
    VirtualClock,
)
from .engine import (
    ContinuousBatchingEngine,
    Request,
    ServingEngine,
    mask_after_stop,
    pim_bytes,
    quantize_tree,
)
from .prefix import PagePool, PrefixTrie, chunk_keys, extras_fingerprint
from .resilience import (
    LadderConfig,
    ResiliencePolicy,
    ServeReport,
    ServeSnapshot,
    ServingSupervisor,
    load_snapshot,
    save_snapshot,
)
from .sampling import (
    acceptance_probs,
    draw_keys,
    rejection_sample,
    residual_dist,
    sample_rows,
    warp_logits,
)
from .router import ReplicaRouter, RouterReport
from .sharded import make_decode_mesh, shard_quantized_tree, tree_pspecs
from .speculative import SpecConfig, propose_ngram

__all__ = [
    "ServingEngine", "ContinuousBatchingEngine", "Request", "quantize_tree",
    "pim_bytes", "mask_after_stop", "make_decode_mesh",
    "shard_quantized_tree", "tree_pspecs", "SpecConfig", "propose_ngram",
    "acceptance_probs", "residual_dist", "rejection_sample", "sample_rows",
    "warp_logits", "draw_keys",
    "ChaosConfig", "FaultInjector", "ChunkFault", "EngineCrash",
    "VirtualClock", "ResiliencePolicy", "LadderConfig", "ServeReport",
    "ServeSnapshot", "ServingSupervisor", "save_snapshot", "load_snapshot",
    "PagePool", "PrefixTrie", "chunk_keys", "extras_fingerprint",
    "ReplicaRouter", "RouterReport",
]
