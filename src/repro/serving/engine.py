"""Batched serving with PIM-quantized weights.

``quantize_tree`` converts a trained parameter tree into PIM-mode storage:
every large matmul weight becomes ``{"codes": int8, "scale": f32}`` — the
overlay execution path reads these directly (models.common.linear), cutting
weight HBM traffic 2x vs bf16 / 4x vs f32 at decode time, which is the
memory-bound regime the paper targets (§I: MLP/RNN inference dominated by
memory).  Per-arch quantized-vs-dense logit agreement is tested in
tests/test_serving.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward, init_cache
from repro.quant import quantize_symmetric

# Leaves that stay dense: norms/gains/biases/scalars, router (accuracy-
# critical and tiny), conv kernels, SSM dynamics params.
_DENSE_KEYS = {"ln", "ln1", "ln2", "ln3", "ln_f", "conv_w", "conv_b", "A_log",
               "dt_bias", "D", "router", "gate_attn", "gate_mlp",
               "bq", "bk", "bv", "scale"}


def _should_quantize(path, leaf) -> bool:
    names = [str(getattr(p, "key", "")) for p in path]
    if names and names[-1] in _DENSE_KEYS:
        return False
    if leaf.ndim < 2:
        return False
    # embed tables are gathered, not matmul'd — keep dense (tied heads too).
    if names and names[-1] == "embed":
        return False
    return leaf.shape[-1] >= 8 and leaf.shape[-2] >= 8


def quantize_tree(params, bits: int = 8):
    """Convert matmul weights to PIM storage. Quantizes the last two dims
    (per-output-channel scales), keeping any leading stack dims.

    bits=4 packs two codes per byte along the K (contraction) dim — the
    storage actually shipped to HBM; ``models.common.linear``/``dq`` unpack
    at the matmul (the 'nibbles' marker leaf flags the packing)."""

    def conv(path, leaf):
        if not _should_quantize(path, leaf):
            return leaf
        q = quantize_symmetric(leaf.astype(jnp.float32), bits=bits, axis=-2)
        if bits == 4 and q.codes.shape[-2] % 2 == 0:
            lo = q.codes[..., 0::2, :] & 0xF
            hi = q.codes[..., 1::2, :] & 0xF
            packed = (lo | (hi << 4)).astype(jnp.int8)
            # marker carries any leading stack dims so lax.scan can slice it
            return {"codes": packed, "scale": q.scale,
                    "nibbles": jnp.zeros(packed.shape[:-2], jnp.int8)}
        return {"codes": q.codes, "scale": q.scale}

    return jax.tree_util.tree_map_with_path(conv, params)


def pim_bytes(params) -> int:
    """HBM bytes of a (possibly quantized) parameter tree."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


def prefill_cache(params, cfg: ModelConfig, tokens, cache, extras: Optional[dict] = None):
    """Sequential prefill via decode steps (reference path; the production
    prefill lowers forward() once over the whole prompt)."""
    pos = 0
    for i in range(tokens.shape[1]):
        _, cache = decode_step(params, cfg, tokens[:, i : i + 1], cache,
                               jnp.int32(pos), extras)
        pos += 1
    return cache, pos


class ServingEngine:
    """Minimal batched engine: prefill once, then step the whole batch."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int, pim_bits: int = 0):
        self.cfg = cfg
        self.params = quantize_tree(params, pim_bits) if pim_bits else params
        self.max_seq = max_seq

    def generate(self, prompt_tokens, n_new: int, extras: Optional[dict] = None,
                 greedy: bool = True):
        cfg = self.cfg
        b, s = prompt_tokens.shape
        cache = init_cache(cfg, b, self.max_seq)

        step_fn = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos, extras)
        )
        # Prefill by stepping the prompt (keeps one lowered program).
        logits = None
        for i in range(s):
            logits, cache = step_fn(self.params, prompt_tokens[:, i : i + 1],
                                    cache, jnp.int32(i))
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for j in range(n_new):
            out.append(tok)
            logits, cache = step_fn(self.params, tok, cache, jnp.int32(s + j))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)
