"""Batched serving with PIM-quantized weights — the decode fast path.

``quantize_tree`` converts a trained parameter tree into PIM-mode storage:
every large matmul weight becomes ``{"codes": int8, "scale": f32}`` — the
overlay execution path reads these directly (models.common.linear), cutting
weight HBM traffic 2x vs bf16 / 4x vs f32 at decode time, which is the
memory-bound regime the paper targets (§I: MLP/RNN inference dominated by
memory).  Per-arch quantized-vs-dense logit agreement is tested in
tests/test_serving.py.

``ServingEngine.generate`` is ONE lowered XLA program: a single-pass prefill
over the whole prompt (``models.prefill``) followed by a ``lax.scan`` over
the decode steps.  The seed engine re-entered Python once per token for both
phases; per Gómez-Luna et al.'s UPMEM study (PAPERS.md), that host-side
dispatch overhead is exactly what erases PIM's memory-bandwidth win.  The
seed loop survives as ``generate_reference`` — the parity oracle and the
benchmark baseline (benchmarks/decode_bench.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill
from repro.quant import quantize_symmetric

# Leaves that stay dense: norms/gains/biases/scalars, router (accuracy-
# critical and tiny), conv kernels, SSM dynamics params.
_DENSE_KEYS = {"ln", "ln1", "ln2", "ln3", "ln_f", "conv_w", "conv_b", "A_log",
               "dt_bias", "D", "router", "gate_attn", "gate_mlp",
               "bq", "bk", "bv", "scale"}


def _should_quantize(path, leaf) -> bool:
    names = [str(getattr(p, "key", "")) for p in path]
    if names and names[-1] in _DENSE_KEYS:
        return False
    if leaf.ndim < 2:
        return False
    # embed tables are gathered, not matmul'd — keep dense (tied heads too).
    if names and names[-1] == "embed":
        return False
    return leaf.shape[-1] >= 8 and leaf.shape[-2] >= 8


def quantize_tree(params, bits: int = 8):
    """Convert matmul weights to PIM storage. Quantizes the last two dims
    (per-output-channel scales), keeping any leading stack dims.

    bits=4 packs two codes per byte along the K (contraction) dim — the
    storage actually shipped to HBM; ``models.common.linear``/``dq`` unpack
    at the matmul.  An odd K is zero-padded by one code row before packing
    and flagged with the ``nibbles_odd`` marker key so ``dq``/``weight_shape``
    drop the pad row statically (the seed silently fell back to INT8 storage
    for odd K).  The marker leaf ("nibbles" / "nibbles_odd") carries any
    leading stack dims so ``lax.scan`` can slice it."""

    def conv(path, leaf):
        if not _should_quantize(path, leaf):
            return leaf
        q = quantize_symmetric(leaf.astype(jnp.float32), bits=bits, axis=-2)
        if bits == 4:
            codes = q.codes
            odd = codes.shape[-2] % 2
            if odd:
                codes = jnp.concatenate(
                    [codes, jnp.zeros_like(codes[..., :1, :])], axis=-2)
            lo = codes[..., 0::2, :] & 0xF
            hi = codes[..., 1::2, :] & 0xF
            packed = (lo | (hi << 4)).astype(jnp.int8)
            marker = "nibbles_odd" if odd else "nibbles"
            return {"codes": packed, "scale": q.scale,
                    marker: jnp.zeros(packed.shape[:-2], jnp.int8)}
        return {"codes": q.codes, "scale": q.scale}

    return jax.tree_util.tree_map_with_path(conv, params)


def pim_bytes(params) -> int:
    """HBM bytes of a (possibly quantized) parameter tree."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


def prefill_cache(params, cfg: ModelConfig, tokens, cache, extras: Optional[dict] = None):
    """Sequential prefill via decode steps (reference path; the production
    prefill is ``models.prefill`` — one lowered program over the prompt)."""
    pos = 0
    for i in range(tokens.shape[1]):
        _, cache = decode_step(params, cfg, tokens[:, i : i + 1], cache,
                               jnp.int32(pos), extras)
        pos += 1
    return cache, pos


# ---------------------------------------------------------------- sampling --
def sample_logits(logits, key, *, greedy: bool, temperature, top_k: int):
    """logits (..., V) -> int32 token ids (...): greedy argmax or
    temperature/top-k categorical sampling."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / jnp.maximum(
        jnp.asarray(temperature, jnp.float32), 1e-6)
    top_k = min(top_k, lg.shape[-1])  # top_k >= vocab is plain sampling
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("cfg", "n_new", "max_seq", "greedy", "top_k")
)
def _generate_scan(params, cfg: ModelConfig, prompt, extras, key, temperature,
                   *, n_new: int, max_seq: int, greedy: bool, top_k: int):
    """The whole generation — prefill + n_new decode steps + sampling — as a
    single XLA program (zero per-token Python dispatch)."""
    b, s = prompt.shape
    if n_new == 0:
        return jnp.zeros((b, 0), jnp.int32)
    cache = init_cache(cfg, b, max_seq)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    key, k0 = jax.random.split(key)
    tok0 = sample_logits(logits[:, -1, :], k0, greedy=greedy,
                         temperature=temperature, top_k=top_k)[:, None]

    # Emit AFTER stepping: n_new-1 scan iterations produce tok1..tok_{n-1}
    # (tok0 comes from the prefill logits), so no decode step's output is
    # ever discarded.
    def body(carry, i):
        tok, cache, key = carry
        lg, cache = decode_step(params, cfg, tok, cache, jnp.int32(s) + i, extras)
        key, sub = jax.random.split(key)
        nxt = sample_logits(lg[:, -1, :], sub, greedy=greedy,
                            temperature=temperature, top_k=top_k)[:, None]
        return (nxt, cache, key), nxt[:, 0]

    _, toks = jax.lax.scan(body, (tok0, cache, key),
                           jnp.arange(n_new - 1, dtype=jnp.int32))
    return jnp.concatenate([tok0, toks.T], axis=1)  # (B, n_new)


class ServingEngine:
    """Batched engine: single-pass prefill, then a scan-compiled decode loop."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int, pim_bits: int = 0):
        self.cfg = cfg
        self.params = quantize_tree(params, pim_bits) if pim_bits else params
        self.max_seq = max_seq

    def generate(self, prompt_tokens, n_new: int, extras: Optional[dict] = None,
                 greedy: bool = True, temperature: float = 1.0, top_k: int = 0,
                 key=None):
        """Generate ``n_new`` tokens for the whole batch in one XLA program.

        greedy=True reproduces the seed engine's argmax decoding; for
        dense/SSM/hybrid families the tokens are bit-identical to
        ``generate_reference`` (tests/test_decode_fastpath.py).  MLA archs
        use the absorbed decode form, whose float-association order differs
        from the expanded prefill by ~1e-3 logit units — argmax can flip at
        near-ties (only observable on untrained models, where top-2 margins
        are that small).  greedy=False samples with ``temperature`` and
        optional ``top_k`` filtering, driven by ``key`` (defaults to
        PRNGKey(0) for reproducibility)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        s = prompt_tokens.shape[1]
        if s + n_new > self.max_seq:
            raise ValueError(
                f"prompt ({s}) + n_new ({n_new}) exceeds max_seq "
                f"({self.max_seq}); cache writes past max_seq would "
                "silently clamp")
        return _generate_scan(
            self.params, self.cfg, prompt_tokens, extras, key,
            jnp.float32(temperature), n_new=int(n_new), max_seq=self.max_seq,
            greedy=bool(greedy), top_k=int(top_k),
        )

    def generate_reference(self, prompt_tokens, n_new: int,
                           extras: Optional[dict] = None):
        """The seed per-token loop: one Python dispatch per prompt AND per
        generated token.  Kept as the parity oracle for the scan-compiled
        path and as the dispatch-bound baseline in decode_bench."""
        cfg = self.cfg
        b, s = prompt_tokens.shape
        cache = init_cache(cfg, b, self.max_seq)

        step_fn = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos, extras)
        )
        logits = None
        for i in range(s):
            logits, cache = step_fn(self.params, prompt_tokens[:, i : i + 1],
                                    cache, jnp.int32(i))
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for j in range(n_new):
            out.append(tok)
            logits, cache = step_fn(self.params, tok, cache, jnp.int32(s + j))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)
