"""Serving with PIM-quantized weights: fixed-batch fast path + a
continuous-batching scheduler on a paged KV cache.

``quantize_tree`` converts a trained parameter tree into PIM-mode storage:
every large matmul weight becomes ``{"codes": int8, "scale": f32}`` — the
overlay execution path reads these directly (models.common.linear), cutting
weight HBM traffic 2x vs bf16 / 4x vs f32 at decode time, which is the
memory-bound regime the paper targets (§I: MLP/RNN inference dominated by
memory).  Per-arch quantized-vs-dense logit agreement is tested in
tests/test_serving.py.

Two engines share the model-side decode path:

``ServingEngine.generate`` — ONE lowered XLA program for a fixed batch: a
single-pass prefill over the whole prompt (``models.prefill``) followed by a
``lax.scan`` over the decode steps.  The seed per-token loop survives as
``generate_reference`` — the single parity oracle (prefill AND decode
per-token) and the dispatch-bound baseline in benchmarks/decode_bench.py.
Its weakness is request-level: every sequence rides until the longest one
finishes, and the dense cache preallocates ``B * max_seq`` tokens.

``ContinuousBatchingEngine`` — request-level scheduling on a paged cache:

* **Page / block-table layout** (``models.init_paged_cache``): each layer's
  K/V (or MLA latent) store is a pool of ``num_pages`` fixed-size pages of
  ``page_size`` tokens, shaped ``(P, KV, page_size, D)`` (latents:
  ``(P, page_size, rank)``), shared across all batch slots.  A slot's
  ``block_tables`` row (width ``max_seq / page_size``) maps its logical page
  ``i`` — positions ``[i*page_size, (i+1)*page_size)`` — to a pool page id.
  Decode scatters the new token's K/V through the table and gathers the
  slot's pages at the contraction (``models.attention.attn_decode_paged``).
  Page 0 is reserved as the trash page: inactive slots write there, so
  freed pages can be re-issued without cross-slot corruption.  Cache memory
  therefore scales with live tokens (pages in use), not ``B * max_seq``.
  SSM/conv state is O(1) per slot and stays per-slot dense.

* **Scheduler states**: a request is QUEUED until a batch slot and enough
  pages for its (page-aligned) prompt are free; ADMITTED by a batch-1
  single-pass prefill that writes STRAIGHT into its pool pages and per-slot
  state row (``models.prefill`` with ``pages``/``slot``; the old dense
  round-trip survives only as ``models.paged_insert``, the reference for
  the equivalence test) and yields its first token; RUNNING
  while the jit-compiled decode chunk (``lax.scan`` over ``chunk`` steps,
  per-slot ``pos``/``done``/``n_out`` carried) advances all live slots;
  FINISHED when it emits a stop token or reaches ``max_new``, at which
  point its pages return to the free list and the slot admits the next
  queued request — short requests no longer wait on the longest.  If the
  free list runs dry mid-flight the youngest running request is PREEMPTED
  (pages freed, requeued for recompute), matching vLLM-style recompute
  preemption.  The host only intervenes at chunk boundaries (admit /
  page top-up / retire); the inner loop stays one compiled program.

Both engines accept ``mesh=`` (a 1-D ``"model"`` mesh, see
``serving.sharded``): the quantized weight tree is distributed over the
mesh along output dims and every compiled path — the generate scan, the
admit prefill, the decode chunk — lowers ONCE under ``shard_map`` with
weight-stationary local matvecs and a single activation all-gather per
linear.  Host-side scheduling is untouched (it never sees a device count),
and greedy decode stays token-identical to the single-device engines
(tests/test_sharded_decode.py).

Both engines also speculate (``serving.speculative``): ``speculate=`` turns
each decode step into a k-token verify window — one weight stream for up to
k+1 emitted tokens.  Greedy decode stays token-identical to the plain
engines by greedy-prefix acceptance (tests/test_speculative.py); sampled
decode (temperature/top-k) is verified by rejection sampling
(``serving.sampling``), which preserves the plain sampled output
distribution exactly and — because every draw is keyed per (request id,
draw counter) rather than per batch step — emits identical tokens for the
same key on either engine, any mesh width, and across recompute
preemptions (tests/test_sampled_speculative.py).

**Shared-prefix KV page cache** (``prefix_cache=True``,
``serving.prefix``): the block-table indirection already lets several
slots alias ONE pool page, so requests sharing a token-identical prompt
prefix (system prompts, few-shot headers) can share its KV instead of
re-prefilling it.  Lifecycle:

* the page allocator is a refcounted ``prefix.PagePool`` (a page's
  refcount = live block-table references); an uncached admit registers
  its prompt's FULL pages in a ``prefix.PrefixTrie`` keyed by each
  page-aligned chunk's raw token bytes, chained from position 0 under a
  per-extras-fingerprint root — so a page only matches when every
  preceding token and the request's conditioning are identical, exactly
  the causal dependency of its KV content;
* admission probes the trie: matched pages are aliased into the slot's
  block table (refcount + 1) and only the unmatched tail is computed —
  ONE ``models.verify_step`` window at the tail position against the
  aliased prefix (the same per-position math as ``models.prefill``, so
  cache hits stay token-identical to uncached serving, greedy AND
  fold_in-keyed sampled);
* when the tail write frontier lands INSIDE a matched page (a fully
  page-aligned full-prefix hit still recomputes the last position's
  logits, writing its K/V), the page is forked copy-on-write first — a
  writer can never perturb a page a sibling or the trie still reads;
* on retire/preempt the slot's references drop; trie-registered pages
  at refcount 0 are RETAINED on an LRU (``pages_in_use`` counts them as
  reclaimable, not in-use) and re-aliased by later hits, while pool
  pressure (admission, top-up, chaos squeezes) evicts them LRU-first —
  cached pages are opportunistic capacity, never reserved capacity.
  ``assert_quiescent`` accounts for retained pages explicitly.

Only families whose prefill/verify logits agree bitwise are eligible
(``_PREFIX_FAMILIES``): ssm/hybrid carry unpaged per-slot recurrent
state, moe batched expert capacity makes a tail window diverge from a
full prefill under capacity pressure, and MLA's absorbed decode differs
at ~1e-3.  Ineligible families (and draft mode) simply never hit.

**Per-request telemetry** (``RequestRecord.slot``/``.events``,
``ServeReport.counters``): every request carries span events —
``{"name", "ts", "dur"?, ...}`` in engine-clock seconds — for admit
(with cached/prefilled token counts), per-round decode (with tokens
emitted), preempt, shed, and finish, plus one per-round counter sample
(free/retained pages, prefix-hit tokens, effective k, queue depth).
``tools/trace_export.py`` turns a report into chrome-tracing JSON (one
Perfetto track per slot + counter tracks); under a ``VirtualClock`` the
trace is fully deterministic.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import (
    decode_step,
    init_cache,
    init_paged_cache,
    prefill,
    verify_step,
)
from repro.quant import quantize_symmetric
from repro.serving import speculative as spec_mod
from repro.serving.chaos import ChunkFault, EngineCrash
from repro.serving.prefix import (
    PagePool,
    PrefixTrie,
    chunk_keys,
    extras_fingerprint,
)
from repro.serving.resilience import (
    DegradationLadder,
    InflightState,
    LadderConfig,
    RequestRecord,
    ResiliencePolicy,
    ServeReport,
    ServeSnapshot,
)
from repro.serving.sampling import (
    TAG_TOKEN,
    draw_keys,
    sample_rows,
    warp_logits,
)
from repro.serving.sharded import shard_quantized_tree, tree_pspecs
from repro.serving.speculative import SpecConfig

# Leaves that stay dense: norms/gains/biases/scalars, router (accuracy-
# critical and tiny), conv kernels, SSM dynamics params.
_DENSE_KEYS = {"ln", "ln1", "ln2", "ln3", "ln_f", "conv_w", "conv_b", "A_log",
               "dt_bias", "D", "router", "gate_attn", "gate_mlp",
               "bq", "bk", "bv", "scale"}

# Metadata leaves — markers, not shipped storage: int4 packing flags and the
# tensor-parallel shard tag added by serving.sharded.shard_quantized_tree.
_MARKER_KEYS = ("nibbles", "nibbles_odd", "tp")

# Families eligible for shared-prefix page caching: those whose admit
# prefill and tail verify_step produce bitwise-identical logits, so a cache
# hit cannot change a single output token.  ssm/hybrid keep per-slot
# recurrent state outside the page pool (nothing to alias); moe expert
# capacity is computed per batched group, so a tail-only window can drop
# tokens a full prefill keeps (see ROADMAP carried-forward note); MLA
# (cfg.mla) absorbed decode differs from expanded prefill at ~1e-3.
_PREFIX_FAMILIES = ("dense", "vlm", "encdec")



def _should_quantize(path, leaf) -> bool:
    names = [str(getattr(p, "key", "")) for p in path]
    if names and names[-1] in _DENSE_KEYS:
        return False
    if leaf.ndim < 2:
        return False
    # embed tables are gathered, not matmul'd — keep dense (tied heads too).
    if names and names[-1] == "embed":
        return False
    return leaf.shape[-1] >= 8 and leaf.shape[-2] >= 8


def quantize_tree(params, bits: int = 8):
    """Convert matmul weights to PIM storage. Quantizes the last two dims
    (per-output-channel scales), keeping any leading stack dims.

    bits=4 packs two codes per byte along the K (contraction) dim — the
    storage actually shipped to HBM; ``models.common.linear``/``dq`` unpack
    at the matmul.  An odd K is zero-padded by one code row before packing
    and flagged with the ``nibbles_odd`` marker key so ``dq``/``weight_shape``
    drop the pad row statically (the seed silently fell back to INT8 storage
    for odd K).  The marker leaf ("nibbles" / "nibbles_odd") carries any
    leading stack dims so ``lax.scan`` can slice it."""

    def conv(path, leaf):
        if not _should_quantize(path, leaf):
            return leaf
        q = quantize_symmetric(leaf.astype(jnp.float32), bits=bits, axis=-2)
        if bits == 4:
            codes = q.codes
            odd = codes.shape[-2] % 2
            if odd:
                codes = jnp.concatenate(
                    [codes, jnp.zeros_like(codes[..., :1, :])], axis=-2)
            lo = codes[..., 0::2, :] & 0xF
            hi = codes[..., 1::2, :] & 0xF
            packed = (lo | (hi << 4)).astype(jnp.int8)
            marker = "nibbles_odd" if odd else "nibbles"
            return {"codes": packed, "scale": q.scale,
                    marker: jnp.zeros(packed.shape[:-2], jnp.int8)}
        return {"codes": q.codes, "scale": q.scale}

    return jax.tree_util.tree_map_with_path(conv, params)


def pim_bytes(params, per_device: bool = False) -> int:
    """HBM bytes of a (possibly quantized, possibly sharded) parameter tree.

    The ``nibbles``/``nibbles_odd``/``tp`` leaves are *markers* — metadata
    for ``dq``/``weight_shape``/``linear``, never shipped to HBM — so they
    are excluded from the byte count.

    ``per_device=True`` reports the bytes ONE device actually holds/streams:
    each leaf counts its shard shape under its committed sharding, so a
    mesh-distributed tree reports codes AND scales at 1/devices while
    replicated leaves (norms, markers' siblings, non-divisible weights)
    count in full — instead of silently double-counting replicated storage
    as if it were split.  The default (total) is unchanged: the global
    weight bytes the model streams per token across all devices."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if path and str(getattr(path[-1], "key", "")) in _MARKER_KEYS:
            continue
        n = leaf.size
        if per_device:
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and hasattr(sharding, "shard_shape"):
                n = math.prod(sharding.shard_shape(leaf.shape))
        total += n * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------- sampling --
def sample_logits(logits, key, *, greedy: bool, temperature, top_k: int):
    """logits (..., V) -> int32 token ids (...): greedy argmax or
    temperature/top-k categorical sampling with ONE key for the whole
    batch.  The engines' decode loops use ``sampling.sample_rows`` with
    per-row counter-derived keys instead (engine-independent streams);
    this stays as the simple one-shot helper."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, warp_logits(logits, temperature, top_k), axis=-1
    ).astype(jnp.int32)


def mask_after_stop(tokens, stop_tokens: Sequence[int], pad_id: int = 0):
    """Replace every token emitted *after* a row's first stop token with
    ``pad_id`` (the stop token itself is kept).  tokens: (B, N) int32."""
    stop_tokens = tuple(stop_tokens)
    if not stop_tokens:
        return tokens
    hit = jnp.zeros(tokens.shape, bool)
    for s in stop_tokens:
        hit = hit | (tokens == s)
    h = hit.astype(jnp.int32)
    stopped_before = (jnp.cumsum(h, axis=1) - h) > 0
    return jnp.where(stopped_before, jnp.int32(pad_id), tokens)


def _generate_body(params, cfg: ModelConfig, prompt, extras, key, temperature,
                   *, n_new: int, max_seq: int, greedy: bool, top_k: int):
    """The whole generation — prefill + n_new decode steps + sampling — as a
    single XLA program (zero per-token Python dispatch).  Jitted directly by
    ``_generate_scan`` or lowered per-device under ``shard_map`` by
    ``_generate_scan_sharded``.  Sampled draws are keyed per row and per
    emission index (``sampling.draw_keys``), so a row's stream is
    independent of batch composition and identical on the paged engine."""
    b, s = prompt.shape
    if n_new == 0:
        return jnp.zeros((b, 0), jnp.int32)
    rids = jnp.arange(b, dtype=jnp.int32)
    cache = init_cache(cfg, b, max_seq)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    tok0 = sample_rows(
        logits[:, -1, :],
        None if greedy else draw_keys(key, rids, 0, TAG_TOKEN),
        greedy=greedy, temperature=temperature, top_k=top_k)[:, None]

    # Emit AFTER stepping: n_new-1 scan iterations produce tok1..tok_{n-1}
    # (tok0 comes from the prefill logits, draw index 0), so no decode
    # step's output is ever discarded.
    def body(carry, i):
        tok, cache = carry
        lg, cache = decode_step(params, cfg, tok, cache, jnp.int32(s) + i, extras)
        nxt = sample_rows(
            lg[:, -1, :],
            None if greedy else draw_keys(key, rids, i + 1, TAG_TOKEN),
            greedy=greedy, temperature=temperature, top_k=top_k)[:, None]
        return (nxt, cache), nxt[:, 0]

    _, toks = jax.lax.scan(body, (tok0, cache),
                           jnp.arange(n_new - 1, dtype=jnp.int32))
    return jnp.concatenate([tok0, toks.T], axis=1)  # (B, n_new)


_generate_scan = functools.partial(
    jax.jit, static_argnames=("cfg", "n_new", "max_seq", "greedy", "top_k")
)(_generate_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "n_new", "max_seq", "greedy", "top_k"),
)
def _generate_scan_sharded(params, cfg: ModelConfig, prompt, extras, key,
                           temperature, *, mesh, n_new: int, max_seq: int,
                           greedy: bool, top_k: int):
    """``_generate_body`` lowered once under ``shard_map``: weights enter
    pre-sharded along their output dims (``tree_pspecs`` reads the ``tp``
    markers), every other operand and every output is replicated — the
    per-layer collectives happen inside ``models.common.linear``/``dq``."""

    def f(p, pr, ex, k, t):
        return _generate_body(p, cfg, pr, ex, k, t, n_new=n_new,
                              max_seq=max_seq, greedy=greedy, top_k=top_k)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params), P(), P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(params, prompt, extras, key, temperature)


class ServingEngine:
    """Fixed-batch engine: single-pass prefill, then a scan-compiled decode
    loop — one XLA program end-to-end.  The baseline the continuous-batching
    engine is benchmarked against (benchmarks/serving_bench.py).

    ``mesh``: a 1-D ``"model"`` mesh (``serving.sharded.make_decode_mesh``)
    distributes the quantized weight tree over its devices; generation then
    runs under ``shard_map`` with per-device weight shards, token-identical
    to the single-device engine."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 pim_bits: int = 0, mesh=None, draft_cfg: ModelConfig = None,
                 draft_params=None, draft_pim_bits: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        params = quantize_tree(params, pim_bits) if pim_bits else params
        if mesh is not None:
            params = shard_quantized_tree(params, mesh)
        self.params = params
        self.max_seq = max_seq
        # Optional draft model for speculate=SpecConfig(mode="draft"): a
        # smaller same-family model whose k cheap autoregressive steps seed
        # the target's single verify pass.
        self.draft_cfg = draft_cfg
        if draft_params is not None and draft_pim_bits:
            draft_params = quantize_tree(draft_params, draft_pim_bits)
        self.draft_params = draft_params
        self.spec_stats: Optional[dict] = None

    def generate(self, prompt_tokens, n_new: int, extras: Optional[dict] = None,
                 greedy: bool = True, temperature: float = 1.0, top_k: int = 0,
                 key=None, stop_tokens: Sequence[int] = (), pad_id: int = 0,
                 speculate=None):
        """Generate ``n_new`` tokens for the whole batch in one XLA program.

        greedy=True reproduces the seed engine's argmax decoding; for
        dense/SSM/hybrid families the tokens are bit-identical to
        ``generate_reference`` (tests/test_decode_fastpath.py).  MLA archs
        use the absorbed decode form, whose float-association order differs
        from the expanded prefill by ~1e-3 logit units — argmax can flip at
        near-ties (only observable on untrained models, where top-2 margins
        are that small).  greedy=False samples with ``temperature`` and
        optional ``top_k`` filtering, driven by ``key`` (defaults to
        PRNGKey(0) for reproducibility).

        ``stop_tokens`` masks every token a row emits after its first stop
        token with ``pad_id`` (the stop token itself is kept) — pure
        post-processing on the emitted tokens, so varying stop sets never
        recompile the generation program.  The scan still runs ``n_new``
        steps — a fixed batch cannot retire rows early; that is exactly
        what ``ContinuousBatchingEngine`` adds.

        ``speculate`` (a ``serving.SpecConfig`` or an int ``k`` shorthand)
        switches to speculative multi-token decode: propose ``k`` tokens
        (prompt-lookup n-grams, or the engine's draft model), verify them
        with ONE target forward, emit the accepted prefix + one more token
        — the per-token weight stream amortised over the accepted tokens.
        Under greedy decode the output is token-identical to this method's
        plain greedy output (greedy-prefix acceptance); under sampling
        (``greedy=False``) verification is rejection sampling
        (``serving.sampling.rejection_sample``), which leaves the output
        DISTRIBUTION of plain sampled decode exactly unchanged and is
        key-deterministic across engines and meshes (the draws are keyed
        per row and window, not per batch step).  Per-row accepted lengths
        ride in a compiled ``while_loop``.  ``self.spec_stats`` records
        the realised acceptance (``emitted_per_step``) after each
        speculative call."""
        if key is None:
            key = jax.random.PRNGKey(0)
        s = prompt_tokens.shape[1]
        if s + n_new > self.max_seq:
            raise ValueError(
                f"prompt ({s}) + n_new ({n_new}) exceeds max_seq "
                f"({self.max_seq}); cache writes past max_seq would "
                "silently clamp")
        if speculate is not None:
            toks = self._generate_speculative(
                prompt_tokens, int(n_new), extras,
                spec_mod.as_spec(speculate), greedy=bool(greedy),
                temperature=temperature, top_k=int(top_k), key=key)
        elif self.mesh is not None:
            toks = _generate_scan_sharded(
                self.params, self.cfg, prompt_tokens, extras, key,
                jnp.float32(temperature), mesh=self.mesh, n_new=int(n_new),
                max_seq=self.max_seq, greedy=bool(greedy), top_k=int(top_k),
            )
        else:
            toks = _generate_scan(
                self.params, self.cfg, prompt_tokens, extras, key,
                jnp.float32(temperature), n_new=int(n_new), max_seq=self.max_seq,
                greedy=bool(greedy), top_k=int(top_k),
            )
        return mask_after_stop(toks, tuple(stop_tokens), int(pad_id))

    def _generate_speculative(self, prompt_tokens, n_new: int, extras,
                              spec: SpecConfig, *, greedy: bool, temperature,
                              top_k: int, key):
        b = prompt_tokens.shape[0]
        if spec.mode == "draft":
            if self.draft_params is None or self.draft_cfg is None:
                raise ValueError(
                    "speculate mode='draft' needs the engine constructed "
                    "with draft_cfg/draft_params")
            if self.mesh is not None:
                raise NotImplementedError(
                    "draft-model speculation is single-device (the draft "
                    "tree is not mesh-distributed); use mode='ngram' on a "
                    "mesh")
        if spec.tree_fan:
            if self.mesh is not None:
                toks, steps, live_steps = spec_mod._spec_tree_generate_sharded(
                    self.params, self.cfg, prompt_tokens, extras, key,
                    jnp.float32(temperature), mesh=self.mesh, n_new=n_new,
                    max_seq=self.max_seq, fan=spec.tree_fan, depth=spec.k,
                    ngram_n=spec.ngram_n, greedy=greedy, top_k=top_k)
            else:
                toks, steps, live_steps = spec_mod._spec_tree_generate(
                    self.params, self.cfg, prompt_tokens, extras, key,
                    jnp.float32(temperature), n_new=n_new,
                    max_seq=self.max_seq, fan=spec.tree_fan, depth=spec.k,
                    ngram_n=spec.ngram_n, greedy=greedy, top_k=top_k)
        elif self.mesh is not None:
            toks, steps, live_steps = spec_mod._spec_generate_sharded(
                self.params, self.cfg, prompt_tokens, extras, key,
                jnp.float32(temperature), mesh=self.mesh, n_new=n_new,
                max_seq=self.max_seq, k=spec.k, ngram_n=spec.ngram_n,
                greedy=greedy, top_k=top_k, adaptive=spec.adaptive,
                ctrl_alpha=spec.ctrl_alpha, ctrl_init=spec.ctrl_init,
                ctrl_cost=spec.ctrl_cost, accept=spec.accept,
                typical_eps=spec.typical_eps,
                typical_delta=spec.typical_delta)
        else:
            toks, steps, live_steps = spec_mod._spec_generate(
                self.params, self.cfg, prompt_tokens, extras,
                self.draft_params if spec.mode == "draft" else None,
                key, jnp.float32(temperature),
                draft_cfg=self.draft_cfg if spec.mode == "draft" else None,
                n_new=n_new, max_seq=self.max_seq, k=spec.k, mode=spec.mode,
                ngram_n=spec.ngram_n, greedy=greedy, top_k=top_k,
                adaptive=spec.adaptive, ctrl_alpha=spec.ctrl_alpha,
                ctrl_init=spec.ctrl_init, ctrl_cost=spec.ctrl_cost,
                accept=spec.accept, typical_eps=spec.typical_eps,
                typical_delta=spec.typical_delta)
        steps, live_steps = int(steps), int(live_steps)
        # One verify step streams the weight tree once for the WHOLE batch,
        # so the weight-stream amortisation is per-row tokens over verify
        # steps: plain greedy needs n_new-1 streams, speculation `steps`.
        # (Normalising by live_row_steps instead would overstate the win
        # when rows finish at different times — a straggler row keeps the
        # batch streaming.)  acceptance_per_live_row is the per-row window
        # acceptance, the proposer-quality number.
        self.spec_stats = {
            "k": spec.k, "mode": spec.mode, "greedy": greedy,
            "adaptive": spec.adaptive, "tree_fan": spec.tree_fan,
            "accept": spec.accept,
            "verify_steps": steps,
            "live_row_steps": live_steps,
            "emitted_per_step": ((n_new - 1) / steps if steps else 0.0),
            "acceptance_per_live_row": (b * (n_new - 1) / live_steps
                                        if live_steps else 0.0),
        }
        return toks

    def generate_reference(self, prompt_tokens, n_new: int,
                           extras: Optional[dict] = None, greedy: bool = True,
                           temperature: float = 1.0, top_k: int = 0, key=None,
                           stop_tokens: Sequence[int] = (), pad_id: int = 0):
        """The seed per-token loop: one Python dispatch per prompt AND per
        generated token.  THE parity oracle — it exercises both the
        per-token prefill path and the per-token decode path that the
        scan-compiled ``generate`` replaces — and the dispatch-bound
        baseline in decode_bench.  Mirrors ``generate``'s sampling options
        and per-row ``(key, row, draw index)`` key derivation, so matching
        keys give matching samples."""
        if self.mesh is not None:
            raise NotImplementedError(
                "generate_reference is the single-device parity oracle; "
                "construct the engine without a mesh to run it")
        if key is None:
            key = jax.random.PRNGKey(0)
        cfg = self.cfg
        b, s = prompt_tokens.shape
        if n_new == 0:
            return jnp.zeros((b, 0), jnp.int32)
        cache = init_cache(cfg, b, self.max_seq)

        step_fn = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos, extras)
        )
        rids = jnp.arange(b, dtype=jnp.int32)

        def draw(logits, idx):
            return sample_rows(
                logits[:, -1, :],
                None if greedy else draw_keys(key, rids, idx, TAG_TOKEN),
                greedy=greedy, temperature=jnp.float32(temperature),
                top_k=int(top_k))[:, None]

        logits = None
        for i in range(s):
            logits, cache = step_fn(self.params, prompt_tokens[:, i : i + 1],
                                    cache, jnp.int32(i))
        tok = draw(logits, 0)
        out = [tok]
        for j in range(n_new - 1):
            logits, cache = step_fn(self.params, tok, cache, jnp.int32(s + j))
            tok = draw(logits, j + 1)
            out.append(tok)
        toks = jnp.concatenate(out, axis=1)
        return mask_after_stop(toks, tuple(stop_tokens), int(pad_id))


# ===================================================== continuous batching ==
@dataclasses.dataclass
class Request:
    """One generation request for ``ContinuousBatchingEngine.serve``.

    ``extras`` are this request's per-slot model inputs (vlm image embeds,
    encdec encoder output) WITHOUT a batch dim; every request in a trace
    must share the same extras structure/shapes (or all pass None).

    The SLO fields only matter under a ``ResiliencePolicy``
    (``serve_detailed``): ``arrival`` is when the request becomes
    admissible and ``deadline`` when its answer stops being useful, both
    in engine-clock seconds from serve start; ``slo`` is the priority
    class load-shedding protects (HIGHER sheds LAST).

    ``rid`` overrides the sampled-draw key id (defaults to the request's
    index in the trace).  Every sampled draw is keyed by (rid, counter),
    so a front-end that splits one logical trace across engine replicas
    (``serving.router``) pins each request's GLOBAL index here and every
    replica emits exactly the tokens a solo engine would."""

    prompt: np.ndarray  # (len,) int32 token ids
    max_new: int  # emit at most this many tokens (>= 1)
    stop_tokens: tuple = ()  # retire early after emitting any of these
    extras: Optional[dict] = None
    arrival: float = 0.0           # not admitted before this engine time
    deadline: Optional[float] = None  # shed from queue / flag miss past this
    slo: int = 1                   # shed priority class (lower sheds first)
    rid: Optional[int] = None      # sampled-draw key id (default: trace index)


def _admit_body(params, cfg: ModelConfig, cache, prompt, length, slot, pages,
                rid, key, temperature, extras, *, greedy: bool, top_k: int):
    """Admit one request: batch-1 single-pass prefill written STRAIGHT into
    the slot's pool pages and per-slot state row (``models.prefill`` with
    ``pages``/``slot`` — no temporary dense cache, no ``paged_insert``
    scatter round-trip), then sample the first token from the logits at the
    true prompt end with the request's draw-0 key (``key`` is the serve
    call's BASE key; recompute preemption re-derives the same key and
    replays the same token).  Compiled once per padded prompt length (a
    page multiple, carried by ``prompt``'s shape)."""
    logits, cache = prefill(params, cfg, prompt, cache, extras, length=length,
                            pages=pages, slot=slot)
    lg = jax.lax.dynamic_index_in_dim(logits, length - 1, axis=1,
                                      keepdims=False)  # (1, V)
    tok0 = sample_rows(
        lg, None if greedy else draw_keys(key, rid[None], 0, TAG_TOKEN),
        greedy=greedy, temperature=temperature, top_k=top_k)[0]
    return cache, tok0


_admit_prefill = functools.partial(
    jax.jit, static_argnames=("cfg", "greedy", "top_k"),
    donate_argnames=("cache",),
)(_admit_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "greedy", "top_k"),
    donate_argnames=("cache",),
)
def _admit_prefill_sharded(params, cfg: ModelConfig, cache, prompt, length,
                           slot, pages, rid, key, temperature, extras, *,
                           mesh, greedy: bool, top_k: int):
    """``_admit_body`` under ``shard_map``: sharded weights, replicated
    paged cache / prompt / scheduler scalars."""

    def f(p, c, pr, ln, sl, pg, ri, k, t, ex):
        return _admit_body(p, cfg, c, pr, ln, sl, pg, ri, k, t, ex,
                           greedy=greedy, top_k=top_k)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 9,
        out_specs=P(), check_rep=False,
    )(params, cache, prompt, length, slot, pages, rid, key, temperature,
      extras)


def _pool_leaf_paths(cfg: ModelConfig) -> tuple:
    """(cache key, leading stack dims) of every page-pool subtree for the
    family — the leaves a copy-on-write page fork must duplicate.  Every
    pool leaf (K/V, quantized codes + scales, MLA latents) carries its
    page axis immediately after the lead dims."""
    fam = cfg.family
    if fam == "dense":
        return (("layers", 1),)
    if fam == "moe":
        return ((("layers", 1), ("dense_layers", 1))
                if cfg.n_dense_layers else (("layers", 1),))
    if fam == "vlm":
        return (("groups_self", 2),)
    if fam == "encdec":
        return (("decoder", 1),)
    if fam == "hybrid":
        return (("groups_attn", 1),)
    return ()  # ssm: per-slot state only, nothing paged


@functools.partial(jax.jit, static_argnames=("keys",),
                   donate_argnames=("cache",))
def _copy_page(cache, src, dst, *, keys):
    """Device-side copy-on-write fork: duplicate pool page ``src`` into
    ``dst`` across every paged leaf (``keys`` from ``_pool_leaf_paths``)."""
    new = dict(cache)
    for key, lead in keys:
        idx = (slice(None),) * lead
        new[key] = jax.tree.map(
            lambda l: l.at[idx + (dst,)].set(l[idx + (src,)]), cache[key])
    return new


def _tail_verify_body(params, cfg: ModelConfig, cache, tokens, pos, slot,
                      rid, sample_at, key, temperature, extras, *,
                      greedy: bool, top_k: int, page_size: int):
    """Cached-admit tail: run ONE ``models.verify_step`` window over the
    unmatched tail of a prompt whose prefix pages were aliased from the
    trie.  ``tokens`` (B, T) is zero except the admitted slot's row (the
    padded tail), ``pos`` is zero except ``pos[slot] = tail start``; every
    other row's block-table row is zeroed by the caller, so their window
    writes land in the trash page.  Per window position the math matches
    ``models.prefill``/``decode_step`` exactly (same projections, masks,
    float association — the bit-identity the prefix cache's token-identity
    bar rests on), and the first token is sampled from the logits at the
    true prompt end with the request's draw-0 key, exactly like
    ``_admit_body``."""
    logits, cache = verify_step(params, cfg, tokens, cache, pos, extras,
                                page_size=page_size)
    lg = jax.lax.dynamic_index_in_dim(logits, slot, axis=0, keepdims=False)
    lg = jax.lax.dynamic_index_in_dim(lg, sample_at, axis=0,
                                      keepdims=False)  # (V,)
    tok0 = sample_rows(
        lg[None], None if greedy else draw_keys(key, rid[None], 0, TAG_TOKEN),
        greedy=greedy, temperature=temperature, top_k=top_k)[0]
    return cache, tok0


_tail_verify = functools.partial(
    jax.jit, static_argnames=("cfg", "greedy", "top_k", "page_size"),
    donate_argnames=("cache",),
)(_tail_verify_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "greedy", "top_k", "page_size"),
    donate_argnames=("cache",),
)
def _tail_verify_sharded(params, cfg: ModelConfig, cache, tokens, pos, slot,
                         rid, sample_at, key, temperature, extras, *, mesh,
                         greedy: bool, top_k: int, page_size: int):
    """``_tail_verify_body`` under ``shard_map``: sharded weights,
    replicated cache/window operands."""

    def f(p, c, tk, ps_, sl, ri, sa, k, t, ex):
        return _tail_verify_body(p, cfg, c, tk, ps_, sl, ri, sa, k, t, ex,
                                 greedy=greedy, top_k=top_k,
                                 page_size=page_size)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 10,
        out_specs=P(), check_rep=False,
    )(params, cache, tokens, pos, slot, rid, sample_at, key, temperature,
      extras)


def _decode_chunk_body(params, cfg: ModelConfig, cache, tok, pos, n_out, done,
                       rids, max_new, stops, key, temperature, extras, *,
                       chunk: int, page_size: int, greedy: bool, top_k: int,
                       pad_id: int):
    """``chunk`` decode steps over all batch slots as one compiled scan.

    Per-slot carry: current token, position (cached length), emitted count,
    and done flag.  Done/inactive slots keep stepping (their writes land in
    their own pages or the trash page — harmless) but their emissions are
    masked; the host retires/admits at the chunk boundary.  Sampled draws
    are keyed per slot by ``(key, rid, n_out)`` — the same stream the
    fixed-batch engine consumes — so a request's tokens never depend on
    slot assignment or chunk boundaries."""

    def body(carry, _):
        tok, cache, pos, n_out, done = carry
        lg, cache = decode_step(params, cfg, tok, cache, pos, extras,
                                page_size=page_size)
        nxt = sample_rows(
            lg[:, -1, :],
            None if greedy else draw_keys(key, rids, n_out, TAG_TOKEN),
            greedy=greedy, temperature=temperature, top_k=top_k)
        live = ~done
        emit = jnp.where(live, nxt, jnp.int32(pad_id))
        pos = jnp.where(live, pos + 1, pos)
        n_out = jnp.where(live, n_out + 1, n_out)
        hit = jnp.any(emit[:, None] == stops, axis=1)
        done = done | (live & hit) | (n_out >= max_new)
        return (emit[:, None], cache, pos, n_out, done), (emit, live)

    carry, (emits, lives) = jax.lax.scan(
        body, (tok, cache, pos, n_out, done), None, length=chunk)
    tok, cache, pos, n_out, done = carry
    return cache, tok, pos, n_out, done, emits, lives


_decode_chunk = functools.partial(
    jax.jit,
    static_argnames=("cfg", "chunk", "page_size", "greedy", "top_k", "pad_id"),
    donate_argnames=("cache",),
)(_decode_chunk_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "chunk", "page_size", "greedy", "top_k",
                     "pad_id"),
    donate_argnames=("cache",),
)
def _decode_chunk_sharded(params, cfg: ModelConfig, cache, tok, pos, n_out,
                          done, rids, max_new, stops, key, temperature,
                          extras, *, mesh, chunk: int, page_size: int,
                          greedy: bool, top_k: int, pad_id: int):
    """``_decode_chunk_body`` under ``shard_map``: the paged pools, block
    tables, and per-slot scheduler carry are replicated (they are tiny next
    to the weight stream); only the weight shards differ per device."""

    def f(p, c, tk, ps_, no, dn, ri, mn, st, k, t, ex):
        return _decode_chunk_body(p, cfg, c, tk, ps_, no, dn, ri, mn, st, k,
                                  t, ex, chunk=chunk, page_size=page_size,
                                  greedy=greedy, top_k=top_k, pad_id=pad_id)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 11,
        out_specs=P(), check_rep=False,
    )(params, cache, tok, pos, n_out, done, rids, max_new, stops, key,
      temperature, extras)


class ContinuousBatchingEngine:
    """Continuous-batching scheduler over a paged KV cache (see module
    docstring for the page/block-table layout and scheduler states).

    ``slots`` is the decode batch width; ``num_pages`` bounds total cache
    memory (pages are ``page_size`` tokens each, page 0 is the trash page);
    ``max_seq`` caps a single request's ``prompt + max_new``; ``chunk`` is
    how many decode steps run per compiled program between host scheduling
    points.  Per-request model inputs (vlm image embeds, encdec encoder
    output) ride on ``Request.extras``: admit writes them into the
    request's slot row of a per-slot device buffer, so a request keeps its
    own conditioning no matter which slot it lands in.

    ``page_alloc_seed`` shuffles the free list so block tables become random
    permutations of physical pages — decode must be layout-independent
    (tests/test_paged_serving.py exercises this).

    ``speculate`` (``serving.SpecConfig`` or int ``k``) turns each
    decode-chunk iteration into a speculative verify window: every slot
    proposes ``k`` tokens (its own history via the n-gram proposer, or the
    engine's draft model), the target verifies the window in one pass, and
    each slot advances by its own accepted length — per-slot position/page
    advance stays exact because rejected page writes are dead by masking
    and rewritten by the next window (``models.verify_step``).  Greedy
    output tokens are identical to the non-speculative engine; sampled
    output (``serve(greedy=False)``) is rejection-sampling verified —
    distributionally identical to plain sampled decode and
    key-deterministic per request (``serving.sampling``).
    ``mode="draft"`` (constructed with ``draft_cfg``/``draft_params``)
    keeps the draft model's state in its OWN paged cache pool sharing the
    target's block tables, so draft speculation survives admit/retire and
    recompute preemption like any other per-slot state.  After ``serve``,
    ``spec_emitted / decode_chunk_iters`` is the realised weight-stream
    amortisation (chunk iterations = streams paid, counted for the plain
    engine too so the two are comparable) and
    ``spec_emitted / spec_live_steps`` the per-slot window acceptance."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_seq: int,
                 page_size: int = 8, num_pages: Optional[int] = None,
                 chunk: int = 8, pim_bits: int = 0, pad_id: int = 0,
                 page_alloc_seed: Optional[int] = None, mesh=None,
                 speculate=None, draft_cfg: ModelConfig = None,
                 draft_params=None, draft_pim_bits: int = 0, clock=None,
                 prefix_cache: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        # ``clock``: a 0-arg monotonic-seconds callable (time.monotonic by
        # default; chaos.VirtualClock in tests) — drives request timing,
        # deadlines, and retry backoff in ``serve_detailed``.
        self._clock = clock if clock is not None else time.monotonic
        self.last_snapshot = None  # latest ServeSnapshot (crash recovery)
        self.last_round = -1
        self.last_report = None
        self.spec = None if speculate is None else spec_mod.as_spec(speculate)
        if self.spec is not None and self.spec.mode == "draft":
            if draft_params is None or draft_cfg is None:
                raise ValueError(
                    "speculate mode='draft' needs the engine constructed "
                    "with draft_cfg/draft_params")
            if mesh is not None:
                raise NotImplementedError(
                    "draft-model speculation is single-device (the draft "
                    "tree is not mesh-distributed); use mode='ngram' on a "
                    "mesh")
        params = quantize_tree(params, pim_bits) if pim_bits else params
        if mesh is not None:
            params = shard_quantized_tree(params, mesh)
        self.params = params
        self.draft_cfg = draft_cfg
        if draft_params is not None and draft_pim_bits:
            draft_params = quantize_tree(draft_params, draft_pim_bits)
        self.draft_params = draft_params
        self._draft_mode = (self.spec is not None
                            and self.spec.mode == "draft")
        self.slots = int(slots)
        self.page_size = int(page_size)
        self.max_seq = -(-int(max_seq) // self.page_size) * self.page_size
        # Draft mode: the draft chain READS back the speculative positions
        # it just wrote (the target only writes them), so both pools carry
        # k extra provisioned positions past the request frontier — even a
        # request using the full max_seq budget must never route a draft
        # read through the shared trash page, or cross-engine
        # key-determinism breaks at the boundary.  Tree mode needs a
        # bigger reserve, fan*k: ``models.tree_relocate`` GATHERS the
        # accepted chain's rows from their tree columns (up to
        # pos + fan*k) before scattering them into the linear layout, and
        # a gather through the trash page would corrupt committed
        # positions, not merely waste a proposal.
        if self._draft_mode:
            reserve = self.spec.k
        elif self.spec is not None and self.spec.tree_fan:
            reserve = self.spec.tree_fan * self.spec.k
        else:
            reserve = 0
        self._store_seq = self.max_seq + (
            -(-reserve // self.page_size) * self.page_size)
        self.width = self._store_seq // self.page_size
        if num_pages is None:
            num_pages = self.slots * self.width + 1  # worst case + trash page
        self.num_pages = int(num_pages)
        self.chunk = int(chunk)
        self.pad_id = int(pad_id)
        self._rng = (np.random.default_rng(page_alloc_seed)
                     if page_alloc_seed is not None else None)
        # Shared-prefix page cache (module docstring): only families whose
        # prefill/verify logits agree bitwise are eligible, and the draft
        # pool has no trie (its pages would alias stale draft KV), so
        # ineligible configurations silently never hit.
        self.prefix_cache = bool(prefix_cache)
        self._prefix_on = (self.prefix_cache
                           and cfg.family in _PREFIX_FAMILIES
                           and not getattr(cfg, "mla", None)
                           and not self._draft_mode)
        # Strict pending sweep (serve_detailed): None defers to the
        # REPRO_STRICT_SERVE env var (tests set it); an explicit bool wins.
        self.strict_pending: Optional[bool] = None
        # Test hook: request indices the admission loop silently drops —
        # simulates a scheduler bug so the strict sweep's detection is
        # itself testable.
        self._debug_drop_rids: set[int] = set()
        self._pool_poisoned = False
        # Telemetry plumbing for helpers that fire outside the serve loop's
        # lexical scope (_preempt_slot/_shed): the live RequestRecord
        # list and the engine-clock closure of the current serve call.
        self._records = None
        self._now = lambda: 0.0
        self.prefix_hits = 0        # cached admits (>= 1 page aliased)
        self.prefix_hit_tokens = 0  # prompt tokens served from aliased pages
        self.prefill_tokens = 0     # prompt tokens actually computed
        self.cow_forks = 0          # copy-on-write page forks
        self.peak_pages_in_use = 0
        self.preemptions = 0
        self.spec_emitted = 0  # tokens emitted by speculative verify windows
        self.spec_live_steps = 0  # live (slot, iteration) verify windows
        # chunk iterations executed, speculative or not — each streams the
        # weight tree once (idle iterations after every slot finishes
        # mid-chunk still pay): chunk-emitted tokens / decode_chunk_iters
        # is the realised weight-stream amortisation, comparable between
        # the plain and speculative engines; spec_emitted/spec_live_steps
        # is the per-slot window acceptance (proposer quality).
        self.decode_chunk_iters = 0
        # Debug invariant (enabled by tests): after every speculative
        # chunk, each live slot's n-gram history row must equal its
        # admitted prompt followed by every emitted token — across ladder
        # no_spec rounds, recompute preemption, and crash-replay resume.
        self.debug_check_hist = False

    # ------------------------------------------------------------- helpers --
    def _spad(self, length: int) -> int:
        """Prompt length padded up to a whole number of pages."""
        ps = self.page_size
        return max(ps, -(-length // ps) * ps)

    def _set_slot_extras(self, slot: int, extras: Optional[dict]):
        """Write a request's extras into its slot row of the per-slot
        buffer the decode chunk reads; returns the batch-1 view for the
        admit prefill."""
        if extras is None:
            return None
        ex = jax.tree.map(jnp.asarray, extras)
        if self._extras_slots is None:
            self._extras_slots = jax.tree.map(
                lambda v: jnp.zeros((self.slots,) + v.shape, v.dtype), ex)
        self._extras_slots = jax.tree.map(
            lambda buf, v: buf.at[slot].set(v), self._extras_slots, ex)
        return jax.tree.map(lambda v: v[None], ex)

    def pages_in_use(self) -> int:
        """Pages with live block-table references.  Retained prefix-cache
        pages (refcount 0, evictable on demand) count as NOT in use —
        they are reclaimable capacity, exactly like free pages."""
        return self._pool.in_use()

    def _alloc_pages(self, n: int) -> list[int]:
        return self._pool.alloc(n)

    def _free_pages(self, pages: list[int]) -> None:
        for p in pages:
            self._pool.release(p)

    def assert_quiescent(self) -> None:
        """Page-pool invariant at quiescence (no live slots): every page
        holds zero references and sits on the free list or the retained
        prefix-cache LRU exactly once.  ``serve_detailed`` checks this
        after every completed trace, so a scheduling path that leaks or
        double-frees pages fails loudly in ANY test that serves to
        completion.  A pool poisoned by an abnormal serve exit (escaped
        ``EngineCrash``/fault mid-round) fails until the next serve's
        ``_reset`` — its mid-trace state proves nothing either way."""
        if self._pool_poisoned:
            raise AssertionError(
                "page pool poisoned: a serve trace aborted mid-round, so "
                "allocator state is mid-flight, not quiescent; start a new "
                "serve (or _reset) before asserting invariants")
        self._pool.assert_quiescent()

    # ------------------------------------------------------------ lifecycle --
    def _reset(self, requests, n_stops: int):
        b, w = self.slots, self.width
        self._cache = init_paged_cache(self.cfg, b, self._store_seq,
                                       self.num_pages, self.page_size)
        # The draft model's OWN paged pool: same geometry and the same
        # block tables as the target's, so one host-side page allocator
        # covers both and admit/retire/preemption keep them in lockstep.
        self._dcache = (init_paged_cache(self.draft_cfg, b, self._store_seq,
                                         self.num_pages, self.page_size)
                        if self._draft_mode else ())
        # Refcounted page pool + (fresh) prefix trie: trie-registered pages
        # are only valid against THIS pool's device storage, so both reset
        # together — prefix reuse is within one serve trace, which is where
        # repeated system prompts actually collide.  Page 0 = trash.
        self._trie = PrefixTrie() if self._prefix_on else None
        self._pool = PagePool(
            self.num_pages, rng=self._rng,
            on_evict=self._trie.drop_page if self._trie is not None else None)
        self._pool_poisoned = False
        self._plen = np.zeros(b, np.int32)  # prompt length per slot
        self._bt = np.zeros((b, w), np.int32)
        self._pos = np.zeros(b, np.int32)
        self._n_out = np.zeros(b, np.int32)
        self._done = np.ones(b, bool)  # inactive slots are "done"
        self._max_new = np.zeros(b, np.int32)
        self._stops = np.full((b, n_stops), -1, np.int32)
        self._tok = np.zeros((b, 1), np.int32)
        # per-slot request id and verify-window counter: the (rid, counter)
        # pair keys every sampled draw, so a request's random stream is
        # slot- and schedule-independent (sampling.draw_keys)
        self._rids = np.zeros(b, np.int32)
        self._wctr = np.zeros(b, np.int32)
        self._slot_req = [-1] * b
        self._slot_pages: list[list[int]] = [[] for _ in range(b)]
        self._admit_seq = [-1] * b
        self._seq = 0
        self._outputs = [[] for _ in requests]
        self._queue = deque(range(len(requests)))
        self._extras_slots = None
        # per-slot token history (prompt + emissions) for the n-gram
        # proposer; rewritten whole at admit, so stale rows never leak
        self._hist = np.zeros((b, self.max_seq), np.int32)
        # per-slot acceptance EMA for the adaptive controller: updated by
        # the spec chunk, read by ``adaptive_k_host`` each round, carried
        # through snapshots so crash replay resumes the learned rate
        self._acc_ema = np.zeros(b, np.float32)
        # controller probation: a freshly admitted slot gets one SHORT
        # round before the controller commits to a full-length one, so
        # the first wide window is picked from a measured EMA rather
        # than ``ctrl_init``
        self._ctrl_fresh = np.zeros(b, bool)

    def _prefix_probe(self, req, resume):
        """(chunk keys, extras fingerprint, matched trie pages) for a fresh
        request under an active prefix cache; ``([], None, [])`` otherwise.
        Pure probe — no refcount or LRU side effects, so the admission
        gate and ``_admit`` can both call it.  Resume admits never match:
        their rebuilt sequence embeds emitted tokens and must replay
        through the exact full-prefill path the snapshot semantics pin."""
        if not self._prefix_on or resume is not None:
            return [], None, []
        keys = chunk_keys(np.asarray(req.prompt, np.int32), self.page_size)
        fp = extras_fingerprint(req.extras)
        return keys, fp, self._trie.match(keys, fp)

    def _admit_page_need(self, req, resume) -> tuple[int, list[int]]:
        """(fresh pages the admit itself would allocate, trie pages it
        would alias) — the admission gate's capacity probe.  Admission is
        deliberately optimistic (prompt footprint only, not the first
        chunk's growth): if the same round's ``_top_up`` then finds the
        pool dry, the freshly admitted slot — necessarily the youngest —
        YIELDS by requeueing itself rather than preempting an elder, so
        optimism can waste a prefill but can never livelock (see
        ``_top_up``)."""
        L = len(req.prompt) + (len(resume.emitted) - 1 if resume else 0)
        total = self._spad(L) // self.page_size
        _, _, matched = self._prefix_probe(req, resume)
        if matched and len(matched) == total and L == total * self.page_size:
            # Full-prefix hit: only the CoW fork of the last page is fresh.
            return 1, matched
        return total - len(matched), matched

    def _admit(self, requests, slot: int, ridx: int, greedy, temperature,
               top_k, resume: Optional[InflightState] = None) -> dict:
        """Admit request ``ridx`` into ``slot``; returns admit telemetry
        (``cached_tokens``/``prefilled_tokens``/``cow``).

        A prefix-trie hit aliases the matched pages into the slot's block
        table (refcount + 1 each) and computes only the unmatched tail via
        ONE ``models.verify_step`` window (``_tail_verify``) — sampling the
        first token from the logits at the true prompt end with the same
        (rid, 0) draw key as the full-prefill path, so a hit is
        token-identical to a miss.  A FULL-prefix hit still has to run the
        last prompt position for its logits, and that write lands inside
        the final matched page — the page is forked copy-on-write first
        (``_copy_page``), so the trie's copy and every aliasing sibling
        keep their bytes.  An uncached admit full-prefills as before and
        then registers its prompt's full pages in the trie.

        With ``resume`` (crash replay, resume_mode="prefill") the request
        is re-admitted mid-stream: ONE prefill pass over
        ``prompt + emitted[:-1]`` rebuilds its KV pages, the last emission
        becomes the slot's current token, and the token draw counter
        restarts at ``len(emitted)`` — the fold_in (rid, counter) keys
        then continue the exact random stream the crashed run was
        consuming, so replay is token-identical."""
        req = requests[ridx]
        ps = self.page_size
        length = len(req.prompt)
        rid = ridx if req.rid is None else int(req.rid)
        emitted = [int(t) for t in resume.emitted] if resume is not None else []
        m = len(emitted)
        seq = np.asarray(req.prompt, np.int32)
        if m:
            seq = np.concatenate(
                [seq, np.asarray(emitted[:-1], np.int32)])
        L = len(seq)  # length + m - 1 when resuming
        spad = self._spad(L)
        total = spad // ps
        ex1 = self._set_slot_extras(slot, req.extras)
        keys, fp, matched = self._prefix_probe(req, resume)
        cow = False
        if matched and len(matched) == total and L == total * ps:
            # Full-prefix hit: every prompt position is cached, but the
            # logits at L-1 must still be computed, and verify_step writes
            # that position's K/V — into the final matched page, which the
            # trie (and possibly siblings) still read.  Fork it.
            for p in matched:
                self._pool.acquire(p)
            fork = self._pool.alloc(1)[0]
            self._cache = _copy_page(
                self._cache, jnp.int32(matched[-1]), jnp.int32(fork),
                keys=_pool_leaf_paths(self.cfg))
            self._pool.release(matched[-1])
            pages = matched[:-1] + [fork]
            tail_start = L - 1
            cow = True
            self.cow_forks += 1
        elif matched:
            # Partial hit: alias the matched pages, allocate only the tail.
            for p in matched:
                self._pool.acquire(p)
            pages = matched + self._pool.alloc(total - len(matched))
            tail_start = len(matched) * ps
        else:
            pages = self._pool.alloc(total)
            tail_start = 0
        self._bt[slot, :] = 0
        self._bt[slot, : len(pages)] = pages
        if matched:
            # Cached admit: one verify window over the padded tail.  Only
            # this slot's block-table row is exposed — every other row's
            # window writes go to the trash page.
            tokens = np.zeros((self.slots, spad - tail_start), np.int32)
            tokens[slot, : L - tail_start] = seq[tail_start:]
            pos = np.zeros(self.slots, np.int32)
            pos[slot] = tail_start
            bt_masked = np.zeros_like(self._bt)
            bt_masked[slot] = self._bt[slot]
            self._cache["block_tables"] = jnp.asarray(bt_masked)
            tail = (_tail_verify if self.mesh is None else functools.partial(
                _tail_verify_sharded, mesh=self.mesh))
            self._cache, tok0 = tail(
                self.params, self.cfg, self._cache, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.int32(slot), jnp.int32(rid),
                jnp.int32(L - 1 - tail_start), self._key,
                jnp.float32(temperature), self._extras_slots,
                greedy=bool(greedy), top_k=int(top_k),
                page_size=self.page_size)
            self.prefix_hits += 1
            self.prefix_hit_tokens += tail_start
            self.prefill_tokens += L - tail_start
        else:
            prompt = np.zeros((1, spad), np.int32)
            prompt[0, :L] = seq
            admit = (_admit_prefill if self.mesh is None
                     else functools.partial(_admit_prefill_sharded,
                                            mesh=self.mesh))
            self._cache, tok0 = admit(
                self.params, self.cfg, self._cache, jnp.asarray(prompt),
                jnp.int32(L), jnp.int32(slot), jnp.asarray(pages, jnp.int32),
                jnp.int32(rid), self._key, jnp.float32(temperature), ex1,
                greedy=bool(greedy), top_k=int(top_k))
            if self._draft_mode:
                # Prefill the draft pool's copy of the prompt into the SAME
                # page ids (its own storage); the draft admit's sample is
                # discarded — tok0 always comes from the target.
                self._dcache, _ = _admit_prefill(
                    self.draft_params, self.draft_cfg, self._dcache,
                    jnp.asarray(prompt), jnp.int32(L), jnp.int32(slot),
                    jnp.asarray(pages, jnp.int32), jnp.int32(rid), self._key,
                    jnp.float32(temperature), ex1, greedy=True, top_k=0)
            self.prefill_tokens += L
            if self._prefix_on and resume is None:
                # Register the prompt's FULL pages: their positions are
                # final (decode writes start at L) and their content came
                # from the exact full-prefill computation a later miss
                # would run, so hits can be bit-identical.  Verify-written
                # tail pages of cached admits are never registered.
                self._trie.insert(keys, fp, pages,
                                  on_new=self._pool.mark_cached)
        if not m:
            # Fresh admit: the prefill's sample IS emission 0 (draw key 0).
            emitted = [int(tok0)]
        # Resume admit: the prefill re-sampled draw 0 — discarded; draws
        # are keyed by (rid, counter), not sequentially consumed, so the
        # stream resumes at counter m untouched.
        st = tuple(req.stop_tokens)
        self._outputs[ridx] = list(emitted)
        self._hist[slot, :] = 0
        self._hist[slot, :length] = np.asarray(req.prompt, np.int32)
        self._hist[slot, length : length + len(emitted)] = emitted
        self._plen[slot] = length
        self._pos[slot] = length + len(emitted) - 1
        self._n_out[slot] = len(emitted)
        self._max_new[slot] = req.max_new
        self._stops[slot, :] = -1
        self._stops[slot, : len(st)] = st
        self._tok[slot, 0] = emitted[-1]
        self._rids[slot] = rid
        self._wctr[slot] = int(resume.wctr) if resume is not None else 0
        self._acc_ema[slot] = (float(resume.acc_ema) if resume is not None
                               else (self.spec.ctrl_init
                                     if self.spec is not None else 0.0))
        self._ctrl_fresh[slot] = True
        self._done[slot] = (len(emitted) >= req.max_new
                            or emitted[-1] in st)
        self._slot_req[slot] = ridx
        self._slot_pages[slot] = list(pages)
        self._admit_seq[slot] = self._seq
        self._seq += 1
        return {"cached_tokens": tail_start,
                "prefilled_tokens": L - tail_start if matched else L,
                "cow": cow}

    def _retire(self, slot: int) -> None:
        self._free_pages(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slot_req[slot] = -1
        self._admit_seq[slot] = -1
        self._bt[slot, :] = 0
        self._pos[slot] = 0
        self._n_out[slot] = 0
        self._max_new[slot] = 0
        self._stops[slot, :] = -1
        self._rids[slot] = 0
        self._wctr[slot] = 0
        self._acc_ema[slot] = 0.0
        self._ctrl_fresh[slot] = False
        self._done[slot] = True

    def _preempt_slot(self, victim: int) -> None:
        """Recompute preemption: requeue ``victim``'s request at the queue
        head and free its pages.  Progress is discarded; replay is exact
        (draws are (rid, counter)-keyed)."""
        ridx = self._slot_req[victim]
        self._outputs[ridx].clear()
        self._queue.appendleft(ridx)
        self._retire(victim)
        self.preemptions += 1
        if self._records is not None:
            self._records[ridx].events.append(
                {"name": "preempt", "ts": self._now(), "slot": victim})

    def _top_up(self, requests, slot: int,
                eff_chunk: Optional[int] = None,
                eff_k: Optional[int] = None) -> None:
        """Extend the slot's block table to cover the next chunk's writes.
        If the pool runs dry, younger live requests are recompute-preempted
        — unless THIS slot is the youngest, in which case it yields
        (requeues itself) so elders keep their progress; see the loop
        below for why preempting upward would livelock.

        ``eff_chunk``/``eff_k`` are the ROUND's effective scheduling
        parameters (the degradation ladder may shrink them below the
        engine's configured ``chunk``/``spec.k``; ``eff_k=None`` means no
        speculative window this round, so no verify-window overdraw)."""
        req = requests[self._slot_req[slot]]
        ps = self.page_size
        length = len(req.prompt)
        spad = self._spad(length)
        # Live CONSUMED positions in the next chunk reach pos + advance - 1
        # (advance = chunk steps x the window's worst-case accepted length),
        # bounded by the last live write position length + max_new - 2;
        # prefill already covered spad - 1.  Speculative writes BEYOND the
        # consumed frontier need no pages for the TARGET: an unprovisioned
        # block-table entry is 0, the trash page, and the verify window
        # attends to its own in-flight K/V, so a token only ever gets
        # consumed after being rewritten into a provisioned page.  The
        # DRAFT chain, however, runs k+1 sequential single-token steps that
        # READ BACK the window positions they just wrote, so draft mode
        # provisions up to k positions past the consumed cap (the pools
        # carry k extra positions past max_seq for exactly this — see
        # ``_store_seq``) to keep those reads out of the shared trash page:
        # a trash read would only degrade proposal quality, never
        # exactness, but it would break cross-engine key-determinism.
        chunk = self.chunk if eff_chunk is None else eff_chunk
        k = (self.spec.k if self.spec is not None else None) \
            if eff_chunk is None else eff_k  # default call = engine config
        fan = self.spec.tree_fan if self.spec is not None else 0
        adv = chunk * (k + 1 if k is not None else 1)
        cap = length + req.max_new - 2
        if self._draft_mode and k is not None:
            cap = min(cap + k, self._store_seq - 1)
        if fan and k is not None:
            # Tree relocation GATHERS from the tree columns (up to
            # pos + fan*k past the frontier) before scattering into the
            # linear layout — those sources must be provisioned pages, or
            # the gather reads the shared trash page and corrupts
            # committed positions.  Each iteration advances at most k+1.
            adv = chunk * (k + 1) + fan * k
            cap = min(cap + fan * k, self._store_seq - 1)
        last = min(int(self._pos[slot]) + adv - 1, cap)
        need = max(last, spad - 1) // ps + 1
        have = len(self._slot_pages[slot])
        if need <= have:
            return
        while self._pool.available() < need - have:
            live = [s for s in range(self.slots) if self._slot_req[s] >= 0]
            youngest = max(live, key=lambda s: self._admit_seq[s])
            if youngest != slot:
                self._preempt_slot(youngest)
                continue
            if len(live) == 1:
                raise RuntimeError(
                    f"page pool exhausted ({self.num_pages} pages of "
                    f"{ps} tokens) with a single live request; increase "
                    "num_pages")
            # This slot is the YOUNGEST live request and the pool is dry:
            # yield by requeueing ITSELF instead of stealing pages from an
            # elder that already has progress.  Preempting upward here is
            # the livelock: on a pool just big enough to re-admit the
            # victim, two symmetric requests alternate evicting each other
            # pre-decode forever (each re-admit's same-round top-up fires
            # before either emits a token, and recompute preemption
            # discards everything).  Yielding makes progress monotone for
            # the oldest request — it always completes, frees its pages,
            # and unblocks the queue.
            self._preempt_slot(slot)
            return
        pages = self._alloc_pages(need - have)
        self._bt[slot, have:need] = pages
        self._slot_pages[slot].extend(pages)

    # --------------------------------------------------------------- serve --
    def serve(self, requests: Sequence[Request], *, greedy: bool = True,
              temperature: float = 1.0, top_k: int = 0, key=None,
              policy=None, chaos=None) -> list[np.ndarray]:
        """Run every request through the scheduler; returns one int32 array
        of emitted tokens per request (<= max_new; ends at the stop token
        if one fired).  Deterministic for a fixed key — and because draws
        are keyed per (request index in the trace, counter), a request's
        sampled tokens are independent of slot assignment, chunk size, and
        page allocation, and match the dense fixed-batch engine run in
        which it occupies the SAME batch row index (the fixed engine keys
        row i's draws by rid=i).  A solo batch-1 dense run matches request
        0 only; greedy decode matches solo runs regardless.

        Thin wrapper over ``serve_detailed`` (which adds per-request
        deadlines/SLOs, load shedding, fault retry, degradation, and crash
        snapshots under a ``resilience.ResiliencePolicy``); without a
        policy the scheduler behaves exactly as before — invalid requests
        raise, faults propagate.  The full ``ServeReport`` of the last
        call is kept on ``self.last_report``."""
        report = self.serve_detailed(
            requests, greedy=greedy, temperature=temperature, top_k=top_k,
            key=key, policy=policy, chaos=chaos)
        return [r.tokens for r in report.records]

    def _shed(self, records, report, ridx: int, reason: str) -> None:
        rec = records[ridx]
        rec.status, rec.reason = "shed", reason
        rec.tokens = np.asarray(self._outputs[ridx], np.int32)
        rec.events.append({"name": "shed", "ts": self._now(),
                           "reason": reason})
        report.sheds += 1

    def _finish(self, requests, records, slot: int, t: float) -> None:
        """Retire a finished slot, stamping completion time and deadline
        attainment on its record.  ``t`` is the slot's OWN completion
        estimate — the round boundary interpolated to the chunk iteration
        the slot actually finished in (see ``ServeReport.latencies`` for
        the residual quantization)."""
        ridx = self._slot_req[slot]
        rec = records[ridx]
        rec.tokens = np.asarray(self._outputs[ridx], np.int32)
        rec.status = "done"
        rec.t_done = t
        rec.events.append({"name": "finish", "ts": t,
                           "tokens": len(rec.tokens)})
        dl = requests[ridx].deadline
        rec.met_deadline = None if dl is None else bool(t <= dl)
        self._retire(slot)

    def _take_snapshot(self, records, policy, rnd: int) -> ServeSnapshot:
        """Host-side recovery point: finished outputs + in-flight replay
        state (emitted tokens + verify-window counter, admit order
        preserved) + the queue.  No device state — resume rebuilds KV
        pages by re-prefilling (see ``_admit``)."""
        live = sorted((s for s in range(self.slots)
                       if self._slot_req[s] >= 0),
                      key=lambda s: self._admit_seq[s])
        inflight = {}
        for s in live:
            ridx = self._slot_req[s]
            inflight[ridx] = InflightState(
                emitted=[int(t) for t in self._outputs[ridx]],
                wctr=int(self._wctr[s]),
                t_admit=records[ridx].t_admit,
                t_first=records[ridx].t_first,
                acc_ema=float(self._acc_ema[s]))
        snap = ServeSnapshot(
            finished={i: [int(t) for t in self._outputs[i]]
                      for i, r in enumerate(records) if r.status == "done"},
            inflight=inflight,
            queued=list(self._queue),
            closed={i: (r.status, r.reason) for i, r in enumerate(records)
                    if r.status in ("shed", "rejected")},
            round=rnd)
        self.last_snapshot = snap
        if policy is not None and policy.snapshot_sink is not None:
            policy.snapshot_sink(snap)
        return snap

    def serve_detailed(self, requests: Sequence[Request], *,
                       greedy: bool = True, temperature: float = 1.0,
                       top_k: int = 0, key=None,
                       policy: Optional[ResiliencePolicy] = None,
                       chaos=None, resume: Optional[ServeSnapshot] = None,
                       heartbeat=None) -> ServeReport:
        """``serve`` with the resilience layer: returns a ``ServeReport``
        with per-request outcomes (done/shed/rejected + timing) and the
        round-level counters.  See ``serving.resilience`` for the full
        failure semantics (what is retried, shed, rejected, degraded, and
        replayed).

        ``policy`` enables request-level robustness: admission validation
        (invalid/corrupt payloads become status "rejected" instead of
        raising), deadline and queue-bound load shedding, per-chunk
        retry-with-backoff for transient ``ChunkFault``s, the degradation
        ladder, and periodic ``ServeSnapshot``s.  ``chaos`` (a
        ``chaos.FaultInjector``) injects seeded failures at the scheduling
        boundaries; passing chaos without a policy gets the default
        ``ResiliencePolicy()``.  ``resume`` replays a snapshot: finished/
        closed requests keep their outcome, in-flight requests re-admit
        mid-stream (resume_mode="prefill"; exact for every family whose
        prefill and decode paths agree bit-wise — MLA's absorbed decode
        differs at ~1e-3, use "recompute" there) or requeue from scratch
        ("recompute", universally exact, same semantics as recompute
        preemption).  ``heartbeat`` is called once per scheduling round
        (the supervisor's liveness signal).  Timing (``t_admit``/
        ``t_done``/deadlines) is engine-clock seconds from THIS call's
        start, plus accumulated skew: injected straggler latency, retry
        backoff, and ``policy.round_time`` per round — fully deterministic
        under a ``chaos.VirtualClock``.

        On ``EngineCrash`` (injected, retry exhaustion, or a wrapped
        compiled-step failure) the latest snapshot stays on
        ``self.last_snapshot`` for the supervisor to replay."""
        if chaos is not None and policy is None:
            policy = ResiliencePolicy()
        hardened = policy is not None
        ex_struct = jax.tree.structure(requests[0].extras) if requests else None
        records = [RequestRecord() for _ in requests]
        rejected_upfront: set[int] = set()
        for i, r in enumerate(requests):
            bad = None
            if len(r.prompt) < 1 or r.max_new < 1:
                bad = "requests need len(prompt) >= 1, max_new >= 1"
            elif len(r.prompt) + r.max_new > self.max_seq:
                bad = (f"prompt ({len(r.prompt)}) + max_new ({r.max_new}) "
                       f"exceeds max_seq ({self.max_seq})")
            if bad is not None:
                if not hardened:
                    raise ValueError(bad)
                records[i].status, records[i].reason = "rejected", bad
                rejected_upfront.add(i)
                continue
            if jax.tree.structure(r.extras) != ex_struct:
                raise ValueError(
                    "all requests in a trace must share the same extras "
                    "structure (the decode chunk is one compiled program)")
        self._key = key if key is not None else jax.random.PRNGKey(0)
        n_stops = max((len(r.stop_tokens) for r in requests), default=0)
        self._reset(requests, n_stops)
        self.peak_pages_in_use = 0
        self.spec_emitted = 0
        self.spec_live_steps = 0
        self.decode_chunk_iters = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefill_tokens = 0
        self.cow_forks = 0
        report = ServeReport(records=records)
        report.rejects += len(rejected_upfront)
        clock = self._clock
        t0 = clock()
        skew = 0.0  # injected latency + retry backoff + per-round time

        def now() -> float:
            return (clock() - t0) + skew

        self._records = records
        self._now = now

        ladder = DegradationLadder(
            policy.ladder if hardened else LadderConfig(enabled=False),
            has_spec=self.spec is not None)
        # ---- resume: restore finished/closed outcomes, rebuild the queue
        resume_inflight: dict[int, InflightState] = {}
        if resume is not None:
            for ridx, toks in resume.finished.items():
                records[ridx].status = "done"
                records[ridx].tokens = np.asarray(toks, np.int32)
                self._outputs[ridx] = [int(t) for t in toks]
            for ridx, (st, reason) in resume.closed.items():
                if ridx in rejected_upfront:
                    continue  # already re-rejected (and counted) upfront
                records[ridx].status, records[ridx].reason = st, reason
                if st == "shed":
                    report.sheds += 1
                else:
                    report.rejects += 1
            if hardened and policy.resume_mode == "prefill":
                resume_inflight = dict(resume.inflight)
                for ridx, st in resume.inflight.items():
                    records[ridx].t_admit = st.t_admit
                    records[ridx].t_first = st.t_first
            # "recompute" (or no policy): in-flight requests requeue from
            # scratch — same semantics as recompute preemption.
            self._queue = deque(
                list(resume.inflight)
                + [r for r in resume.queued if r not in rejected_upfront])
        else:
            self._queue = deque(i for i in range(len(requests))
                                if i not in rejected_upfront)
        self.last_snapshot = None
        snap_every = policy.snapshot_every if hardened else 0
        if snap_every:
            self._take_snapshot(records, policy, -1)

        rnd = 0
        # Exception safety: any abnormal exit from the round loop (escaped
        # EngineCrash, chunk fault, compiled-step failure) leaves allocator
        # state mid-trace — mark the pool poisoned until the next
        # ``_reset`` so it can't masquerade as quiescent and leaks can't
        # be silently rebuilt away.  Cleared on normal completion below.
        self._pool_poisoned = True
        while self._queue or any(r >= 0 for r in self._slot_req):
            self.last_round = rnd
            if heartbeat is not None:
                heartbeat()
            if chaos is not None:
                chaos.crash(rnd)  # raises EngineCrash; supervisor replays
            retries_before = report.retries
            preempt_before = self.preemptions
            sheds_round = 0
            # ---- queue management: deadline sheds, bounded queue, ladder
            if hardened:
                t = now()
                if policy.shed_expired:
                    for ridx in list(self._queue):
                        dl = requests[ridx].deadline
                        if dl is not None and t > dl:
                            self._queue.remove(ridx)
                            self._shed(records, report, ridx, "deadline")
                            sheds_round += 1
                if policy.max_queue is not None:
                    while len(self._queue) > policy.max_queue:
                        q = list(self._queue)
                        # lowest SLO class first; ties shed the youngest
                        i = min(range(len(q)),
                                key=lambda j: (requests[q[j]].slo, -j))
                        self._queue.remove(q[i])
                        self._shed(records, report, q[i], "queue")
                        sheds_round += 1
                if ladder.shedding():
                    for ridx in list(self._queue):
                        if requests[ridx].slo < ladder.cfg.protect_slo:
                            self._queue.remove(ridx)
                            self._shed(records, report, ridx, "ladder")
                            sheds_round += 1
            # ---- admit queued requests into free slots while pages last
            admitted_any = False
            blocked = False
            for slot in range(self.slots):
                if blocked or self._slot_req[slot] >= 0:
                    continue
                while self._queue:
                    ridx = self._queue[0]
                    if ridx in self._debug_drop_rids:
                        # Test hook: simulate a scheduler bug that loses a
                        # request on the floor, so the strict pending sweep
                        # below is itself testable.
                        self._queue.popleft()
                        continue
                    req = requests[ridx]
                    if hardened and req.arrival > now():
                        blocked = True  # FIFO: an unarrived head waits
                        break
                    prompt = np.asarray(req.prompt)
                    if chaos is not None:
                        prompt = chaos.corrupt_request(prompt, ridx, rnd)
                    if hardened and policy.validate:
                        arr = np.asarray(prompt)
                        if arr.size and (int(arr.min()) < 0
                                         or int(arr.max()) >= self.cfg.vocab):
                            self._queue.popleft()
                            records[ridx].status = "rejected"
                            records[ridx].reason = "corrupt"
                            report.rejects += 1
                            continue  # slot still free: try the next head
                    rs = resume_inflight.pop(ridx, None)
                    need, reserve = self._admit_page_need(req, rs)
                    if self._pool.available(reserve) < need:
                        if rs is not None:
                            resume_inflight[ridx] = rs  # retry next round
                        blocked = True
                        break
                    self._queue.popleft()
                    info = self._admit(requests, slot, ridx, greedy,
                                       temperature, top_k, resume=rs)
                    rec = records[ridx]
                    rec.slot = slot
                    if rec.t_admit is None:
                        rec.t_admit = now()
                        rec.t_first = rec.t_admit
                    rec.events.append({"name": "admit", "ts": now(),
                                       "slot": slot, "round": rnd, **info})
                    admitted_any = True
                    break
            # Retire anything that finished at admit (max_new==1 / instant
            # stop) so its slot and pages free up immediately.
            t_adm = now()
            for slot in range(self.slots):
                if self._slot_req[slot] >= 0 and self._done[slot]:
                    self._finish(requests, records, slot, t_adm)
            live = [s for s in range(self.slots) if self._slot_req[s] >= 0]
            if not live:
                if self._queue and not admitted_any:
                    head = self._queue[0]
                    if hardened and requests[head].arrival > now():
                        # Idle until the head arrives; advance deterministic
                        # time so a virtual clock cannot spin forever.
                        skew += policy.round_time or policy.backoff_s
                    elif hardened:
                        self._queue.popleft()
                        self._shed(records, report, head, "oom")
                    else:
                        # Nothing running and the head could not admit.
                        raise RuntimeError(
                            "page pool too small to admit request with "
                            f"prompt {len(requests[head].prompt)} tokens; "
                            "increase num_pages")
                rnd += 1
                continue
            # ---- effective scheduling parameters for this round (ladder)
            eff_chunk, eff_k = ladder.params(
                self.chunk, self.spec.k if self.spec is not None else None)
            if (self.spec is not None and self.spec.adaptive
                    and eff_k is not None):
                # Adaptive controller: the verify-window width is SHARED
                # across the batch (one compiled program per round), so
                # the round's k comes from the batch-aggregate expected
                # gain over the per-slot acceptance EMAs — composed with
                # the ladder as min(rung, controller).  A k == 0 pick
                # dispatches the genuine plain decode chunk below (the
                # fixed engine instead runs width-0 windows with the
                # in-loop ``_ctrl_probe``).
                alive = np.asarray(
                    [self._slot_req[s] >= 0 and not self._done[s]
                     for s in range(self.slots)])
                eff_k = min(eff_k,
                            spec_mod.adaptive_k_host(self._acc_ema, alive,
                                                     self.spec))
                # Shrink the chunk to the longest live remaining budget:
                # iterations past every slot's max_new stream weights for
                # nothing, and chunk boundaries never change a request's
                # token stream (draws are (rid, counter)-keyed).
                rem = int(max(
                    (self._max_new[s] - self._n_out[s]
                     for s in range(self.slots) if alive[s]), default=1))
                # Admission happens only at round boundaries, so when
                # requests are WAITING a slot that finishes mid-round
                # idles until the round ends.  End the round where the
                # first live slot can free (its remaining budget), and
                # the top-up refills it immediately — the fixed-chunk
                # plain baseline eats that idle tail.
                if self._queue:
                    rem = min(rem, int(min(
                        (self._max_new[s] - self._n_out[s]
                         for s in range(self.slots) if alive[s]),
                        default=rem)))
                if eff_k > 0:
                    # Wide window: cap the round at ceil(rem/(k+1))
                    # iterations — enough to cover the longest remaining
                    # budget — so the controller re-picks k from fresh
                    # EMAs instead of riding one stale pick for a whole
                    # ``chunk``.  A round containing a freshly admitted
                    # slot is cut to a 2-iteration probation round so its
                    # first full-width window is priced from a MEASURED
                    # acceptance rate, not ``ctrl_init``.
                    eff_chunk = min(eff_chunk, -(-rem // (eff_k + 1)))
                    if alive.any() and self._ctrl_fresh[alive].any():
                        eff_chunk = min(eff_chunk, 2)
                else:
                    # Speculation is losing (or unmeasured): genuinely
                    # fall back to the PLAIN decode chunk.  The
                    # ladder-degrade path keeps the n-gram history warm,
                    # and the host-side probe in the plain emit loop
                    # (``propose_first_host``) keeps the EMA learning at
                    # zero device cost, so a regime change is picked up
                    # at the next round boundary — no probe rounds, no
                    # short rounds, no spec-chunk overhead on text where
                    # speculation cannot pay.
                    eff_k = None
                    eff_chunk = min(eff_chunk, rem)
                if alive.any():
                    self._ctrl_fresh[alive] = False
                # Quantize the cap to a power of two (or the full chunk)
                # so the jitted chunk compiles O(log chunk) shapes, not
                # one per distinct remaining-budget value.
                eff_chunk = max(1, eff_chunk)
                if eff_chunk < self.chunk:
                    eff_chunk = 1 << (eff_chunk.bit_length() - 1)
            spec_on = self.spec is not None and eff_k is not None
            # ---- page top-up, under injected pool pressure
            withheld: list[int] = []
            if chaos is not None:
                n_w = chaos.squeeze_pages(len(self._pool.free), rnd)
                if n_w:
                    # Withhold from the free list only: retained
                    # prefix-cache pages stay evictable, so a squeeze
                    # squeezes the CACHE first — exactly the
                    # opportunistic-capacity contract.
                    withheld = self._pool.free[-n_w:]
                    del self._pool.free[-n_w:]
                    report.squeezed_pages += n_w

            def _top_ups():
                for s in live:
                    # An earlier top-up in this round may have preempted
                    # this slot — don't grow a retired slot.
                    if self._slot_req[s] >= 0:
                        self._top_up(requests, s, eff_chunk, eff_k)

            try:
                _top_ups()
            except RuntimeError:
                if withheld:
                    # The squeeze alone exhausted the pool: give the pages
                    # back and retry before escalating.
                    self._pool.free.extend(withheld)
                    withheld = []
                    try:
                        _top_ups()
                    except RuntimeError:
                        if not hardened:
                            raise
                        withheld = None  # sentinel: shed below
                elif hardened:
                    withheld = None
                else:
                    raise
            if withheld is None:
                # Pool genuinely too small for the single remaining live
                # request: shed it with its partial output.
                s0 = next(s for s in range(self.slots)
                          if self._slot_req[s] >= 0)
                ridx = self._slot_req[s0]
                self._shed(records, report, ridx, "oom")
                self._retire(s0)
                rnd += 1
                continue
            if withheld:
                self._pool.free.extend(withheld)
            self.peak_pages_in_use = max(self.peak_pages_in_use,
                                         self.pages_in_use())
            # ---- transient chunk faults: retry with (virtual) backoff
            if chaos is not None:
                attempt = 0
                while True:
                    try:
                        chaos.chunk_fault(rnd)
                        break
                    except ChunkFault as e:
                        report.retries += 1
                        if attempt >= policy.max_retries:
                            raise EngineCrash(
                                f"chunk retries exhausted: {e}") from e
                        skew += policy.backoff_s * (2.0 ** attempt)
                        attempt += 1
                lag = chaos.chunk_latency(rnd)
                skew += lag
                report.straggle_s += lag

            n0 = self._n_out.copy()
            t_round_start = now()
            self._cache["block_tables"] = jnp.asarray(self._bt)
            self.decode_chunk_iters += eff_chunk
            try:
                if spec_on and self.spec.tree_fan:
                    step = (spec_mod._spec_tree_chunk if self.mesh is None
                            else functools.partial(
                                spec_mod._spec_tree_chunk_sharded,
                                mesh=self.mesh))
                    (self._cache, tok, pos, n_out, done, hist, wctr,
                     emits, ms) = step(
                        self.params, self.cfg, self._cache,
                        jnp.asarray(self._tok), jnp.asarray(self._pos),
                        jnp.asarray(self._n_out), jnp.asarray(self._done),
                        jnp.asarray(self._hist), jnp.asarray(self._wctr),
                        jnp.asarray(self._rids), jnp.asarray(self._max_new),
                        jnp.asarray(self._stops), self._key,
                        jnp.float32(temperature), self._extras_slots,
                        chunk=eff_chunk, page_size=self.page_size,
                        fan=self.spec.tree_fan, depth=eff_k,
                        ngram_n=self.spec.ngram_n, pad_id=self.pad_id,
                        greedy=bool(greedy), top_k=int(top_k))
                elif spec_on:
                    if self._draft_mode:
                        self._dcache["block_tables"] = jnp.asarray(self._bt)
                    if self.mesh is None:
                        (self._cache, self._dcache, tok, pos, n_out, done,
                         hist, wctr, ema, emits, ms) = spec_mod._spec_chunk(
                            self.params, self.cfg, self._cache,
                            self.draft_params, self._dcache,
                            jnp.asarray(self._tok), jnp.asarray(self._pos),
                            jnp.asarray(self._n_out), jnp.asarray(self._done),
                            jnp.asarray(self._hist), jnp.asarray(self._wctr),
                            jnp.asarray(self._acc_ema),
                            jnp.asarray(self._rids), jnp.asarray(self._max_new),
                            jnp.asarray(self._stops), self._key,
                            jnp.float32(temperature), self._extras_slots,
                            draft_cfg=self.draft_cfg, chunk=eff_chunk,
                            page_size=self.page_size, k=eff_k,
                            mode=self.spec.mode, ngram_n=self.spec.ngram_n,
                            pad_id=self.pad_id, greedy=bool(greedy),
                            top_k=int(top_k), adaptive=self.spec.adaptive,
                            ctrl_alpha=self.spec.ctrl_alpha,
                            accept=self.spec.accept,
                            typical_eps=self.spec.typical_eps,
                            typical_delta=self.spec.typical_delta)
                    else:
                        (self._cache, tok, pos, n_out, done, hist, wctr,
                         ema, emits, ms) = spec_mod._spec_chunk_sharded(
                            self.params, self.cfg, self._cache,
                            jnp.asarray(self._tok), jnp.asarray(self._pos),
                            jnp.asarray(self._n_out), jnp.asarray(self._done),
                            jnp.asarray(self._hist), jnp.asarray(self._wctr),
                            jnp.asarray(self._acc_ema),
                            jnp.asarray(self._rids), jnp.asarray(self._max_new),
                            jnp.asarray(self._stops), self._key,
                            jnp.float32(temperature), self._extras_slots,
                            mesh=self.mesh, chunk=eff_chunk,
                            page_size=self.page_size, k=eff_k,
                            ngram_n=self.spec.ngram_n, pad_id=self.pad_id,
                            greedy=bool(greedy), top_k=int(top_k),
                            adaptive=self.spec.adaptive,
                            ctrl_alpha=self.spec.ctrl_alpha,
                            accept=self.spec.accept,
                            typical_eps=self.spec.typical_eps,
                            typical_delta=self.spec.typical_delta)
                    self._acc_ema = np.array(ema)
                else:
                    step = (_decode_chunk if self.mesh is None
                            else functools.partial(_decode_chunk_sharded,
                                                   mesh=self.mesh))
                    (self._cache, tok, pos, n_out, done, emits,
                     lives) = step(
                        self.params, self.cfg, self._cache,
                        jnp.asarray(self._tok),
                        jnp.asarray(self._pos), jnp.asarray(self._n_out),
                        jnp.asarray(self._done), jnp.asarray(self._rids),
                        jnp.asarray(self._max_new),
                        jnp.asarray(self._stops), self._key,
                        jnp.float32(temperature), self._extras_slots,
                        chunk=eff_chunk, page_size=self.page_size,
                        greedy=bool(greedy), top_k=int(top_k),
                        pad_id=self.pad_id)
            except (ChunkFault, EngineCrash):
                raise
            except Exception as e:
                if hardened:
                    # The compiled step died mid-execution (its donated
                    # cache is gone) — surface as a crash: the supervisor
                    # rebuilds everything from the last snapshot.
                    raise EngineCrash(f"chunk execution failed: {e}") from e
                raise
            if spec_on:
                self._hist = np.array(hist)
                self._wctr = np.array(wctr)
                emits, ms = np.asarray(emits), np.asarray(ms)
                for t in range(eff_chunk):
                    for slot in range(self.slots):
                        mm = int(ms[t, slot])
                        if mm and self._slot_req[slot] >= 0:
                            self._outputs[self._slot_req[slot]].extend(
                                int(x) for x in emits[t, slot, :mm])
                            self.spec_emitted += mm
                            self.spec_live_steps += 1
                if self.debug_check_hist:
                    for slot in range(self.slots):
                        ridx = self._slot_req[slot]
                        if ridx < 0:
                            continue
                        out = self._outputs[ridx]
                        pl = int(self._plen[slot])
                        got = self._hist[slot, pl : pl + len(out)]
                        if not np.array_equal(
                                got, np.asarray(out, np.int32)):
                            raise AssertionError(
                                f"n-gram history desync on slot {slot} "
                                f"(request {ridx}): hist emissions "
                                f"{got.tolist()} != outputs {out}")
            else:
                emits, lives = np.asarray(emits), np.asarray(lives)
                cnt = n0.copy()
                for t in range(eff_chunk):
                    for slot in range(self.slots):
                        if lives[t, slot] and self._slot_req[slot] >= 0:
                            tv = int(emits[t, slot])
                            self._outputs[self._slot_req[slot]].append(tv)
                            if self.spec is not None:
                                # Ladder degraded a speculative engine to
                                # plain decode this round: keep the n-gram
                                # history warm so re-enabling speculation
                                # proposes from the full stream.
                                hl = int(self._plen[slot]) + cnt[slot]
                                if self.spec.adaptive:
                                    # Free host-side probe: the chance
                                    # the emitted token equals the
                                    # proposer's next guess IS the
                                    # acceptance ``_ctrl_probe`` would
                                    # measure, so plain fallback rounds
                                    # keep the controller learning.
                                    pred = spec_mod.propose_first_host(
                                        self._hist[slot], hl,
                                        self.spec.ngram_n)
                                    al = self.spec.ctrl_alpha
                                    self._acc_ema[slot] = (
                                        (1.0 - al) * self._acc_ema[slot]
                                        + al * float(pred == tv))
                                self._hist[slot, hl] = tv
                                cnt[slot] += 1
            self._tok = np.array(tok)  # np.array: writable host copies
            self._pos = np.array(pos)
            self._n_out = np.array(n_out)
            self._done = np.array(done)
            if hardened:
                skew += policy.round_time
            t_end = now()
            for slot in live:
                ridx_s = self._slot_req[slot]
                if ridx_s < 0:
                    continue  # preempted during this round's top-up
                records[ridx_s].events.append(
                    {"name": "decode", "ts": t_round_start,
                     "dur": t_end - t_round_start, "round": rnd,
                     "tokens": int(self._n_out[slot] - n0[slot])})
            for slot in range(self.slots):
                if self._slot_req[slot] >= 0 and self._done[slot]:
                    # Per-slot completion at chunk granularity: interpolate
                    # the round's [t_round_start, t_end] span to the LAST
                    # chunk iteration the slot was live in, instead of
                    # stamping every retiring slot with the same round
                    # boundary (see ServeReport.latencies for the residual
                    # quantization).
                    if spec_on:
                        liv = np.flatnonzero(ms[:, slot] > 0)
                    else:
                        liv = np.flatnonzero(lives[:, slot])
                    fin_it = int(liv[-1]) if liv.size else eff_chunk - 1
                    t_slot = t_round_start + (fin_it + 1) / eff_chunk * (
                        t_end - t_round_start)
                    self._finish(requests, records, slot, t_slot)
            report.counters.append(
                {"ts": t_end, "round": rnd,
                 "free_pages": len(self._pool.free),
                 "retained_pages": len(self._pool.lru),
                 "pages_in_use": self.pages_in_use(),
                 "prefix_hit_tokens": self.prefix_hit_tokens,
                 "eff_k": int(eff_k) if spec_on else 0,
                 "queued": len(self._queue),
                 "retries": report.retries})
            # ---- ladder signals + snapshot
            if hardened:
                bad = []
                if report.retries > retries_before:
                    bad.append("retries")
                if self.preemptions > preempt_before:
                    bad.append("preempt")
                if sheds_round:
                    bad.append("shed")
                if (self._pool.available() / max(1, self.num_pages - 1)
                        < ladder.cfg.free_frac):
                    bad.append("pressure")
                if chaos is not None and lag > 0:
                    bad.append("straggle")
                ladder.update(rnd, bool(bad), "+".join(bad))
                if snap_every and rnd % snap_every == 0:
                    self._take_snapshot(records, policy, rnd)
            rnd += 1

        self._pool_poisoned = False  # round loop completed normally
        report.rounds = rnd
        report.ladder_trace = list(ladder.trace)
        report.max_ladder_level = max(
            (lvl for _, lvl, _ in ladder.trace), default=0)
        dropped = [i for i, rec in enumerate(records)
                   if rec.status == "pending"]
        if dropped:
            # A still-pending record means the scheduler LOST a request —
            # it was neither finished, shed, nor rejected.  Raising is the
            # only honest outcome; the old unconditional "pending -> done"
            # coercion hid exactly this class of bug.  Hardened production
            # serving may opt back into coercion (strict_pending=False or
            # unset REPRO_STRICT_SERVE) to prefer degraded answers over an
            # exception, and marks the records so the loss is auditable.
            strict = (self.strict_pending if self.strict_pending is not None
                      else os.environ.get("REPRO_STRICT_SERVE", "")
                      not in ("", "0", "false"))
            if strict or not hardened:
                raise RuntimeError(
                    f"scheduler dropped requests {dropped}: still pending "
                    "after the serve loop — every request must end "
                    "done/shed/rejected")
            for i in dropped:
                records[i].status = "done"
                records[i].reason = "coerced-pending"
        report.prefix_hits = self.prefix_hits
        report.prefix_hit_tokens = self.prefix_hit_tokens
        report.prefill_tokens = self.prefill_tokens
        report.cow_forks = self.cow_forks
        report.evictions = self._pool.evictions
        self.assert_quiescent()
        if snap_every:
            self._take_snapshot(records, policy, rnd)
        self.last_report = report
        return report

    def generate(self, prompt_tokens, n_new: int, *,
                 extras: Optional[dict] = None, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0, key=None,
                 stop_tokens: Sequence[int] = ()):
        """Old fixed-batch API as a thin wrapper over the scheduler: each
        batch row becomes a Request (row i of ``extras`` — batched like
        ``ServingEngine.generate``'s — becomes its per-request extras);
        rows retiring early are padded with ``pad_id`` to keep the
        (B, n_new) shape."""
        prompts = np.asarray(prompt_tokens, np.int32)
        reqs = [
            Request(prompt=row, max_new=int(n_new),
                    stop_tokens=tuple(stop_tokens),
                    extras=(None if extras is None
                            else jax.tree.map(lambda a: a[i], extras)))
            for i, row in enumerate(prompts)
        ]
        outs = self.serve(reqs, greedy=greedy, temperature=temperature,
                          top_k=top_k, key=key)
        full = np.full((len(reqs), int(n_new)), self.pad_id, np.int32)
        for i, o in enumerate(outs):
            full[i, : len(o)] = o
        return jnp.asarray(full)
