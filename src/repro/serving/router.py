"""Multi-replica front-end: route a request stream over N
``ContinuousBatchingEngine`` replicas, token-identical to a solo engine.

``ReplicaRouter`` is the "fleet" half of the ROADMAP's millions-of-users
item: the engines are independent replicas (each with its own page pool,
prefix trie, scheduler, and — under ``serving.sharded`` — its own model
mesh), and the router is a thin host-side dispatcher.

Routing policy — least-loaded with prefix affinity:

* **affinity**: a host-side shadow of each replica's prefix trie
  (``prefix.PrefixTrie`` keyed the same way: page-aligned chunk bytes
  under an extras-fingerprint root) tracks which prompt prefixes each
  replica has already been routed.  A request prefers the replica whose
  shadow holds its longest prefix — that replica's REAL trie will serve
  those pages without recomputing them, so repeated system prompts
  concentrate instead of re-prefilling once per replica.  The shadow is
  a routing heuristic, not ground truth (it ignores evictions), which is
  exactly the split a networked fleet needs: routing must not require
  synchronous cache state from the data plane.
* **load**: ties break toward the replica with the least outstanding
  predicted work — sum over its assigned requests of (prompt tokens it
  will actually prefill, given affinity) + max_new decode tokens.

Token identity: every sampled draw in the engines is keyed by
``(rid, draw counter)`` via fold_in, independent of slot, chunk, engine,
and batch composition.  The router pins each request's GLOBAL trace index
as ``Request.rid`` before handing the per-replica sub-lists out, and every
replica serves with the same base key — so each request's token stream is
bit-identical to the one a solo engine serving the full trace would emit
(greedy trivially, sampled by key construction;
tests/test_router_trace.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.serving.engine import Request
from repro.serving.prefix import PrefixTrie, chunk_keys, extras_fingerprint
from repro.serving.resilience import RequestRecord, ServeReport


@dataclasses.dataclass
class RouterReport:
    """Merged outcome of one routed trace: ``records`` in the ORIGINAL
    trace order (so index i is request i, as with a solo engine),
    ``assignments[i]`` = replica that served request i, and the
    per-replica ``ServeReport``s for drill-down (their record lists are
    the same objects, per-replica order).  ``affinity_hits`` counts
    requests routed to a replica whose shadow trie already held a prefix
    of their prompt."""

    records: list = dataclasses.field(default_factory=list)
    assignments: list = dataclasses.field(default_factory=list)
    replica_reports: list = dataclasses.field(default_factory=list)
    affinity_hits: int = 0
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    prefill_tokens: int = 0
    cow_forks: int = 0
    evictions: int = 0

    @property
    def outputs(self) -> list[np.ndarray]:
        return [r.tokens for r in self.records]

    def done(self) -> list[int]:
        return [i for i, r in enumerate(self.records) if r.status == "done"]

    def latencies(self) -> list[float]:
        return [r.t_done for r in self.records
                if r.status == "done" and r.t_done is not None]


class ReplicaRouter:
    """Route request streams over ``engines`` (see module docstring).

    The engines should be constructed alike (same family/params; prefix
    caching per taste).  ``serve_detailed`` serves each replica's
    sub-list independently — replicas never share device state, so this
    models N separate serving processes."""

    def __init__(self, engines: Sequence):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines = list(engines)
        ps = {e.page_size for e in self.engines}
        if len(ps) != 1:
            raise ValueError(
                f"replicas disagree on page_size ({sorted(ps)}); prefix "
                "affinity keys chunks by page_size, so routing would be "
                "meaningless")
        self.page_size = ps.pop()

    # ---------------------------------------------------------------- route --
    def route(self, requests: Sequence[Request]) -> list[int]:
        """Assign each request a replica index: longest shadow-trie prefix
        match first, least predicted outstanding work second, lowest
        replica index last.  Pure host-side planning — no engine state is
        touched, so callers may inspect/override before serving."""
        n = len(self.engines)
        shadows = [PrefixTrie() for _ in range(n)]
        load = [0] * n
        page_ctr = [0] * n  # shadow page ids are sequence numbers
        out = []
        for req in requests:
            keys = chunk_keys(np.asarray(req.prompt, np.int32),
                              self.page_size)
            fp = extras_fingerprint(req.extras)
            matched = [len(shadows[r].match(keys, fp)) for r in range(n)]
            best = max(range(n),
                       key=lambda r: (matched[r], -load[r], -r))
            hit_tok = matched[best] * self.page_size
            load[best] += (len(req.prompt) - hit_tok) + int(req.max_new)
            fresh = list(range(page_ctr[best],
                               page_ctr[best] + len(keys)))
            page_ctr[best] += len(keys)
            shadows[best].insert(keys, fp, fresh, on_new=lambda p: None)
            out.append(best)
        return out

    # ---------------------------------------------------------------- serve --
    def serve_detailed(self, requests: Sequence[Request], *,
                       greedy: bool = True, temperature: float = 1.0,
                       top_k: int = 0, key=None,
                       policy=None, chaos=None,
                       assignments: Optional[Sequence[int]] = None
                       ) -> RouterReport:
        """Route (unless ``assignments`` is given) and serve every
        sub-list, merging the per-replica reports back into original
        trace order.  Each request's ``rid`` is pinned to its global
        index (unless the caller already set one), so sampled streams
        match a solo engine; ``policy``/``chaos`` apply to every replica
        alike."""
        assign = (list(assignments) if assignments is not None
                  else self.route(requests))
        if len(assign) != len(requests):
            raise ValueError("assignments length != requests length")
        report = RouterReport(records=[None] * len(requests),
                              assignments=assign)
        # affinity_hits needs the shadow replay only when assignments were
        # computed here; recompute cheaply either way for the stat.
        shadows = [PrefixTrie() for _ in range(len(self.engines))]
        ctr = [0] * len(self.engines)
        for i, req in enumerate(requests):
            keys = chunk_keys(np.asarray(req.prompt, np.int32),
                              self.page_size)
            fp = extras_fingerprint(req.extras)
            r = assign[i]
            if shadows[r].match(keys, fp):
                report.affinity_hits += 1
            fresh = list(range(ctr[r], ctr[r] + len(keys)))
            ctr[r] += len(keys)
            shadows[r].insert(keys, fp, fresh, on_new=lambda p: None)
        for r, eng in enumerate(self.engines):
            idxs = [i for i, a in enumerate(assign) if a == r]
            if not idxs:
                report.replica_reports.append(ServeReport())
                continue
            subs = [dataclasses.replace(requests[i],
                                        rid=(requests[i].rid
                                             if requests[i].rid is not None
                                             else i))
                    for i in idxs]
            rep = eng.serve_detailed(subs, greedy=greedy,
                                     temperature=temperature, top_k=top_k,
                                     key=key, policy=policy, chaos=chaos)
            report.replica_reports.append(rep)
            for i, rec in zip(idxs, rep.records):
                rec.replica = r  # annotate for the trace exporter
                report.records[i] = rec
            report.prefix_hits += rep.prefix_hits
            report.prefix_hit_tokens += rep.prefix_hit_tokens
            report.prefill_tokens += rep.prefill_tokens
            report.cow_forks += rep.cow_forks
            report.evictions += rep.evictions
        for i, rec in enumerate(report.records):
            if rec is None:  # replica had no requests -> unreachable, but
                report.records[i] = RequestRecord()  # keep shape total
        return report

    def serve(self, requests: Sequence[Request], **kw) -> list[np.ndarray]:
        return [r.tokens for r in self.serve_detailed(requests, **kw).records]
