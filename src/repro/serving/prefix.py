"""Shared-prefix KV page cache: refcounted page pool + host-side prefix trie.

The continuous engine's block-table indirection already lets several slots
point at the SAME pool page; this module supplies the host-side accounting
that makes that aliasing safe and useful:

* ``PagePool`` — the refcounted allocator that replaces the old binary
  free-list/``_allocated``-set pair.  A page's refcount is the number of
  live block-table references to it.  Pages *registered* in the prefix trie
  are additionally marked ``cached``: when their refcount drops to zero
  they are RETAINED on an LRU list (their KV content stays valid — the
  device pools are only rewritten through block tables, and no live slot
  references them) instead of returning to the free list, so a later
  request with the same prompt prefix can re-alias them without any
  recompute.  Under pool pressure ``alloc`` evicts retained pages LRU-first
  (deregistering them via ``on_evict``) before failing, which is how the
  cache yields to the PR 6 squeeze/preemption paths: cached pages are
  opportunistic capacity, never reserved capacity.

* ``PrefixTrie`` — maps page-aligned prompt-token chunks to registered
  pages.  Keys are the raw token bytes of each ``page_size`` chunk, walked
  from position 0, under a root per extras fingerprint — chain keying, so a
  page is only ever matched when EVERY preceding token (and the request's
  conditioning: vlm image embeds, encdec encoder output) is identical,
  which is exactly the causal dependency of its KV content.  Matching is
  content-addressed: two different requests that share a token-identical
  prefix (system prompt, few-shot header) share its pages no matter when or
  in which slot the prefix was first prefilled.

Correctness contract (enforced by the engine, tested in
tests/test_prefix_cache.py):

* only FULL pages covering final, never-rewritten positions are registered
  (positions ``[0, floor(L/ps)*ps)`` of a prompt of length L — decode
  writes start at L, so registered content is immutable);
* registration happens only on full-prefill admits, so every cached page's
  KV was produced by the exact ``models.prefill`` computation an uncached
  admit would run — cache hits can therefore be bit-identical to uncached
  serving;
* a write landing inside a shared page (refcount > 1 or trie-registered)
  forks it copy-on-write first (engine ``_admit``), so a writer can never
  perturb a page a sibling still reads.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Optional

import numpy as np


def extras_fingerprint(extras) -> Optional[str]:
    """Digest of a request's per-slot conditioning (vlm image embeds,
    encdec encoder output).  Prefix KV depends on the conditioning as well
    as the token prefix, so the trie roots one chain family per
    fingerprint; ``None`` extras share the ``None`` root."""
    if extras is None:
        return None
    import jax

    h = hashlib.sha1()
    for leaf in jax.tree.leaves(extras):
        a = np.asarray(leaf)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def chunk_keys(seq: np.ndarray, page_size: int) -> list[bytes]:
    """The trie keys of a token sequence: one raw-bytes key per FULL
    ``page_size``-aligned chunk (the partial tail chunk never enters the
    trie — its page is still being written by decode)."""
    seq = np.ascontiguousarray(np.asarray(seq, np.int32))
    n = len(seq) // page_size
    return [seq[i * page_size:(i + 1) * page_size].tobytes()
            for i in range(n)]


@dataclasses.dataclass
class _Node:
    page: int
    children: dict = dataclasses.field(default_factory=dict)


class PrefixTrie:
    """Chunk-chain trie: ``match`` returns the pages of the longest
    registered chain prefix, ``insert`` extends a chain, ``drop_page``
    detaches an evicted page's node (its subtree becomes unreachable for
    matching but stays individually evictable through the pool's LRU —
    content-keyed chains mean a later re-registration of the same chunk
    reattaches equivalent content, so stale subtrees are merely cold,
    never wrong)."""

    def __init__(self):
        self._roots: dict = {}          # extras fp -> {chunk bytes: _Node}
        self._where: dict[int, tuple] = {}  # page -> (children dict, key)

    def match(self, keys: list[bytes], fp) -> list[int]:
        """Pages of the longest registered chain prefix of ``keys``."""
        children = self._roots.get(fp)
        out: list[int] = []
        for k in keys:
            node = None if children is None else children.get(k)
            if node is None:
                break
            out.append(node.page)
            children = node.children
        return out

    def insert(self, keys: list[bytes], fp, pages: list[int],
               on_new: Callable[[int], None]) -> int:
        """Walk/extend the chain for ``keys``; chunk i that has no node yet
        gets one holding ``pages[i]`` (``on_new(pages[i])`` fires so the
        pool can mark it cached).  Existing nodes are left untouched — the
        first registration of a chunk wins, so chain content is stable.
        Returns the number of newly registered pages."""
        children = self._roots.setdefault(fp, {})
        new = 0
        for k, page in zip(keys, pages):
            node = children.get(k)
            if node is None:
                node = _Node(page=int(page))
                children[k] = node
                self._where[int(page)] = (children, k)
                on_new(int(page))
                new += 1
            children = node.children
        return new

    def drop_page(self, page: int) -> None:
        loc = self._where.pop(int(page), None)
        if loc is not None:
            children, k = loc
            node = children.get(k)
            if node is not None and node.page == int(page):
                del children[k]

    def __len__(self) -> int:
        return len(self._where)


class PagePool:
    """Refcounted page pool with prefix-cache retention (see module
    docstring).  Page 0 is the trash page and never circulates.

    State machine per page: FREE (on ``free``, refcount 0) -> ALLOCATED/
    REFERENCED (refcount >= 1; ``alloc`` starts at 1, aliasing ``acquire``s
    increment) -> on the last ``release``: RETAINED (trie-registered pages,
    refcount 0, parked on the LRU — re-aliasable via ``acquire`` or
    evictable by ``alloc``) or straight back to FREE."""

    def __init__(self, num_pages: int, rng=None,
                 on_evict: Optional[Callable[[int], None]] = None):
        self.num_pages = int(num_pages)
        self.free = list(range(1, self.num_pages))
        self.refcnt = np.zeros(self.num_pages, np.int64)
        self.cached: set[int] = set()       # pages registered in the trie
        self.lru = OrderedDict()            # retained refcount-0 cached pages
        self.on_evict = on_evict
        self.evictions = 0
        self._rng = rng

    # -- capacity ------------------------------------------------------------
    def available(self, reserve: tuple = ()) -> int:
        """Pages ``alloc`` could hand out right now: free + retained-LRU,
        minus any retained pages the caller is about to ``acquire`` for
        aliasing (``reserve``) — those must not be double-counted as
        evictable."""
        held = sum(1 for p in reserve if p in self.lru)
        return len(self.free) + len(self.lru) - held

    def in_use(self) -> int:
        """Pages with live references (retained cache pages are NOT in
        use — they are reclaimable capacity)."""
        return int((self.refcnt[1:] > 0).sum())

    # -- alloc / refcounting -------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        if n > self.available():
            raise RuntimeError(
                f"page allocator overdraw: requested {n} pages with only "
                f"{len(self.free)} free (+{len(self.lru)} evictable) — "
                "admission/top-up must check the free list before "
                "allocating")
        while len(self.free) < n:
            page, _ = self.lru.popitem(last=False)  # evict least-recent
            self.cached.discard(page)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(page)
            self.free.append(page)
        if self._rng is not None:
            self._rng.shuffle(self.free)
        pages, self.free = self.free[:n], self.free[n:]
        for p in pages:
            self.refcnt[p] = 1
        return pages

    def acquire(self, page: int) -> None:
        """Add a reference to an already-known page (block-table aliasing
        of a cached/live page)."""
        if self.refcnt[page] == 0:
            # coming off the retained LRU (must be there: refcount-0 pages
            # are either free or retained, and free pages go through alloc)
            self.lru.pop(page)
        self.refcnt[page] += 1

    def release(self, page: int) -> None:
        if page == 0 or self.refcnt[page] <= 0:
            raise ValueError(
                f"double-free: page {page} is not currently allocated — a "
                "page freed twice would be issued to two slots at once "
                "and silently cross-corrupt their KV state")
        self.refcnt[page] -= 1
        if self.refcnt[page] == 0:
            if page in self.cached:
                self.lru[page] = None       # retained, most-recent end
            else:
                self.free.append(page)

    def touch(self, page: int) -> None:
        """Refresh a retained page's LRU position on a cache hit probe."""
        if page in self.lru:
            self.lru.move_to_end(page)

    def mark_cached(self, page: int) -> None:
        self.cached.add(page)

    # -- invariants ----------------------------------------------------------
    def assert_quiescent(self) -> None:
        held = np.flatnonzero(self.refcnt[1:] > 0) + 1
        if held.size:
            raise AssertionError(
                f"page leak: {held.tolist()} still allocated with no live "
                "requests")
        expect = self.num_pages - 1  # page 0 (trash) never circulates
        pool = list(self.free) + list(self.lru)
        if len(pool) != expect or len(set(pool)) != expect:
            raise AssertionError(
                f"free-list corruption: {len(self.free)} free + "
                f"{len(self.lru)} retained ({len(set(pool))} unique), "
                f"expected {expect}")
        if not set(self.lru) <= self.cached:
            raise AssertionError(
                f"retained pages {sorted(set(self.lru) - self.cached)} are "
                "not trie-registered")
