"""Speculative multi-token decode: amortise one weight stream over several
emitted tokens.

The paper's bound — and ``BENCH_decode.json``'s — is weight bytes per token:
every decode step streams the whole quantized tree to emit ONE token.
Speculation proposes ``k`` cheap draft tokens, then runs the target model
ONCE over the ``k+1``-token window (``models.verify_step``) and emits
``accepted + 1`` tokens (the accepted drafts plus one token the verify pass
itself produces) per weight stream.  Verification comes in two flavours:

* **Greedy** (``greedy=True`` decode): accept the longest prefix whose
  greedy argmax agrees with the proposals.  An accepted token is by
  construction exactly what non-speculative greedy decode would have
  emitted, so output is TOKEN-IDENTICAL to the baseline
  (tests/test_speculative.py enforces the parity matrix).
* **Sampled** (``greedy=False``, temperature/top-k): rejection-sampling
  verification (``sampling.rejection_sample``): accept proposal ``d_i ~
  q_i`` with probability ``min(1, p_i(d_i)/q_i(d_i))`` against the
  target's warped verify distribution ``p_i``, resample the first
  rejection from the normalised residual ``max(p_i - q_i, 0)``, and draw
  the bonus token from ``p_{k+1}`` when everything is accepted.

**Distribution-preservation guarantee.**  Sampled speculation leaves the
output distribution of plain sampled decode EXACTLY unchanged: the
accept/residual construction makes each emitted token marginally (and
jointly) distributed as ancestral sampling from the warped target
distribution, for ANY proposal distribution q — proposer quality moves
the acceptance rate (weight streams paid), never the law of the output.
The test methodology is two-layered (tests/test_sampled_speculative.py):

* **Seeded exactness** where the algorithm is key-deterministic: the
  per-row ``(base key, request id, counter)`` fold_in discipline
  (``serving.sampling``) makes the same ``key`` produce identical tokens
  across {dense fixed engine, paged continuous engine} x {1, 8 devices},
  across slot assignments/chunk sizes, and across preemption/recompute
  replays — asserted token-for-token.  One scoped caveat: the moe archs'
  dense-vs-paged cache layouts yield ~1e-3 logit differences (expert
  top-k gates amplify contraction-order ulps; pre-existing since the
  PR 2 paged cache), so THEIR cross-engine guarantee is distributional
  only — per-engine key-determinism, schedule independence, and
  mesh-width invariance still hold exactly
  (tests/helpers.PAGED_BITEXACT_ARCHS documents the split).
* **Distributional equivalence** where it is not (speculative vs plain
  sampled decode consume different draw counts): empirical token
  histograms over thousands of seeded decodes are compared with a
  pooled-bin chi-square homogeneity test at alpha=0.01 (plus a
  total-variation report), per model family
  (``tests/helpers.histogram_decode`` / ``chi_square_homogeneity``).

Two proposers:

* ``mode="ngram"`` — prompt-lookup decoding: match the last ``ngram_n``
  tokens of the row's history (prompt + emissions) against every earlier
  position and propose the ``k`` tokens that followed the most recent
  match; fall back to repeating the last token.  Zero extra parameters,
  runs inside the compiled program, and thrives on the repetitive tails
  real decodes (and untrained-model attractors) produce.  Deterministic,
  so its ``q`` is a one-hot point mass: acceptance degenerates to
  ``u < p(d)`` and the residual to ``p`` with the proposal zeroed.
* ``mode="draft"`` — a small draft model (its own cache) proposes ``k``
  tokens autoregressively — argmax under greedy decode, sampled from its
  own warped distribution ``q_i`` under sampling; its per-step states
  stack across the chain (``models.stack_verify_caches``) and commit once
  at the accepted length with the same ``commit_verify`` machinery as the
  target — no re-sync forward.  On the fixed engine the draft cache is
  dense; on the continuous engine it is a PAGED pool sharing the target's
  block tables (same page ids, its own storage), so draft speculation
  survives admit/retire/preemption like any other per-slot state.

Rollback discipline (see ``models.verify_step``): attention/MLA writes at
rejected positions are dead by masking and rewritten by the next window;
SSM/conv state returns per-step stacked and ``commit_verify`` keeps the
accepted step per row; the paged engine's rejected page writes are
reclaimed the same way (the block tables never move).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import (
    commit_verify,
    init_cache,
    prefill,
    verify_step,
)
from repro.models.lm import stack_verify_caches
from repro.serving.sampling import (
    TAG_TOKEN,
    TAG_WINDOW,
    draw_keys,
    rejection_sample,
    sample_rows,
    warp_logits,
)
from repro.serving.sharded import tree_pspecs


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Static speculation settings (hashable — safe to close over in jit).

    ``k``: proposed tokens per verify step (the window is ``k+1`` wide).
    ``mode``: ``"ngram"`` (prompt-lookup, default) or ``"draft"`` (draft
    model; the engine must hold ``draft_cfg``/``draft_params``).
    ``ngram_n``: match length for the prompt-lookup proposer."""

    k: int = 4
    mode: str = "ngram"
    ngram_n: int = 2

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculation needs k >= 1, got {self.k}")
        if self.mode not in ("ngram", "draft"):
            raise ValueError(f"mode must be ngram|draft, got {self.mode!r}")
        if self.ngram_n < 1:
            raise ValueError(f"ngram_n must be >= 1, got {self.ngram_n}")


def as_spec(speculate) -> SpecConfig:
    """Normalise an engine's ``speculate=`` argument: SpecConfig, or an int
    shorthand for ``SpecConfig(k=...)``."""
    if isinstance(speculate, SpecConfig):
        return speculate
    return SpecConfig(k=int(speculate))


# ---------------------------------------------------------------- proposer --
def propose_ngram(hist: jnp.ndarray, hlen: jnp.ndarray, k: int,
                  n: int) -> jnp.ndarray:
    """Prompt-lookup proposal: for each row of ``hist`` (B, W) with live
    length ``hlen`` (B,) — prompt plus every emitted token, the last one
    still pending — find the most recent earlier occurrence of the trailing
    ``n``-gram and propose the ``k`` tokens that followed it.  Positions
    past the match's continuation (and rows with no match) propose the last
    token — a cheap guess that costs nothing when rejected.  Returns
    (B, k) int32."""
    b, w = hist.shape
    gi = hlen[:, None] - n + jnp.arange(n)[None, :]
    gram = jnp.take_along_axis(hist, jnp.clip(gi, 0, w - 1), axis=1)  # (B, n)
    match = jnp.ones((b, w), bool)
    for i in range(n):
        # window starting at q sees hist[q + i]; shift left, pad invalid
        shifted = jnp.pad(hist[:, i:], ((0, 0), (0, i)), constant_values=-1)
        match = match & (shifted == gram[:, i : i + 1])
    q = jnp.arange(w)[None, :]
    # strictly-earlier windows only: the trailing gram itself sits at
    # hlen - n, so candidates end at hlen - n - 1
    valid = match & (q <= hlen[:, None] - n - 1)
    j = jnp.max(jnp.where(valid, q, -1), axis=1)  # (B,) most recent match
    found = j >= 0
    last = jnp.take_along_axis(hist, jnp.clip(hlen - 1, 0, w - 1)[:, None],
                               axis=1)  # (B, 1)
    src = j[:, None] + n + jnp.arange(k)[None, :]  # (B, k)
    prop = jnp.take_along_axis(hist, jnp.clip(src, 0, w - 1), axis=1)
    use = found[:, None] & (src < hlen[:, None])
    return jnp.where(use, prop, last).astype(jnp.int32)


def greedy_accept(window: jnp.ndarray, logits: jnp.ndarray):
    """Longest-matching-prefix greedy acceptance.  ``window`` (B, k+1) is
    the verified input (last accepted token + k proposals); ``logits``
    (B, k+1, V) the target's outputs.  Returns ``(g, a)``: the target's
    greedy tokens (B, k+1) — position j is the token following window[:j+1]
    — and ``a`` (B,) the number of accepted proposals; the row emits
    ``g[:a+1]`` (accepted proposals == g[:a] plus the free bonus token)."""
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    match = (window[:, 1:] == g[:, :-1]).astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    return g, a


def _accept(window, drafts, lg, *, greedy: bool, temperature, top_k: int,
            wkeys, q):
    """One verification: greedy longest-prefix, or rejection sampling
    against the warped target distribution.  Returns ``(g, a)`` with the
    shared contract that the row emits ``g[:, :a+1]``.  ``q`` is the
    proposal distribution (B, k, V) or None for deterministic proposers
    (one-hot point mass)."""
    if greedy:
        return greedy_accept(window, lg)
    p = jax.nn.softmax(warp_logits(lg, temperature, top_k), axis=-1)
    if q is None:
        q = jax.nn.one_hot(drafts, lg.shape[-1], dtype=jnp.float32)
    return rejection_sample(wkeys, drafts, q, p)


# ------------------------------------------------- fixed-batch spec engine --
def _draft_propose(draft_params, draft_cfg, dcache, tok, pos, extras, k,
                   *, page_size: int = 0, wkeys=None, greedy: bool = True,
                   temperature=1.0, top_k: int = 0):
    """Autoregressive draft proposals: k+1 single-token steps consume the
    whole window ``[tok, d_1..d_k]`` (the extra step eats ``d_k`` so every
    accepted length has a state).  Greedy decode proposes the draft's
    argmax; sampled decode draws ``d_i ~ q_i`` from the draft's warped
    distribution using per-row subkeys of the window key, and returns the
    stacked ``q`` (B, k, V) for the rejection-sampling accept ratio.
    Returns ``(drafts (B, k), q or None, stacked)`` where ``stacked`` is
    the chain's states merged into one verify cache
    (``models.stack_verify_caches``) — the caller commits it once at the
    accepted length, no re-sync forward.  With a paged ``dcache`` (the
    continuous engine) the chain scatters/gathers through the draft pool's
    block tables at per-slot positions."""
    dc, t, ds, qs, vcs = dcache, tok, [], [], []
    zero = jnp.zeros((tok.shape[0],), jnp.int32)
    for i in range(k + 1):
        lg, vc = verify_step(draft_params, draft_cfg, t, dc, pos + i, extras,
                             page_size=page_size)
        vcs.append(vc)
        dc = commit_verify(draft_cfg, vc, zero)
        if i < k:
            last = lg[:, -1, :]
            if greedy:
                t = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
            else:
                wl = warp_logits(last, temperature, top_k)
                ki = jax.vmap(lambda kk: jax.random.fold_in(kk, 3 + i))(wkeys)
                t = jax.vmap(jax.random.categorical)(ki, wl).astype(
                    jnp.int32)[:, None]
                qs.append(jax.nn.softmax(wl, axis=-1))
            ds.append(t)
    return (jnp.concatenate(ds, axis=1),
            jnp.stack(qs, axis=1) if qs else None,
            stack_verify_caches(draft_cfg, vcs))


def _spec_generate_body(params, cfg: ModelConfig, prompt, extras, draft_params,
                        key, temperature, *, draft_cfg, n_new: int,
                        max_seq: int, k: int, mode: str, ngram_n: int,
                        greedy: bool, top_k: int):
    """Whole speculative generation — prefill + a verify-window loop — as
    one XLA program.  Greedy verification or rejection sampling (see module
    docstring).  Returns (tokens (B, n_new), verify_steps, live_row_steps):
    greedy tokens are identical to the plain greedy ``generate``; sampled
    tokens are key-deterministic (per-row fold_in streams) and
    distributionally identical to plain sampled decode.
    emitted-per-live-row-step = ``B*(n_new-1) / live_row_steps`` is the
    speculation multiplier."""
    b, s = prompt.shape
    if n_new == 0:
        return (jnp.zeros((b, 0), jnp.int32), jnp.int32(0), jnp.int32(0))
    rids = jnp.arange(b, dtype=jnp.int32)
    cache = init_cache(cfg, b, max_seq)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    tok = sample_rows(
        logits[:, -1, :],
        None if greedy else draw_keys(key, rids, 0, TAG_TOKEN),
        greedy=greedy, temperature=temperature, top_k=top_k)[:, None]
    hist = jnp.zeros((b, max_seq), jnp.int32)
    hist = jax.lax.dynamic_update_slice(hist, prompt.astype(jnp.int32), (0, 0))
    hist = hist.at[:, s].set(tok[:, 0])
    out = jnp.zeros((b, n_new), jnp.int32).at[:, 0].set(tok[:, 0])
    n_em = jnp.ones((b,), jnp.int32)
    if mode == "draft":
        # k extra positions: the draft chain reads back the speculative
        # positions it writes, and near the max_seq frontier those reads
        # must hit real stored values (a dense out-of-store write DROPS),
        # mirroring the paged engine's _store_seq over-provisioning so the
        # two engines stay key-identical at the boundary.
        dcache = init_cache(draft_cfg, b, max_seq + k)
        _, dcache = prefill(draft_params, draft_cfg, prompt, dcache, extras)
    else:
        dcache = ()
    rows = jnp.arange(b)[:, None]
    steps0 = jnp.int32(0)
    wctr0 = jnp.zeros((b,), jnp.int32)

    def cond(carry):
        return jnp.any(carry[3] < n_new)

    def body(carry):
        tok, cache, dcache, n_em, out, hist, wctr, steps, live_steps = carry
        pos = jnp.int32(s) - 1 + n_em  # (B,) tokens already consumed
        wkeys = (None if greedy
                 else draw_keys(key, rids, wctr, TAG_WINDOW))
        if mode == "draft":
            drafts, q, dstack = _draft_propose(
                draft_params, draft_cfg, dcache, tok, pos, extras, k,
                wkeys=wkeys, greedy=greedy, temperature=temperature,
                top_k=top_k)
        else:
            drafts = propose_ngram(hist, jnp.int32(s) + n_em, k, ngram_n)
            q = None
        window = jnp.concatenate([tok, drafts], axis=1)  # (B, k+1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras)
        g, a = _accept(window, drafts, lg, greedy=greedy,
                       temperature=temperature, top_k=top_k, wkeys=wkeys, q=q)
        live = n_em < n_new
        m = jnp.where(live, jnp.minimum(a + 1, n_new - n_em), 0)  # (B,)
        emit = jnp.arange(k + 1)[None, :] < m[:, None]
        cols = n_em[:, None] + jnp.arange(k + 1)[None, :]
        out = out.at[rows, jnp.where(emit, cols, n_new)].set(g, mode="drop")
        hist = hist.at[rows, jnp.where(emit, jnp.int32(s) + cols, max_seq)
                       ].set(g, mode="drop")
        cache = commit_verify(cfg, vc, jnp.maximum(m - 1, 0))
        if mode == "draft":
            dcache = commit_verify(draft_cfg, dstack, jnp.maximum(m - 1, 0))
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                            axis=1),
                        tok)
        n_em = n_em + m
        return (tok, cache, dcache, n_em, out, hist,
                wctr + live.astype(jnp.int32), steps + 1,
                live_steps + jnp.sum(live.astype(jnp.int32)))

    carry = jax.lax.while_loop(
        cond, body,
        (tok, cache, dcache, n_em, out, hist, wctr0, steps0, steps0))
    return carry[4], carry[7], carry[8]


_spec_generate = functools.partial(
    jax.jit,
    static_argnames=("cfg", "draft_cfg", "n_new", "max_seq", "k", "mode",
                     "ngram_n", "greedy", "top_k"),
)(_spec_generate_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "n_new", "max_seq", "k", "ngram_n",
                     "greedy", "top_k"),
)
def _spec_generate_sharded(params, cfg: ModelConfig, prompt, extras, key,
                           temperature, *, mesh, n_new: int, max_seq: int,
                           k: int, ngram_n: int, greedy: bool, top_k: int):
    """``_spec_generate_body`` (ngram mode) under ``shard_map``: weight
    shards per device, everything else — including the PRNG key — is
    replicated, so every device draws the same samples and iterates in
    lockstep."""

    def f(p, pr, ex, ky, t):
        return _spec_generate_body(p, cfg, pr, ex, None, ky, t,
                                   draft_cfg=None, n_new=n_new,
                                   max_seq=max_seq, k=k, mode="ngram",
                                   ngram_n=ngram_n, greedy=greedy,
                                   top_k=top_k)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params), P(), P(), P(), P()),
        out_specs=(P(), P(), P()), check_rep=False,
    )(params, prompt, extras, key, temperature)


# ------------------------------------------- continuous-batching spec chunk --
def _spec_chunk_body(params, cfg: ModelConfig, cache, draft_params, dcache,
                     tok, pos, n_out, done, hist, wctr, rids, max_new, stops,
                     key, temperature, extras, *, draft_cfg, chunk: int,
                     page_size: int, k: int, mode: str, ngram_n: int,
                     pad_id: int, greedy: bool, top_k: int):
    """``chunk`` speculative verify windows over all batch slots as one
    compiled scan — the speculation analogue of ``engine._decode_chunk_body``
    (greedy or rejection-sampled).  Each iteration proposes ``k`` tokens per
    slot (n-gram history lookup, or the paged draft model), verifies the
    window against the paged cache, and advances each slot by its own
    accepted length (done slots advance 0 and write only their own pages or
    the trash page).  Sampled draws are keyed per slot by ``(key, rid,
    window counter)`` so slot assignment and chunk boundaries never change
    a request's tokens.  Emissions are truncated at the slot's first stop
    token and at ``max_new``.  Returns per-iteration ``emits``
    (chunk, B, k+1) and counts ``ms`` (chunk, B) — the host appends
    ``emits[t, s, :ms[t, s]]``."""
    b = tok.shape[0]
    rows = jnp.arange(b)[:, None]

    def body(carry, _):
        tok, cache, dcache, pos, n_out, done, hist, wctr = carry
        wkeys = (None if greedy
                 else draw_keys(key, rids, wctr, TAG_WINDOW))
        if mode == "draft":
            drafts, q, dstack = _draft_propose(
                draft_params, draft_cfg, dcache, tok, pos, extras, k,
                page_size=page_size, wkeys=wkeys, greedy=greedy,
                temperature=temperature, top_k=top_k)
        else:
            drafts = propose_ngram(hist, pos + 1, k, ngram_n)
            q = None
        window = jnp.concatenate([tok, drafts], axis=1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras,
                             page_size=page_size)
        g, a = _accept(window, drafts, lg, greedy=greedy,
                       temperature=temperature, top_k=top_k, wkeys=wkeys, q=q)
        live = ~done
        m = jnp.minimum(a + 1, max_new - n_out)
        # A stop token accepted mid-window truncates the window THERE: the
        # stop itself is emitted, everything after it in the window is
        # masked (never reaches the output, the history, or `tok`).
        hit = jnp.any(g[:, :, None] == stops[:, None, :], axis=-1)  # (B, k+1)
        hitm = hit & (jnp.arange(k + 1)[None, :] < m[:, None])
        any_hit = jnp.any(hitm, axis=1)
        first = jnp.argmax(hitm.astype(jnp.int32), axis=1)
        m = jnp.where(any_hit, first + 1, m)
        m = jnp.where(live, m, 0)
        emit_mask = jnp.arange(k + 1)[None, :] < m[:, None]
        emit = jnp.where(emit_mask, g, jnp.int32(pad_id))
        histcol = pos[:, None] + 1 + jnp.arange(k + 1)[None, :]
        hist = hist.at[rows, jnp.where(emit_mask, histcol, hist.shape[1])
                       ].set(g, mode="drop")
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                            axis=1),
                        tok)
        pos = pos + m
        n_out = n_out + m
        done = done | (live & any_hit) | (n_out >= max_new)
        cache = commit_verify(cfg, vc, jnp.maximum(m - 1, 0))
        if mode == "draft":
            dcache = commit_verify(draft_cfg, dstack, jnp.maximum(m - 1, 0))
        return ((tok, cache, dcache, pos, n_out, done, hist,
                 wctr + live.astype(jnp.int32)), (emit, m))

    carry, (emits, ms) = jax.lax.scan(
        body, (tok, cache, dcache, pos, n_out, done, hist, wctr), None,
        length=chunk)
    tok, cache, dcache, pos, n_out, done, hist, wctr = carry
    return cache, dcache, tok, pos, n_out, done, hist, wctr, emits, ms


_spec_chunk = functools.partial(
    jax.jit,
    static_argnames=("cfg", "draft_cfg", "chunk", "page_size", "k", "mode",
                     "ngram_n", "pad_id", "greedy", "top_k"),
    donate_argnames=("cache", "dcache"),
)(_spec_chunk_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "chunk", "page_size", "k", "ngram_n",
                     "pad_id", "greedy", "top_k"),
    donate_argnames=("cache",),
)
def _spec_chunk_sharded(params, cfg: ModelConfig, cache, tok, pos, n_out,
                        done, hist, wctr, rids, max_new, stops, key,
                        temperature, extras, *, mesh, chunk: int,
                        page_size: int, k: int, ngram_n: int, pad_id: int,
                        greedy: bool, top_k: int):
    """``_spec_chunk_body`` (ngram mode) under ``shard_map`` (weight shards
    per device; paged pools, history, PRNG key, and scheduler carry
    replicated — every device draws identical samples)."""

    def f(p, c, tk, ps_, no, dn, hs, wc, ri, mn, st, ky, t, ex):
        (c, _, tk, ps_, no, dn, hs, wc, emits, ms) = _spec_chunk_body(
            p, cfg, c, None, (), tk, ps_, no, dn, hs, wc, ri, mn, st, ky, t,
            ex, draft_cfg=None, chunk=chunk, page_size=page_size, k=k,
            mode="ngram", ngram_n=ngram_n, pad_id=pad_id, greedy=greedy,
            top_k=top_k)
        return c, tk, ps_, no, dn, hs, wc, emits, ms

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 13,
        out_specs=P(), check_rep=False,
    )(params, cache, tok, pos, n_out, done, hist, wctr, rids, max_new, stops,
      key, temperature, extras)
