"""Speculative multi-token decode: amortise one weight stream over several
emitted tokens.

The paper's bound — and ``BENCH_decode.json``'s — is weight bytes per token:
every decode step streams the whole quantized tree to emit ONE token.
Speculation proposes ``k`` cheap draft tokens, then runs the target model
ONCE over the ``k+1``-token window (``models.verify_step``) and accepts the
longest prefix whose greedy argmax agrees with the proposals, emitting
``accepted + 1`` tokens (the accepted drafts plus the verify pass's own
next token) per weight stream.  Verification is GREEDY: an accepted token
is by construction exactly what non-speculative greedy decode would have
emitted, so output is token-identical to the baseline and the speedup is
pure (``tests/test_speculative.py`` enforces the parity matrix).

Two proposers:

* ``mode="ngram"`` — prompt-lookup decoding: match the last ``ngram_n``
  tokens of the row's history (prompt + emissions) against every earlier
  position and propose the ``k`` tokens that followed the most recent
  match; fall back to repeating the last token.  Zero extra parameters,
  runs inside the compiled program, and thrives on the repetitive tails
  real decodes (and untrained-model attractors) produce.
* ``mode="draft"`` — a small draft model (its own cache) proposes ``k``
  tokens autoregressively; its per-step states stack across the chain
  (``models.stack_verify_caches``) and commit once at the accepted length
  with the same ``commit_verify`` machinery as the target — no re-sync
  forward (single-device ``ServingEngine`` path).

Rollback discipline (see ``models.verify_step``): attention/MLA writes at
rejected positions are dead by masking and rewritten by the next window;
SSM/conv state returns per-step stacked and ``commit_verify`` keeps the
accepted step per row; the paged engine's rejected page writes are
reclaimed the same way (the block tables never move).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import (
    commit_verify,
    init_cache,
    prefill,
    verify_step,
)
from repro.models.lm import stack_verify_caches
from repro.serving.sharded import tree_pspecs


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Static speculation settings (hashable — safe to close over in jit).

    ``k``: proposed tokens per verify step (the window is ``k+1`` wide).
    ``mode``: ``"ngram"`` (prompt-lookup, default) or ``"draft"`` (draft
    model; the engine must hold ``draft_cfg``/``draft_params``).
    ``ngram_n``: match length for the prompt-lookup proposer."""

    k: int = 4
    mode: str = "ngram"
    ngram_n: int = 2

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculation needs k >= 1, got {self.k}")
        if self.mode not in ("ngram", "draft"):
            raise ValueError(f"mode must be ngram|draft, got {self.mode!r}")
        if self.ngram_n < 1:
            raise ValueError(f"ngram_n must be >= 1, got {self.ngram_n}")


def as_spec(speculate) -> SpecConfig:
    """Normalise an engine's ``speculate=`` argument: SpecConfig, or an int
    shorthand for ``SpecConfig(k=...)``."""
    if isinstance(speculate, SpecConfig):
        return speculate
    return SpecConfig(k=int(speculate))


# ---------------------------------------------------------------- proposer --
def propose_ngram(hist: jnp.ndarray, hlen: jnp.ndarray, k: int,
                  n: int) -> jnp.ndarray:
    """Prompt-lookup proposal: for each row of ``hist`` (B, W) with live
    length ``hlen`` (B,) — prompt plus every emitted token, the last one
    still pending — find the most recent earlier occurrence of the trailing
    ``n``-gram and propose the ``k`` tokens that followed it.  Positions
    past the match's continuation (and rows with no match) propose the last
    token — a cheap guess that costs nothing when rejected.  Returns
    (B, k) int32."""
    b, w = hist.shape
    gi = hlen[:, None] - n + jnp.arange(n)[None, :]
    gram = jnp.take_along_axis(hist, jnp.clip(gi, 0, w - 1), axis=1)  # (B, n)
    match = jnp.ones((b, w), bool)
    for i in range(n):
        # window starting at q sees hist[q + i]; shift left, pad invalid
        shifted = jnp.pad(hist[:, i:], ((0, 0), (0, i)), constant_values=-1)
        match = match & (shifted == gram[:, i : i + 1])
    q = jnp.arange(w)[None, :]
    # strictly-earlier windows only: the trailing gram itself sits at
    # hlen - n, so candidates end at hlen - n - 1
    valid = match & (q <= hlen[:, None] - n - 1)
    j = jnp.max(jnp.where(valid, q, -1), axis=1)  # (B,) most recent match
    found = j >= 0
    last = jnp.take_along_axis(hist, jnp.clip(hlen - 1, 0, w - 1)[:, None],
                               axis=1)  # (B, 1)
    src = j[:, None] + n + jnp.arange(k)[None, :]  # (B, k)
    prop = jnp.take_along_axis(hist, jnp.clip(src, 0, w - 1), axis=1)
    use = found[:, None] & (src < hlen[:, None])
    return jnp.where(use, prop, last).astype(jnp.int32)


def greedy_accept(window: jnp.ndarray, logits: jnp.ndarray):
    """Longest-matching-prefix greedy acceptance.  ``window`` (B, k+1) is
    the verified input (last accepted token + k proposals); ``logits``
    (B, k+1, V) the target's outputs.  Returns ``(g, a)``: the target's
    greedy tokens (B, k+1) — position j is the token following window[:j+1]
    — and ``a`` (B,) the number of accepted proposals; the row emits
    ``g[:a+1]`` (accepted proposals == g[:a] plus the free bonus token)."""
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    match = (window[:, 1:] == g[:, :-1]).astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    return g, a


# ------------------------------------------------- fixed-batch spec engine --
def _draft_propose(draft_params, draft_cfg, dcache, tok, pos, extras, k):
    """Autoregressive draft proposals: k+1 single-token steps consume the
    whole window ``[tok, d_1..d_k]`` (the extra step eats ``d_k`` so every
    accepted length has a state; its own proposal is discarded).  Returns
    ``(drafts (B,k), stacked)`` where ``stacked`` is the chain's states
    merged into one verify cache (``models.stack_verify_caches``) — the
    caller commits it once at the accepted length, no re-sync forward."""
    dc, t, ds, vcs = dcache, tok, [], []
    zero = jnp.zeros((tok.shape[0],), jnp.int32)
    for i in range(k + 1):
        lg, vc = verify_step(draft_params, draft_cfg, t, dc, pos + i, extras)
        vcs.append(vc)
        dc = commit_verify(draft_cfg, vc, zero)
        t = jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        if i < k:
            ds.append(t)
    return (jnp.concatenate(ds, axis=1),
            stack_verify_caches(draft_cfg, vcs))


def _spec_generate_body(params, cfg: ModelConfig, prompt, extras, draft_params,
                        *, draft_cfg, n_new: int, max_seq: int, k: int,
                        mode: str, ngram_n: int):
    """Whole speculative generation — prefill + a verify-window loop — as
    one XLA program.  Greedy only.  Returns (tokens (B, n_new),
    verify_steps, live_row_steps): tokens are identical to the plain greedy
    ``generate``; emitted-per-live-row-step = ``B*(n_new-1) /
    live_row_steps`` is the speculation multiplier."""
    b, s = prompt.shape
    if n_new == 0:
        return (jnp.zeros((b, 0), jnp.int32), jnp.int32(0), jnp.int32(0))
    cache = init_cache(cfg, b, max_seq)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    hist = jnp.zeros((b, max_seq), jnp.int32)
    hist = jax.lax.dynamic_update_slice(hist, prompt.astype(jnp.int32), (0, 0))
    hist = hist.at[:, s].set(tok[:, 0])
    out = jnp.zeros((b, n_new), jnp.int32).at[:, 0].set(tok[:, 0])
    n_em = jnp.ones((b,), jnp.int32)
    if mode == "draft":
        dcache = init_cache(draft_cfg, b, max_seq)
        _, dcache = prefill(draft_params, draft_cfg, prompt, dcache, extras)
    else:
        dcache = ()
    rows = jnp.arange(b)[:, None]
    steps0 = jnp.int32(0)

    def cond(carry):
        return jnp.any(carry[3] < n_new)

    def body(carry):
        tok, cache, dcache, n_em, out, hist, steps, live_steps = carry
        pos = jnp.int32(s) - 1 + n_em  # (B,) tokens already consumed
        if mode == "draft":
            drafts, dstack = _draft_propose(draft_params, draft_cfg, dcache,
                                            tok, pos, extras, k)
        else:
            drafts = propose_ngram(hist, jnp.int32(s) + n_em, k, ngram_n)
        window = jnp.concatenate([tok, drafts], axis=1)  # (B, k+1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras)
        g, a = greedy_accept(window, lg)
        live = n_em < n_new
        m = jnp.where(live, jnp.minimum(a + 1, n_new - n_em), 0)  # (B,)
        emit = jnp.arange(k + 1)[None, :] < m[:, None]
        cols = n_em[:, None] + jnp.arange(k + 1)[None, :]
        out = out.at[rows, jnp.where(emit, cols, n_new)].set(g, mode="drop")
        hist = hist.at[rows, jnp.where(emit, jnp.int32(s) + cols, max_seq)
                       ].set(g, mode="drop")
        cache = commit_verify(cfg, vc, jnp.maximum(m - 1, 0))
        if mode == "draft":
            dcache = commit_verify(draft_cfg, dstack, jnp.maximum(m - 1, 0))
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                            axis=1),
                        tok)
        n_em = n_em + m
        return (tok, cache, dcache, n_em, out, hist, steps + 1,
                live_steps + jnp.sum(live.astype(jnp.int32)))

    carry = jax.lax.while_loop(
        cond, body, (tok, cache, dcache, n_em, out, hist, steps0, steps0))
    return carry[4], carry[6], carry[7]


_spec_generate = functools.partial(
    jax.jit,
    static_argnames=("cfg", "draft_cfg", "n_new", "max_seq", "k", "mode",
                     "ngram_n"),
)(_spec_generate_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "n_new", "max_seq", "k", "ngram_n"),
)
def _spec_generate_sharded(params, cfg: ModelConfig, prompt, extras, *, mesh,
                           n_new: int, max_seq: int, k: int, ngram_n: int):
    """``_spec_generate_body`` (ngram mode) under ``shard_map``: weight
    shards per device, everything else replicated — the loop condition is
    computed from replicated values, so every device iterates in
    lockstep."""

    def f(p, pr, ex):
        return _spec_generate_body(p, cfg, pr, ex, None, draft_cfg=None,
                                   n_new=n_new, max_seq=max_seq, k=k,
                                   mode="ngram", ngram_n=ngram_n)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params), P(), P()),
        out_specs=(P(), P(), P()), check_rep=False,
    )(params, prompt, extras)


# ------------------------------------------- continuous-batching spec chunk --
def _spec_chunk_body(params, cfg: ModelConfig, cache, tok, pos, n_out, done,
                     hist, max_new, stops, extras, *, chunk: int,
                     page_size: int, k: int, ngram_n: int, pad_id: int):
    """``chunk`` speculative verify windows over all batch slots as one
    compiled scan — the speculation analogue of ``engine._decode_chunk_body``
    (greedy only).  Each iteration proposes ``k`` tokens per slot from its
    history, verifies the window against the paged cache, and advances each
    slot by its own accepted length (done slots advance 0 and write only
    their own pages or the trash page).  Emissions are truncated at the
    slot's first stop token and at ``max_new``.  Returns per-iteration
    ``emits`` (chunk, B, k+1) and counts ``ms`` (chunk, B) — the host
    appends ``emits[t, s, :ms[t, s]]``."""
    b = tok.shape[0]
    rows = jnp.arange(b)[:, None]

    def body(carry, _):
        tok, cache, pos, n_out, done, hist = carry
        drafts = propose_ngram(hist, pos + 1, k, ngram_n)
        window = jnp.concatenate([tok, drafts], axis=1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras,
                             page_size=page_size)
        g, a = greedy_accept(window, lg)
        live = ~done
        m = jnp.minimum(a + 1, max_new - n_out)
        hit = jnp.any(g[:, :, None] == stops[:, None, :], axis=-1)  # (B, k+1)
        hitm = hit & (jnp.arange(k + 1)[None, :] < m[:, None])
        any_hit = jnp.any(hitm, axis=1)
        first = jnp.argmax(hitm.astype(jnp.int32), axis=1)
        m = jnp.where(any_hit, first + 1, m)
        m = jnp.where(live, m, 0)
        emit_mask = jnp.arange(k + 1)[None, :] < m[:, None]
        emit = jnp.where(emit_mask, g, jnp.int32(pad_id))
        histcol = pos[:, None] + 1 + jnp.arange(k + 1)[None, :]
        hist = hist.at[rows, jnp.where(emit_mask, histcol, hist.shape[1])
                       ].set(g, mode="drop")
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                            axis=1),
                        tok)
        pos = pos + m
        n_out = n_out + m
        done = done | (live & any_hit) | (n_out >= max_new)
        cache = commit_verify(cfg, vc, jnp.maximum(m - 1, 0))
        return (tok, cache, pos, n_out, done, hist), (emit, m)

    carry, (emits, ms) = jax.lax.scan(
        body, (tok, cache, pos, n_out, done, hist), None, length=chunk)
    tok, cache, pos, n_out, done, hist = carry
    return cache, tok, pos, n_out, done, hist, emits, ms


_spec_chunk = functools.partial(
    jax.jit,
    static_argnames=("cfg", "chunk", "page_size", "k", "ngram_n", "pad_id"),
    donate_argnames=("cache",),
)(_spec_chunk_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "chunk", "page_size", "k", "ngram_n",
                     "pad_id"),
    donate_argnames=("cache",),
)
def _spec_chunk_sharded(params, cfg: ModelConfig, cache, tok, pos, n_out,
                        done, hist, max_new, stops, extras, *, mesh,
                        chunk: int, page_size: int, k: int, ngram_n: int,
                        pad_id: int):
    """``_spec_chunk_body`` under ``shard_map`` (weight shards per device,
    paged pools / history / scheduler carry replicated)."""

    def f(p, c, tk, ps_, no, dn, hs, mn, st, ex):
        return _spec_chunk_body(p, cfg, c, tk, ps_, no, dn, hs, mn, st, ex,
                                chunk=chunk, page_size=page_size, k=k,
                                ngram_n=ngram_n, pad_id=pad_id)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 9,
        out_specs=P(), check_rep=False,
    )(params, cache, tok, pos, n_out, done, hist, max_new, stops, extras)
