"""Speculative multi-token decode: amortise one weight stream over several
emitted tokens.

The paper's bound — and ``BENCH_decode.json``'s — is weight bytes per token:
every decode step streams the whole quantized tree to emit ONE token.
Speculation proposes ``k`` cheap draft tokens, then runs the target model
ONCE over the ``k+1``-token window (``models.verify_step``) and emits
``accepted + 1`` tokens (the accepted drafts plus one token the verify pass
itself produces) per weight stream.

Three speculation shapes, set on ``SpecConfig``:

* **Fixed linear** (the default): every window proposes exactly ``k``
  tokens.  Wins when acceptance is high, LOSES wall-clock when it is not —
  a (k+1)-token verify window costs more than a decode step, and a random
  workload accepts almost nothing (BENCH_serving.json's 0.85x/0.54x
  motivated the controller below).
* **Adaptive** (``adaptive=True``): a per-request acceptance EMA (carried
  in the compiled scan, snapshot-restored on crash replay) feeds a
  controller that picks the window width each scheduling round by
  maximising expected emitted tokens per window cost over a static bucket
  set {0, 1, 2, 4, ..., k} — ``k_round = argmax_b sum_live(1 + e + ... +
  e^b) / (1 + cost*b)``, composed with the PR 6 degradation ladder as
  ``min(ladder rung, controller)``.  At ``k_round == 0`` speculation gets
  out of the way but keeps learning for free: the fixed engine runs a
  one-token window whose own logits score the would-be first n-gram draft
  (``_ctrl_probe``: ``p_0(d_1)``, or argmax agreement under greedy), while
  the continuous engine dispatches the genuine PLAIN decode chunk and
  probes host-side (``propose_first_host``: the chance the emitted token
  equals the proposer's guess IS ``p_0(d_1)``) — either way the EMA keeps
  tracking the text and speculation re-engages the moment history becomes
  predictable.  Falling back to plain decode when losing is therefore the
  controller's steady state on hostile workloads, not a special mode.
* **Tree** (``tree_fan=F > 0``, n-gram proposer only): each window carries
  F candidate continuations of depth ``k`` sharing the current token as
  root — ``1 + F*k`` nodes verified in ONE pass through the shared-prefix
  tree attention mask of ``models.verify_step(tree=(F, k))`` on dense and
  paged caches alike.  Acceptance picks the best chain (greedy: longest
  matching prefix over chains; sampled: SpecInfer-style sequential head
  elimination + chain descent, ``sampling.tree_reject_sample`` — still
  EXACTLY distribution-preserving), then ``models.tree_relocate`` moves
  the accepted chain's cache rows into the linear layout before commit.

Verification comes in three flavours:

* **Greedy** (``greedy=True`` decode): accept the longest prefix whose
  greedy argmax agrees with the proposals.  An accepted token is by
  construction exactly what non-speculative greedy decode would have
  emitted, so output is TOKEN-IDENTICAL to the baseline — at any fixed,
  adaptive, ladder-degraded, or tree window
  (tests/test_speculative.py, tests/test_adaptive_spec.py).
* **Sampled exact** (``greedy=False``, ``accept="exact"``):
  rejection-sampling verification (``sampling.rejection_sample`` /
  ``tree_reject_sample``): accept proposal ``d_i ~ q_i`` with probability
  ``min(1, p_i(d_i)/q_i(d_i))`` against the target's warped verify
  distribution ``p_i``, resample the first rejection from the normalised
  residual ``max(p_i - q_i, 0)``, and draw the bonus token from the next
  node's distribution when everything is accepted.
* **Typical** (``accept="typical"``): entropy-band acceptance
  (``sampling.typical_accept_sample``) — accept ``d_i`` iff ``p_i(d_i) >
  min(eps, delta * exp(-H(p_i)))``, no rejection residual.  Explicitly
  LOSSY: the output distribution is biased toward the proposer; callers
  opt in for latency.  Linear windows only.

**Exactness contracts.**  Sampled exact speculation leaves the output
distribution of plain sampled decode EXACTLY unchanged for ANY proposal
distribution and ANY window-width schedule — including the adaptive
controller's, because each round's ``k`` is a deterministic function of
already-emitted data, so the accept/residual construction stays ancestral
sampling from ``p`` by induction over windows.  Proposer quality and
controller policy move the acceptance rate (weight streams paid), never
the law of the output.  The test methodology is two-layered
(tests/test_sampled_speculative.py, tests/test_adaptive_spec.py):

* **Seeded exactness** where the algorithm is key-deterministic: the
  per-row ``(base key, request id, counter)`` fold_in discipline
  (``serving.sampling``) makes the same ``key`` produce identical tokens
  across {dense fixed engine, paged continuous engine} x {1, 8 devices},
  across slot assignments/chunk sizes, and across preemption/recompute
  replays — asserted token-for-token.  Both moe archs are in this matrix:
  ``models.moe.moe_apply`` routes per row and combines over the fixed
  top-k axis, so dense and paged cache layouts agree to the last bit
  (tests/helpers.PAGED_BITEXACT_ARCHS).  Two scoped caveats remain:
  (a) logits are a function of the verify WINDOW WIDTH at the ulp level
  for MLA archs (XLA dot shapes) and at capacity level for moe (the
  dispatch capacity depends on the group length), so contracts that
  compare runs with DIFFERENT window schedules — adaptive vs plain,
  ladder-degraded vs clean — are token-exact under greedy but
  distributional under sampling for those archs; (b) tree chains at
  non-zero fan offsets occupy different store columns than a linear run,
  so tree-vs-linear is ulp-close, not bitwise — while chain 0 against an
  equal-width linear window, and tree dense-vs-paged, ARE bitwise
  (scripts/probe_tree_verify.py measures all three).
* **Distributional equivalence** where seeded identity is out of scope
  (different draw counts or window schedules): empirical token histograms
  over thousands of seeded decodes are compared with a pooled-bin
  chi-square homogeneity test at alpha=0.01 (plus a total-variation
  report), per model family (``tests/helpers.histogram_decode`` /
  ``chi_square_homogeneity``).

Two proposers:

* ``mode="ngram"`` — prompt-lookup decoding: match the last ``ngram_n``
  tokens of the row's history (prompt + emissions) against every earlier
  position and propose the ``k`` tokens that followed the most recent
  match (tree mode: the ``F`` most recent matches, one chain each; chain
  0 is always the linear proposer's choice); fall back to repeating the
  last token.  Zero extra parameters, runs inside the compiled program,
  and thrives on the repetitive tails real decodes (and untrained-model
  attractors) produce.  Deterministic, so its ``q`` is a one-hot point
  mass: acceptance degenerates to ``u < p(d)`` and the residual to ``p``
  with the proposal zeroed.  The history buffer is rebuilt WHOLE at every
  admit (fresh, crash-replay resume, and recompute re-admit alike) and
  kept warm through ladder rounds that disable speculation, so proposals
  always see ``prompt + every emission`` (tests/test_adaptive_spec.py
  audits this invariant under chaos).
* ``mode="draft"`` — a small draft model (its own cache) proposes ``k``
  tokens autoregressively — argmax under greedy decode, sampled from its
  own warped distribution ``q_i`` under sampling; its per-step states
  stack across the chain (``models.stack_verify_caches``) and commit once
  at the accepted length with the same ``commit_verify`` machinery as the
  target — no re-sync forward.  On the fixed engine the draft cache is
  dense; on the continuous engine it is a PAGED pool sharing the target's
  block tables (same page ids, its own storage), so draft speculation
  survives admit/retire/preemption like any other per-slot state.  Under
  the adaptive controller a ``k_round == 0`` window still runs ONE draft
  step so the draft cache tracks the emitted stream.

Rollback discipline (see ``models.verify_step``): attention/MLA writes at
rejected positions are dead by masking and rewritten by the next window;
SSM/conv state returns per-step stacked and ``commit_verify`` keeps the
accepted step per row; the paged engine's rejected page writes are
reclaimed the same way (the block tables never move).  Tree windows add
one step: ``models.tree_relocate`` copies the ACCEPTED chain's rows from
their tree columns (``pos + 1 + cf*k .. ``) into the linear columns
before the commit, on both cache layouts — the engines over-provision
``fan*k`` positions past the request frontier so relocation never reads
through the shared trash page.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import (
    commit_verify,
    init_cache,
    prefill,
    tree_relocate,
    verify_step,
)
from repro.models.lm import stack_verify_caches
from repro.serving.sampling import (
    TAG_TOKEN,
    TAG_WINDOW,
    draw_keys,
    rejection_sample,
    sample_rows,
    tree_reject_sample,
    typical_accept_sample,
    warp_logits,
)
from repro.serving.sharded import tree_pspecs


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Static speculation settings (hashable — safe to close over in jit).

    ``k``: proposed tokens per verify step (the window is ``k+1`` wide;
    tree mode: the chain DEPTH, the window is ``1 + tree_fan*k`` wide).
    ``mode``: ``"ngram"`` (prompt-lookup, default) or ``"draft"`` (draft
    model; the engine must hold ``draft_cfg``/``draft_params``).
    ``ngram_n``: match length for the prompt-lookup proposer.

    ``adaptive``: per-request acceptance-EMA controller (module
    docstring); ``ctrl_alpha`` the EMA coefficient, ``ctrl_init`` the
    optimism a fresh request starts with (the default 0.0 starts every
    request at k=0 — plain-decode-cost rounds whose free probe measures
    real acceptance, so hostile traces pay NOTHING for warm-up and
    proposer-friendly ones climb to wide windows within a few rounds),
    ``ctrl_cost`` the modelled marginal cost of one extra window
    position relative to a decode step (the verify window costs
    ``~(1 + ctrl_cost*k)`` decode steps).

    ``tree_fan``: > 0 switches to multi-candidate tree drafts (n-gram
    proposer only; exclusive with ``adaptive`` and ``accept="typical"``).

    ``accept``: ``"exact"`` (rejection sampling, distribution-preserving)
    or ``"typical"`` (entropy-band acceptance, lossy; linear only)."""

    k: int = 4
    mode: str = "ngram"
    ngram_n: int = 2
    adaptive: bool = False
    ctrl_alpha: float = 0.5
    ctrl_init: float = 0.0
    ctrl_cost: float = 0.18
    tree_fan: int = 0
    accept: str = "exact"
    typical_eps: float = 0.3
    typical_delta: float = 0.09

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculation needs k >= 1, got {self.k}")
        if self.mode not in ("ngram", "draft"):
            raise ValueError(f"mode must be ngram|draft, got {self.mode!r}")
        if self.ngram_n < 1:
            raise ValueError(f"ngram_n must be >= 1, got {self.ngram_n}")
        if self.accept not in ("exact", "typical"):
            raise ValueError(
                f"accept must be exact|typical, got {self.accept!r}")
        if self.tree_fan < 0:
            raise ValueError(f"tree_fan must be >= 0, got {self.tree_fan}")
        if self.tree_fan:
            if self.mode != "ngram":
                raise ValueError("tree drafts need mode='ngram' (the draft "
                                 "model proposes one chain)")
            if self.adaptive:
                raise ValueError("tree_fan and adaptive are exclusive (the "
                                 "controller schedules linear windows)")
            if self.accept != "exact":
                raise ValueError("tree verification is exact rejection "
                                 "sampling; accept='typical' is linear-only")
        if not 0.0 < self.ctrl_alpha <= 1.0:
            raise ValueError(f"ctrl_alpha in (0, 1], got {self.ctrl_alpha}")
        if self.ctrl_cost <= 0.0:
            raise ValueError(f"ctrl_cost must be > 0, got {self.ctrl_cost}")


def as_spec(speculate) -> SpecConfig:
    """Normalise an engine's ``speculate=`` argument: SpecConfig, or an int
    shorthand for ``SpecConfig(k=...)``."""
    if isinstance(speculate, SpecConfig):
        return speculate
    return SpecConfig(k=int(speculate))


# -------------------------------------------------------------- controller --
def ctrl_buckets(k: int) -> tuple:
    """Static candidate window widths {0, 1, 2, 4, ..., k}: the controller
    re-jits the chunk at most O(log k) times across a whole serve."""
    bs, b = [0], 1
    while b < k:
        bs.append(b)
        b *= 2
    bs.append(k)
    return tuple(dict.fromkeys(bs))


def _ctrl_gain(e, b: int):
    """Expected tokens one window of width ``b`` emits for a slot with
    per-draft acceptance ``e``: the bonus token plus the geometric
    accepted prefix, ``1 + e + e^2 + ... + e^b``."""
    g, p = 1.0 + 0.0 * e, 1.0 + 0.0 * e
    for _ in range(b):
        p = p * e
        g = g + p
    return g


def adaptive_k_host(ema: np.ndarray, live: np.ndarray,
                    spec: SpecConfig) -> int:
    """The scheduling round's window width: maximise the batch's expected
    emitted tokens per window cost over the bucket set.  Ties (and the
    empty batch) resolve to the SMALLER width — the conservative side of
    the wall-clock bet.  Host-side numpy; the fixed engine runs the jnp
    twin ``_ctrl_k`` inside its loop."""
    if not bool(np.any(live)):
        return 0
    e = np.clip(ema[live].astype(np.float64), 0.0, 1.0)
    best_s, best_b = -1.0, 0
    for b in ctrl_buckets(spec.k):
        s = float(np.sum(_ctrl_gain(e, b))) / (1.0 + spec.ctrl_cost * b)
        if s > best_s + 1e-12:
            best_s, best_b = s, b
    return best_b


def _ctrl_k(ema, live, k: int, cost: float):
    """jnp twin of ``adaptive_k_host`` (traced scalar int32): argmax picks
    the FIRST maximum, i.e. the smallest bucket on ties."""
    buckets = ctrl_buckets(k)
    e = jnp.where(live, jnp.clip(ema, 0.0, 1.0), 0.0)
    scores = jnp.stack([jnp.sum(jnp.where(live, _ctrl_gain(e, b), 0.0))
                        / (1.0 + cost * b) for b in buckets])
    return jnp.asarray(buckets, jnp.int32)[jnp.argmax(scores)]


def _ctrl_probe(lg0, d1, *, greedy: bool, temperature, top_k: int):
    """Free acceptance probe from a window's own first-node logits: the
    probability the would-be first draft ``d1`` would have been accepted
    (point-mass proposal: exactly ``p_0(d_1)``; greedy: argmax
    agreement).  This is what lets a ``k == 0`` round keep learning at
    plain-decode cost."""
    if greedy:
        return (jnp.argmax(lg0, axis=-1).astype(jnp.int32)
                == d1).astype(jnp.float32)
    p0 = jax.nn.softmax(warp_logits(lg0, temperature, top_k), axis=-1)
    return jnp.take_along_axis(p0, d1[:, None], axis=1)[:, 0]


def _ctrl_update(ema, live, a, k_window, phat0, alpha: float):
    """One EMA step from this window's observation: with a real window,
    the censored-geometric estimate ``a/(a+1)`` (1.0 when every proposal
    was accepted); at width 0, the free probe.  Done slots freeze."""
    af = a.astype(jnp.float32)
    kw = jnp.asarray(k_window, jnp.int32)
    r = jnp.where(kw == 0, phat0,
                  jnp.where(a >= kw, 1.0, af / (af + 1.0)))
    return jnp.where(live, (1.0 - alpha) * ema + alpha * r, ema)


# ---------------------------------------------------------------- proposer --
def propose_ngram(hist: jnp.ndarray, hlen: jnp.ndarray, k: int,
                  n: int) -> jnp.ndarray:
    """Prompt-lookup proposal: for each row of ``hist`` (B, W) with live
    length ``hlen`` (B,) — prompt plus every emitted token, the last one
    still pending — find the most recent earlier occurrence of the trailing
    ``n``-gram and propose the ``k`` tokens that followed it.  Positions
    past the match's continuation (and rows with no match) propose the last
    token — a cheap guess that costs nothing when rejected.  Returns
    (B, k) int32."""
    j, last = _ngram_matches(hist, hlen, 1, n)
    found = j[:, 0] >= 0
    src = j + n + jnp.arange(k)[None, :]  # (B, k)
    prop = jnp.take_along_axis(hist, jnp.clip(src, 0, hist.shape[1] - 1),
                               axis=1)
    use = found[:, None] & (src < hlen[:, None])
    return jnp.where(use, prop, last).astype(jnp.int32)


def propose_first_host(hist_row: np.ndarray, hlen: int, n: int) -> int:
    """Host/numpy twin of ``propose_ngram``'s FIRST proposed token for one
    row: the token following the most recent earlier occurrence of the
    trailing ``n``-gram, falling back to repeating the last token.  The
    adaptive controller's plain-decode fallback rounds probe with it at
    zero device cost: for sampled decode ``P(emitted == proposal)`` is
    exactly ``p0(proposal)`` — the quantity ``_ctrl_probe`` measures
    on-device — and for greedy decode the indicator IS the
    argmax-agreement probe."""
    if hlen >= n + 1:
        h = hist_row[:hlen]
        gram = h[hlen - n:]
        win = np.lib.stride_tricks.sliding_window_view(h, n)
        hits = np.nonzero((win[: hlen - n] == gram).all(axis=1))[0]
        if hits.size:
            return int(h[hits[-1] + n])
    return int(hist_row[max(hlen - 1, 0)])


def _ngram_matches(hist, hlen, fan: int, n: int):
    """Positions of the ``fan`` most recent earlier occurrences of each
    row's trailing ``n``-gram, descending (most recent first; -1 where
    fewer exist), plus the last-token fallback.  Returns (j (B, fan),
    last (B, 1))."""
    b, w = hist.shape
    gi = hlen[:, None] - n + jnp.arange(n)[None, :]
    gram = jnp.take_along_axis(hist, jnp.clip(gi, 0, w - 1), axis=1)  # (B, n)
    match = jnp.ones((b, w), bool)
    for i in range(n):
        # window starting at q sees hist[q + i]; shift left, pad invalid
        shifted = jnp.pad(hist[:, i:], ((0, 0), (0, i)), constant_values=-1)
        match = match & (shifted == gram[:, i : i + 1])
    q = jnp.arange(w)[None, :]
    # strictly-earlier windows only: the trailing gram itself sits at
    # hlen - n, so candidates end at hlen - n - 1
    valid = match & (q <= hlen[:, None] - n - 1)
    scored = jnp.where(valid, q, -1)
    j = jax.lax.top_k(scored, fan)[0]  # (B, fan) most recent first
    last = jnp.take_along_axis(hist, jnp.clip(hlen - 1, 0, w - 1)[:, None],
                               axis=1)  # (B, 1)
    return j, last


def propose_ngram_tree(hist: jnp.ndarray, hlen: jnp.ndarray, fan: int,
                       depth: int, n: int) -> jnp.ndarray:
    """Multi-candidate prompt-lookup: one chain per earlier occurrence of
    the trailing n-gram, most recent first — chain 0 is exactly
    ``propose_ngram``'s choice, so a 1-fan tree degenerates to the linear
    proposer.  Rows (or trailing chains) without a match fall back to
    repeating the last token; duplicate chains are harmless — sampled
    verification auto-rejects a head whose mass was already consumed, and
    greedy takes the longest prefix wherever it appears.  Returns
    (B, fan, depth) int32."""
    b, w = hist.shape
    j, last = _ngram_matches(hist, hlen, fan, n)
    found = j >= 0  # (B, fan)
    src = j[:, :, None] + n + jnp.arange(depth)[None, None, :]  # (B, F, D)
    prop = jnp.take_along_axis(
        hist, jnp.clip(src, 0, w - 1).reshape(b, fan * depth), axis=1
    ).reshape(b, fan, depth)
    use = found[:, :, None] & (src < hlen[:, None, None])
    return jnp.where(use, prop, last[:, :, None]).astype(jnp.int32)


# -------------------------------------------------------------- acceptance --
def greedy_accept(window: jnp.ndarray, logits: jnp.ndarray):
    """Longest-matching-prefix greedy acceptance.  ``window`` (B, k+1) is
    the verified input (last accepted token + k proposals); ``logits``
    (B, k+1, V) the target's outputs.  Returns ``(g, a)``: the target's
    greedy tokens (B, k+1) — position j is the token following window[:j+1]
    — and ``a`` (B,) the number of accepted proposals; the row emits
    ``g[:a+1]`` (accepted proposals == g[:a] plus the free bonus token)."""
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    match = (window[:, 1:] == g[:, :-1]).astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    return g, a


def greedy_tree_accept(chains: jnp.ndarray, logits: jnp.ndarray, *,
                       kcap=None):
    """Greedy acceptance over a fan-of-chains tree: per chain, the longest
    prefix whose tokens equal the argmax at their predecessor node; the
    window keeps the best chain (ties: lowest index, which is the linear
    proposer's chain).  ``chains`` (B, F, D); ``logits`` (B, 1+F*D, V) in
    node order.  Returns ``(tokens (B, D+1), a (B,), cf (B,))`` laid out
    like ``sampling.tree_reject_sample``: the row emits
    ``tokens[:, :a+1]``, the last of which is the bonus argmax at the
    deepest accepted node."""
    b, fan, depth = chains.shape
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, 1+F*D)
    # chain f step i's predecessor node: the root for i == 0, else the
    # previous step 1 + f*depth + (i-1) == f*depth + i.
    pred = np.zeros((fan, depth), np.int32)
    for f in range(fan):
        for i in range(1, depth):
            pred[f, i] = f * depth + i
    match = (chains == g[:, jnp.asarray(pred)]).astype(jnp.int32)  # (B,F,D)
    af = jnp.sum(jnp.cumprod(match, axis=2), axis=2)  # (B, F)
    if kcap is not None:
        af = jnp.minimum(af, kcap[:, None])
    a = jnp.max(af, axis=1)
    cf = jnp.argmax(af, axis=1).astype(jnp.int32)  # first max: lowest f
    ch = jnp.take_along_axis(chains, cf[:, None, None], axis=1)[:, 0]  # (B,D)
    last_node = jnp.where(a > 0, cf * depth + a, 0)
    bonus = jnp.take_along_axis(g, last_node[:, None], axis=1)  # (B, 1)
    padded = jnp.concatenate([ch, ch[:, -1:]], axis=1)
    toks = jnp.where(jnp.arange(depth + 1)[None, :] < a[:, None],
                     padded, bonus)
    return toks, a, cf


def _accept(window, drafts, lg, *, greedy: bool, temperature, top_k: int,
            wkeys, q, kcap=None, n_draws=None, accept: str = "exact",
            typical_eps: float = 0.3, typical_delta: float = 0.09):
    """One linear-window verification: greedy longest-prefix, rejection
    sampling against the warped target distribution, or typical
    (entropy-band) acceptance.  Returns ``(g, a)`` with the shared
    contract that the row emits ``g[:, :a+1]``.  ``q`` is the proposal
    distribution (B, k, V) or None for deterministic proposers (one-hot
    point mass).  ``kcap``/``n_draws`` implement the fixed engine's
    adaptive cap: the window stays ``k`` wide (static shapes) while
    acceptance stops at the controller's width, with a cap-independent
    draw stream.  A zero-width window (``drafts`` (B, 0)) degenerates to
    one plain draw — greedy argmax, or a categorical on the window key's
    final-draw half, mirroring ``rejection_sample``'s ``kcap == 0``
    stream."""
    if greedy:
        g, a = greedy_accept(window, lg)
        if kcap is not None:
            a = jnp.minimum(a, kcap)
        return g, a
    if drafts.shape[1] == 0:
        kf = jax.vmap(lambda kk: jax.random.split(kk)[1])(wkeys)
        wl = warp_logits(lg[:, 0], temperature, top_k)
        t0 = jax.vmap(jax.random.categorical)(kf, wl).astype(jnp.int32)
        return t0[:, None], jnp.zeros((lg.shape[0],), jnp.int32)
    p = jax.nn.softmax(warp_logits(lg, temperature, top_k), axis=-1)
    if accept == "typical":
        return typical_accept_sample(wkeys, drafts, p, kcap=kcap,
                                     eps=typical_eps, delta=typical_delta)
    if q is None:
        q = jax.nn.one_hot(drafts, lg.shape[-1], dtype=jnp.float32)
    return rejection_sample(wkeys, drafts, q, p, kcap=kcap, n_draws=n_draws)


# ------------------------------------------------- fixed-batch spec engine --
def _draft_propose(draft_params, draft_cfg, dcache, tok, pos, extras, k,
                   *, page_size: int = 0, wkeys=None, greedy: bool = True,
                   temperature=1.0, top_k: int = 0):
    """Autoregressive draft proposals: k+1 single-token steps consume the
    whole window ``[tok, d_1..d_k]`` (the extra step eats ``d_k`` so every
    accepted length has a state).  Greedy decode proposes the draft's
    argmax; sampled decode draws ``d_i ~ q_i`` from the draft's warped
    distribution using per-row subkeys of the window key, and returns the
    stacked ``q`` (B, k, V) for the rejection-sampling accept ratio.
    Returns ``(drafts (B, k), q or None, stacked)`` where ``stacked`` is
    the chain's states merged into one verify cache
    (``models.stack_verify_caches``) — the caller commits it once at the
    accepted length, no re-sync forward.  With a paged ``dcache`` (the
    continuous engine) the chain scatters/gathers through the draft pool's
    block tables at per-slot positions.  ``k == 0`` (an adaptive
    plain-decode round) still runs the single step that consumes ``tok``,
    so the draft cache keeps tracking the emitted stream."""
    dc, t, ds, qs, vcs = dcache, tok, [], [], []
    zero = jnp.zeros((tok.shape[0],), jnp.int32)
    for i in range(k + 1):
        lg, vc = verify_step(draft_params, draft_cfg, t, dc, pos + i, extras,
                             page_size=page_size)
        vcs.append(vc)
        dc = commit_verify(draft_cfg, vc, zero)
        if i < k:
            last = lg[:, -1, :]
            if greedy:
                t = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
            else:
                wl = warp_logits(last, temperature, top_k)
                ki = jax.vmap(lambda kk: jax.random.fold_in(kk, 3 + i))(wkeys)
                t = jax.vmap(jax.random.categorical)(ki, wl).astype(
                    jnp.int32)[:, None]
                qs.append(jax.nn.softmax(wl, axis=-1))
            ds.append(t)
    drafts = (jnp.concatenate(ds, axis=1) if ds
              else jnp.zeros((tok.shape[0], 0), jnp.int32))
    return (drafts, jnp.stack(qs, axis=1) if qs else None,
            stack_verify_caches(draft_cfg, vcs))


def _spec_generate_body(params, cfg: ModelConfig, prompt, extras, draft_params,
                        key, temperature, *, draft_cfg, n_new: int,
                        max_seq: int, k: int, mode: str, ngram_n: int,
                        greedy: bool, top_k: int, adaptive: bool = False,
                        ctrl_alpha: float = 0.5, ctrl_init: float = 0.5,
                        ctrl_cost: float = 0.18, accept: str = "exact",
                        typical_eps: float = 0.3,
                        typical_delta: float = 0.09):
    """Whole speculative generation — prefill + a verify-window loop — as
    one XLA program.  Greedy verification, rejection sampling, or typical
    acceptance (see module docstring).  With ``adaptive=True`` the loop
    carries the per-row acceptance EMA and caps acceptance at the
    controller's width each iteration; the WINDOW stays ``k`` wide (a
    fixed batch cannot reshape a compiled loop), so the fixed engine is
    the controller's reference semantics — the wall-clock savings live in
    the continuous engine, which actually narrows the window.  Returns
    (tokens (B, n_new), verify_steps, live_row_steps): greedy tokens are
    identical to the plain greedy ``generate``; sampled tokens are
    key-deterministic (per-row fold_in streams) and distributionally
    identical to plain sampled decode.  emitted-per-live-row-step =
    ``B*(n_new-1) / live_row_steps`` is the speculation multiplier."""
    b, s = prompt.shape
    if n_new == 0:
        return (jnp.zeros((b, 0), jnp.int32), jnp.int32(0), jnp.int32(0))
    rids = jnp.arange(b, dtype=jnp.int32)
    cache = init_cache(cfg, b, max_seq)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    tok = sample_rows(
        logits[:, -1, :],
        None if greedy else draw_keys(key, rids, 0, TAG_TOKEN),
        greedy=greedy, temperature=temperature, top_k=top_k)[:, None]
    hist = jnp.zeros((b, max_seq), jnp.int32)
    hist = jax.lax.dynamic_update_slice(hist, prompt.astype(jnp.int32), (0, 0))
    hist = hist.at[:, s].set(tok[:, 0])
    out = jnp.zeros((b, n_new), jnp.int32).at[:, 0].set(tok[:, 0])
    n_em = jnp.ones((b,), jnp.int32)
    if mode == "draft":
        # k extra positions: the draft chain reads back the speculative
        # positions it writes, and near the max_seq frontier those reads
        # must hit real stored values (a dense out-of-store write DROPS),
        # mirroring the paged engine's _store_seq over-provisioning so the
        # two engines stay key-identical at the boundary.
        dcache = init_cache(draft_cfg, b, max_seq + k)
        _, dcache = prefill(draft_params, draft_cfg, prompt, dcache, extras)
    else:
        dcache = ()
    rows = jnp.arange(b)[:, None]
    steps0 = jnp.int32(0)
    wctr0 = jnp.zeros((b,), jnp.int32)
    ema0 = jnp.full((b,), ctrl_init, jnp.float32)

    def cond(carry):
        return jnp.any(carry[3] < n_new)

    def body(carry):
        (tok, cache, dcache, n_em, out, hist, wctr, ema, steps,
         live_steps) = carry
        pos = jnp.int32(s) - 1 + n_em  # (B,) tokens already consumed
        live = n_em < n_new
        wkeys = (None if greedy
                 else draw_keys(key, rids, wctr, TAG_WINDOW))
        if mode == "draft":
            drafts, q, dstack = _draft_propose(
                draft_params, draft_cfg, dcache, tok, pos, extras, k,
                wkeys=wkeys, greedy=greedy, temperature=temperature,
                top_k=top_k)
        else:
            drafts = propose_ngram(hist, jnp.int32(s) + n_em, k, ngram_n)
            q = None
        window = jnp.concatenate([tok, drafts], axis=1)  # (B, k+1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras)
        if adaptive:
            keff = _ctrl_k(ema, live, k, ctrl_cost)
            kcap = jnp.broadcast_to(keff, (b,))
        else:
            keff, kcap = jnp.int32(k), None
        g, a = _accept(window, drafts, lg, greedy=greedy,
                       temperature=temperature, top_k=top_k, wkeys=wkeys,
                       q=q, kcap=kcap, n_draws=k, accept=accept,
                       typical_eps=typical_eps, typical_delta=typical_delta)
        if adaptive:
            d1 = (drafts[:, 0] if mode == "draft"
                  else propose_ngram(hist, jnp.int32(s) + n_em, 1,
                                     ngram_n)[:, 0])
            phat0 = _ctrl_probe(lg[:, 0], d1, greedy=greedy,
                                temperature=temperature, top_k=top_k)
            ema = _ctrl_update(ema, live, a, keff, phat0, ctrl_alpha)
        m = jnp.where(live, jnp.minimum(a + 1, n_new - n_em), 0)  # (B,)
        emit = jnp.arange(k + 1)[None, :] < m[:, None]
        cols = n_em[:, None] + jnp.arange(k + 1)[None, :]
        out = out.at[rows, jnp.where(emit, cols, n_new)].set(g, mode="drop")
        hist = hist.at[rows, jnp.where(emit, jnp.int32(s) + cols, max_seq)
                       ].set(g, mode="drop")
        cache = commit_verify(cfg, vc, jnp.maximum(m - 1, 0))
        if mode == "draft":
            dcache = commit_verify(draft_cfg, dstack, jnp.maximum(m - 1, 0))
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                            axis=1),
                        tok)
        n_em = n_em + m
        return (tok, cache, dcache, n_em, out, hist,
                wctr + live.astype(jnp.int32), ema, steps + 1,
                live_steps + jnp.sum(live.astype(jnp.int32)))

    carry = jax.lax.while_loop(
        cond, body,
        (tok, cache, dcache, n_em, out, hist, wctr0, ema0, steps0, steps0))
    return carry[4], carry[8], carry[9]


_spec_generate = functools.partial(
    jax.jit,
    static_argnames=("cfg", "draft_cfg", "n_new", "max_seq", "k", "mode",
                     "ngram_n", "greedy", "top_k", "adaptive", "ctrl_alpha",
                     "ctrl_init", "ctrl_cost", "accept", "typical_eps",
                     "typical_delta"),
)(_spec_generate_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "n_new", "max_seq", "k", "ngram_n",
                     "greedy", "top_k", "adaptive", "ctrl_alpha", "ctrl_init",
                     "ctrl_cost", "accept", "typical_eps", "typical_delta"),
)
def _spec_generate_sharded(params, cfg: ModelConfig, prompt, extras, key,
                           temperature, *, mesh, n_new: int, max_seq: int,
                           k: int, ngram_n: int, greedy: bool, top_k: int,
                           adaptive: bool = False, ctrl_alpha: float = 0.5,
                           ctrl_init: float = 0.5, ctrl_cost: float = 0.18,
                           accept: str = "exact", typical_eps: float = 0.3,
                           typical_delta: float = 0.09):
    """``_spec_generate_body`` (ngram mode) under ``shard_map``: weight
    shards per device, everything else — including the PRNG key and the
    controller EMA — is replicated, so every device draws the same samples
    and iterates in lockstep."""

    def f(p, pr, ex, ky, t):
        return _spec_generate_body(
            p, cfg, pr, ex, None, ky, t, draft_cfg=None, n_new=n_new,
            max_seq=max_seq, k=k, mode="ngram", ngram_n=ngram_n,
            greedy=greedy, top_k=top_k, adaptive=adaptive,
            ctrl_alpha=ctrl_alpha, ctrl_init=ctrl_init, ctrl_cost=ctrl_cost,
            accept=accept, typical_eps=typical_eps,
            typical_delta=typical_delta)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params), P(), P(), P(), P()),
        out_specs=(P(), P(), P()), check_rep=False,
    )(params, prompt, extras, key, temperature)


def _spec_tree_generate_body(params, cfg: ModelConfig, prompt, extras, key,
                             temperature, *, n_new: int, max_seq: int,
                             fan: int, depth: int, ngram_n: int,
                             greedy: bool, top_k: int):
    """Tree-draft generation on the fixed dense engine: each iteration
    verifies a ``1 + fan*depth``-node window (``verify_step(tree=...)``),
    accepts the best chain, relocates its cache rows into the linear
    layout (``models.tree_relocate``), and commits the matching SSM node.
    The dense store carries ``fan*depth`` columns past ``max_seq`` so
    relocation near the frontier always reads real rows."""
    b, s = prompt.shape
    if n_new == 0:
        return (jnp.zeros((b, 0), jnp.int32), jnp.int32(0), jnp.int32(0))
    rids = jnp.arange(b, dtype=jnp.int32)
    t_nodes = 1 + fan * depth
    cache = init_cache(cfg, b, max_seq + fan * depth)
    logits, cache = prefill(params, cfg, prompt, cache, extras)
    tok = sample_rows(
        logits[:, -1, :],
        None if greedy else draw_keys(key, rids, 0, TAG_TOKEN),
        greedy=greedy, temperature=temperature, top_k=top_k)[:, None]
    hist = jnp.zeros((b, max_seq), jnp.int32)
    hist = jax.lax.dynamic_update_slice(hist, prompt.astype(jnp.int32), (0, 0))
    hist = hist.at[:, s].set(tok[:, 0])
    out = jnp.zeros((b, n_new), jnp.int32).at[:, 0].set(tok[:, 0])
    n_em = jnp.ones((b,), jnp.int32)
    rows = jnp.arange(b)[:, None]
    steps0 = jnp.int32(0)
    wctr0 = jnp.zeros((b,), jnp.int32)

    def cond(carry):
        return jnp.any(carry[2] < n_new)

    def body(carry):
        tok, cache, n_em, out, hist, wctr, steps, live_steps = carry
        pos = jnp.int32(s) - 1 + n_em
        live = n_em < n_new
        wkeys = (None if greedy
                 else draw_keys(key, rids, wctr, TAG_WINDOW))
        chains = propose_ngram_tree(hist, jnp.int32(s) + n_em, fan, depth,
                                    ngram_n)
        window = jnp.concatenate([tok, chains.reshape(b, fan * depth)],
                                 axis=1)  # (B, 1+F*D)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras,
                             tree=(fan, depth))
        if greedy:
            g, a, cf = greedy_tree_accept(chains, lg)
        else:
            p = jax.nn.softmax(warp_logits(lg, temperature, top_k), axis=-1)
            g, a, cf = tree_reject_sample(wkeys, chains, p)
        m = jnp.where(live, jnp.minimum(a + 1, n_new - n_em), 0)
        acc = jnp.maximum(m - 1, 0)  # accepted drafts actually kept
        emit = jnp.arange(depth + 1)[None, :] < m[:, None]
        cols = n_em[:, None] + jnp.arange(depth + 1)[None, :]
        out = out.at[rows, jnp.where(emit, cols, n_new)].set(g, mode="drop")
        hist = hist.at[rows, jnp.where(emit, jnp.int32(s) + cols, max_seq)
                       ].set(g, mode="drop")
        vc = tree_relocate(cfg, vc, pos, acc, cf, fan=fan, depth=depth)
        sel = jnp.where(acc > 0, cf * depth + acc, 0)  # deepest kept node
        cache = commit_verify(cfg, vc, sel)
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, acc[:, None], axis=1),
                        tok)
        n_em = n_em + m
        return (tok, cache, n_em, out, hist,
                wctr + live.astype(jnp.int32), steps + 1,
                live_steps + jnp.sum(live.astype(jnp.int32)))

    carry = jax.lax.while_loop(
        cond, body, (tok, cache, n_em, out, hist, wctr0, steps0, steps0))
    del t_nodes
    return carry[3], carry[6], carry[7]


_spec_tree_generate = functools.partial(
    jax.jit,
    static_argnames=("cfg", "n_new", "max_seq", "fan", "depth", "ngram_n",
                     "greedy", "top_k"),
)(_spec_tree_generate_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "n_new", "max_seq", "fan", "depth",
                     "ngram_n", "greedy", "top_k"),
)
def _spec_tree_generate_sharded(params, cfg: ModelConfig, prompt, extras, key,
                                temperature, *, mesh, n_new: int,
                                max_seq: int, fan: int, depth: int,
                                ngram_n: int, greedy: bool, top_k: int):
    """``_spec_tree_generate_body`` under ``shard_map`` (weight shards per
    device, replicated everything else)."""

    def f(p, pr, ex, ky, t):
        return _spec_tree_generate_body(
            p, cfg, pr, ex, ky, t, n_new=n_new, max_seq=max_seq, fan=fan,
            depth=depth, ngram_n=ngram_n, greedy=greedy, top_k=top_k)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params), P(), P(), P(), P()),
        out_specs=(P(), P(), P()), check_rep=False,
    )(params, prompt, extras, key, temperature)


# ------------------------------------------- continuous-batching spec chunk --
def _spec_chunk_body(params, cfg: ModelConfig, cache, draft_params, dcache,
                     tok, pos, n_out, done, hist, wctr, ema, rids, max_new,
                     stops, key, temperature, extras, *, draft_cfg,
                     chunk: int, page_size: int, k: int, mode: str,
                     ngram_n: int, pad_id: int, greedy: bool, top_k: int,
                     adaptive: bool, ctrl_alpha: float, accept: str,
                     typical_eps: float, typical_delta: float):
    """``chunk`` speculative verify windows over all batch slots as one
    compiled scan — the speculation analogue of ``engine._decode_chunk_body``
    (greedy, rejection-sampled, or typical-accepted).  Each iteration
    proposes ``k`` tokens per slot (n-gram history lookup, or the paged
    draft model), verifies the window against the paged cache, and
    advances each slot by its own accepted length (done slots advance 0
    and write only their own pages or the trash page).  ``k`` here is the
    ROUND's width — under the adaptive controller the host re-picks it
    from the returned per-slot acceptance EMAs at every chunk boundary
    (``adaptive_k_host``), down to ``k == 0``: a one-token window at
    plain-decode cost that still probes the would-be first draft
    (``_ctrl_probe``) so the EMA can recover.  Sampled draws are keyed
    per slot by ``(key, rid, window counter)`` so slot assignment and
    chunk boundaries never change a request's stream.  Emissions are
    truncated at the slot's first stop token and at ``max_new``.  Returns
    per-iteration ``emits`` (chunk, B, k+1) and counts ``ms`` (chunk, B)
    — the host appends ``emits[t, s, :ms[t, s]]``."""
    b = tok.shape[0]
    rows = jnp.arange(b)[:, None]

    def body(carry, _):
        tok, cache, dcache, pos, n_out, done, hist, wctr, ema = carry
        live = ~done
        wkeys = (None if greedy
                 else draw_keys(key, rids, wctr, TAG_WINDOW))
        props = propose_ngram(hist, pos + 1, max(k, 1), ngram_n)
        if mode == "draft":
            drafts, q, dstack = _draft_propose(
                draft_params, draft_cfg, dcache, tok, pos, extras, k,
                page_size=page_size, wkeys=wkeys, greedy=greedy,
                temperature=temperature, top_k=top_k)
        else:
            drafts = props[:, :k]
            q = None
        window = jnp.concatenate([tok, drafts], axis=1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras,
                             page_size=page_size)
        g, a = _accept(window, drafts, lg, greedy=greedy,
                       temperature=temperature, top_k=top_k, wkeys=wkeys,
                       q=q, accept=accept, typical_eps=typical_eps,
                       typical_delta=typical_delta)
        if adaptive:
            d1 = drafts[:, 0] if (mode == "draft" and k) else props[:, 0]
            phat0 = _ctrl_probe(lg[:, 0], d1, greedy=greedy,
                                temperature=temperature, top_k=top_k)
            ema = _ctrl_update(ema, live, a, k, phat0, ctrl_alpha)
        m = jnp.minimum(a + 1, max_new - n_out)
        # A stop token accepted mid-window truncates the window THERE: the
        # stop itself is emitted, everything after it in the window is
        # masked (never reaches the output, the history, or `tok`).
        hit = jnp.any(g[:, :, None] == stops[:, None, :], axis=-1)  # (B, k+1)
        hitm = hit & (jnp.arange(k + 1)[None, :] < m[:, None])
        any_hit = jnp.any(hitm, axis=1)
        first = jnp.argmax(hitm.astype(jnp.int32), axis=1)
        m = jnp.where(any_hit, first + 1, m)
        m = jnp.where(live, m, 0)
        emit_mask = jnp.arange(k + 1)[None, :] < m[:, None]
        emit = jnp.where(emit_mask, g, jnp.int32(pad_id))
        histcol = pos[:, None] + 1 + jnp.arange(k + 1)[None, :]
        hist = hist.at[rows, jnp.where(emit_mask, histcol, hist.shape[1])
                       ].set(g, mode="drop")
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                            axis=1),
                        tok)
        pos = pos + m
        n_out = n_out + m
        done = done | (live & any_hit) | (n_out >= max_new)
        cache = commit_verify(cfg, vc, jnp.maximum(m - 1, 0))
        if mode == "draft":
            dcache = commit_verify(draft_cfg, dstack, jnp.maximum(m - 1, 0))
        return ((tok, cache, dcache, pos, n_out, done, hist,
                 wctr + live.astype(jnp.int32), ema), (emit, m))

    carry, (emits, ms) = jax.lax.scan(
        body, (tok, cache, dcache, pos, n_out, done, hist, wctr, ema), None,
        length=chunk)
    tok, cache, dcache, pos, n_out, done, hist, wctr, ema = carry
    return (cache, dcache, tok, pos, n_out, done, hist, wctr, ema, emits,
            ms)


_spec_chunk = functools.partial(
    jax.jit,
    static_argnames=("cfg", "draft_cfg", "chunk", "page_size", "k", "mode",
                     "ngram_n", "pad_id", "greedy", "top_k", "adaptive",
                     "ctrl_alpha", "accept", "typical_eps", "typical_delta"),
    donate_argnames=("cache", "dcache"),
)(_spec_chunk_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "chunk", "page_size", "k", "ngram_n",
                     "pad_id", "greedy", "top_k", "adaptive", "ctrl_alpha",
                     "accept", "typical_eps", "typical_delta"),
    donate_argnames=("cache",),
)
def _spec_chunk_sharded(params, cfg: ModelConfig, cache, tok, pos, n_out,
                        done, hist, wctr, ema, rids, max_new, stops, key,
                        temperature, extras, *, mesh, chunk: int,
                        page_size: int, k: int, ngram_n: int, pad_id: int,
                        greedy: bool, top_k: int, adaptive: bool,
                        ctrl_alpha: float, accept: str, typical_eps: float,
                        typical_delta: float):
    """``_spec_chunk_body`` (ngram mode) under ``shard_map`` (weight shards
    per device; paged pools, history, PRNG key, controller EMA, and
    scheduler carry replicated — every device draws identical samples)."""

    def f(p, c, tk, ps_, no, dn, hs, wc, em, ri, mn, st, ky, t, ex):
        (c, _, tk, ps_, no, dn, hs, wc, em, emits, ms) = _spec_chunk_body(
            p, cfg, c, None, (), tk, ps_, no, dn, hs, wc, em, ri, mn, st,
            ky, t, ex, draft_cfg=None, chunk=chunk, page_size=page_size,
            k=k, mode="ngram", ngram_n=ngram_n, pad_id=pad_id, greedy=greedy,
            top_k=top_k, adaptive=adaptive, ctrl_alpha=ctrl_alpha,
            accept=accept, typical_eps=typical_eps,
            typical_delta=typical_delta)
        return c, tk, ps_, no, dn, hs, wc, em, emits, ms

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 14,
        out_specs=P(), check_rep=False,
    )(params, cache, tok, pos, n_out, done, hist, wctr, ema, rids, max_new,
      stops, key, temperature, extras)


def _spec_tree_chunk_body(params, cfg: ModelConfig, cache, tok, pos, n_out,
                          done, hist, wctr, rids, max_new, stops, key,
                          temperature, extras, *, chunk: int, page_size: int,
                          fan: int, depth: int, ngram_n: int, pad_id: int,
                          greedy: bool, top_k: int):
    """Tree-draft decode chunk on the paged cache: each iteration verifies
    a ``1 + fan*depth``-node window per slot (shared-prefix tree mask,
    ``models.verify_step(tree=...)``), accepts the best chain
    (``greedy_tree_accept`` / ``sampling.tree_reject_sample``), relocates
    the accepted chain's rows from their tree columns into the linear
    layout through the block tables (``models.tree_relocate``), and
    commits the deepest kept SSM node.  The engine over-provisions
    ``fan*depth`` positions past the request frontier so relocation's
    gathers always hit provisioned pages (a trash-page gather would
    corrupt committed positions, not just degrade proposals).  Emission,
    stop truncation, history, and key discipline are identical to the
    linear ``_spec_chunk_body``; ``depth`` here is the ROUND's depth
    (the degradation ladder may halve it)."""
    b = tok.shape[0]
    rows = jnp.arange(b)[:, None]

    def body(carry, _):
        tok, cache, pos, n_out, done, hist, wctr = carry
        live = ~done
        wkeys = (None if greedy
                 else draw_keys(key, rids, wctr, TAG_WINDOW))
        chains = propose_ngram_tree(hist, pos + 1, fan, depth, ngram_n)
        window = jnp.concatenate([tok, chains.reshape(b, fan * depth)],
                                 axis=1)
        lg, vc = verify_step(params, cfg, window, cache, pos, extras,
                             page_size=page_size, tree=(fan, depth))
        if greedy:
            g, a, cf = greedy_tree_accept(chains, lg)
        else:
            p = jax.nn.softmax(warp_logits(lg, temperature, top_k), axis=-1)
            g, a, cf = tree_reject_sample(wkeys, chains, p)
        m = jnp.minimum(a + 1, max_new - n_out)
        hit = jnp.any(g[:, :, None] == stops[:, None, :], axis=-1)
        hitm = hit & (jnp.arange(depth + 1)[None, :] < m[:, None])
        any_hit = jnp.any(hitm, axis=1)
        first = jnp.argmax(hitm.astype(jnp.int32), axis=1)
        m = jnp.where(any_hit, first + 1, m)
        m = jnp.where(live, m, 0)
        acc = jnp.maximum(m - 1, 0)
        emit_mask = jnp.arange(depth + 1)[None, :] < m[:, None]
        emit = jnp.where(emit_mask, g, jnp.int32(pad_id))
        histcol = pos[:, None] + 1 + jnp.arange(depth + 1)[None, :]
        hist = hist.at[rows, jnp.where(emit_mask, histcol, hist.shape[1])
                       ].set(g, mode="drop")
        tok = jnp.where((m > 0)[:, None],
                        jnp.take_along_axis(g, acc[:, None], axis=1),
                        tok)
        vc = tree_relocate(cfg, vc, pos, acc, cf, fan=fan, depth=depth,
                           page_size=page_size)
        sel = jnp.where(acc > 0, cf * depth + acc, 0)
        pos = pos + m
        n_out = n_out + m
        done = done | (live & any_hit) | (n_out >= max_new)
        cache = commit_verify(cfg, vc, sel)
        return ((tok, cache, pos, n_out, done, hist,
                 wctr + live.astype(jnp.int32)), (emit, m))

    carry, (emits, ms) = jax.lax.scan(
        body, (tok, cache, pos, n_out, done, hist, wctr), None, length=chunk)
    tok, cache, pos, n_out, done, hist, wctr = carry
    return cache, tok, pos, n_out, done, hist, wctr, emits, ms


_spec_tree_chunk = functools.partial(
    jax.jit,
    static_argnames=("cfg", "chunk", "page_size", "fan", "depth", "ngram_n",
                     "pad_id", "greedy", "top_k"),
    donate_argnames=("cache",),
)(_spec_tree_chunk_body)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "chunk", "page_size", "fan", "depth",
                     "ngram_n", "pad_id", "greedy", "top_k"),
    donate_argnames=("cache",),
)
def _spec_tree_chunk_sharded(params, cfg: ModelConfig, cache, tok, pos,
                             n_out, done, hist, wctr, rids, max_new, stops,
                             key, temperature, extras, *, mesh, chunk: int,
                             page_size: int, fan: int, depth: int,
                             ngram_n: int, pad_id: int, greedy: bool,
                             top_k: int):
    """``_spec_tree_chunk_body`` under ``shard_map``."""

    def f(p, c, tk, ps_, no, dn, hs, wc, ri, mn, st, ky, t, ex):
        return _spec_tree_chunk_body(
            p, cfg, c, tk, ps_, no, dn, hs, wc, ri, mn, st, ky, t, ex,
            chunk=chunk, page_size=page_size, fan=fan, depth=depth,
            ngram_n=ngram_n, pad_id=pad_id, greedy=greedy, top_k=top_k)

    return shard_map(
        f, mesh=mesh,
        in_specs=(tree_pspecs(params),) + (P(),) * 13,
        out_specs=P(), check_rep=False,
    )(params, cache, tok, pos, n_out, done, hist, wctr, rids, max_new, stops,
      key, temperature, extras)
