"""Deterministic fault injection for the serving tier.

A ``FaultInjector`` wraps the ``ContinuousBatchingEngine`` scheduling
boundaries — chunk execution, request admission, page top-up — and injects
seeded failures so every failure mode the resilience layer handles
(``serving.resilience``) is REPRODUCIBLE: the same ``ChaosConfig.seed``
produces the same fault trace on the same request schedule, which is what
lets tests and benches assert exact token parity under chaos
(tests/test_chaos.py) instead of eyeballing flaky runs.

Failure modes, each drawn from its own counter-based PRNG stream (so e.g.
the chunk-fault schedule does not shift when admission consumes more or
fewer draws):

* **chunk-step faults** (``fault_rate``) — a transient ``ChunkFault``
  raised at the chunk boundary BEFORE the compiled step runs (the step's
  donated cache buffers are untouched, so the engine's retry-with-backoff
  simply re-invokes it).  Models a failed collective, a poisoned dispatch,
  a device OOM that clears on retry.
* **engine crashes** (``crash_rate``) — an ``EngineCrash`` raised at the
  round boundary.  ``serve_detailed`` lets it propagate after stashing its
  latest snapshot; ``resilience.ServingSupervisor`` restarts the engine
  and replays in-flight requests token-identically.
* **stragglers** (``straggle_rate`` / ``straggle_s``) — artificial chunk
  latency, surfaced to the engine as virtual-clock skew (no real sleeps:
  deadline/SLO behavior under stragglers stays deterministic and tests
  stay fast).
* **page-pool pressure** (``squeeze_rate`` / ``squeeze_frac``) — a
  fraction of the free list is withheld for one scheduling round, forcing
  the engine down its recompute-preemption path exactly as a real
  burst of long prompts would.
* **request corruption** (``corrupt_rate``) — a request's prompt payload
  is corrupted at admission (an out-of-range token id); the engine's
  admission validation must reject the request instead of serving garbage
  or wedging the compiled program.

Every injection is recorded in ``FaultInjector.log`` as an
``InjectedFault`` — the seeded chaos trace benches store next to their
goodput numbers (benchmarks/serving_bench.py ``--fault-rate``).

The ``*_rounds`` / ``corrupt_rids`` script fields override the
probabilistic draws with exact schedules ("crash at round 2, fault at
round 5") for surgical tests.  Scripted schedules count each site's CALLS
globally across supervisor restarts (a crashed-and-restored engine does
not re-fire the same scripted crash at its restarted round 0), while the
engine's local round number is recorded in the log for readability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


class ChunkFault(RuntimeError):
    """Transient failure of one decode chunk: retryable — the compiled
    step never ran, so the engine's host state and cache are intact."""


class EngineCrash(RuntimeError):
    """The engine process is gone.  ``serve_detailed`` re-raises it after
    stashing ``engine.last_snapshot``; only the ``ServingSupervisor``
    recovers from it (restore + replay)."""


class VirtualClock:
    """A monotonic clock advanced explicitly — deadlines, heartbeat
    timeouts, and injected straggler latency become deterministic instead
    of wall-clock flaky.  Callable like ``time.monotonic``."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("time only moves forward")
        self._now += float(dt)
        return self._now


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault-injection rates; all default to 0 (no chaos).

    Rates are per-opportunity probabilities: ``fault_rate``/``crash_rate``/
    ``straggle_rate``/``squeeze_rate`` per scheduling round, ``corrupt_rate``
    per admitted request.  The ``*_rounds``/``corrupt_rids`` fields script
    exact injection points on top of (or instead of) the random draws."""

    seed: int = 0
    fault_rate: float = 0.0
    crash_rate: float = 0.0
    straggle_rate: float = 0.0
    straggle_s: float = 0.05
    squeeze_rate: float = 0.0
    squeeze_frac: float = 0.5
    corrupt_rate: float = 0.0
    max_faults: Optional[int] = None  # cap TOTAL injections (None = unbounded)
    # Scripted schedules (exact, in addition to the random draws).  Each
    # matches the site's GLOBAL call counter — calls accumulate across
    # supervisor restarts, so "crash at call 2" fires exactly once even
    # though the restored engine restarts its local round numbering:
    fault_rounds: Sequence[int] = ()
    crash_rounds: Sequence[int] = ()
    straggle_rounds: Sequence[int] = ()
    squeeze_rounds: Sequence[int] = ()
    corrupt_rids: Sequence[int] = ()  # matches request ids, not calls


@dataclasses.dataclass
class InjectedFault:
    site: str    # "chunk" | "crash" | "straggle" | "squeeze" | "corrupt"
    round: int
    detail: str = ""


class FaultInjector:
    """Draws each site's injections from an independent counter-based
    stream (``SeedSequence([seed, site_id])``), so one site's consumption
    never shifts another's schedule — the property that makes a chaos
    trace comparable across engine configurations."""

    _SITES = ("chunk", "crash", "straggle", "squeeze", "corrupt")

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._rng = {
            site: np.random.default_rng(np.random.SeedSequence([cfg.seed, i]))
            for i, site in enumerate(self._SITES)
        }
        self._calls = {site: 0 for site in self._SITES}
        self.log: list[InjectedFault] = []

    # ------------------------------------------------------------- helpers --
    def _budget_left(self) -> bool:
        return (self.cfg.max_faults is None
                or len(self.log) < self.cfg.max_faults)

    def _fire(self, site: str, rate: float, script: Sequence[int],
              match=None) -> bool:
        call = self._calls[site]
        self._calls[site] += 1
        hit = self._rng[site].random() < rate  # always draw: stable streams
        scripted = (call if match is None else match) in script
        return scripted or (hit and self._budget_left())

    def reset_log(self) -> None:
        """Forget recorded injections (NOT the PRNG streams): a supervisor
        restart keeps consuming each stream where the crashed run left
        off, so a crash_rate draw never re-fires deterministically at the
        same post-restore round forever."""
        self.log = []

    @property
    def counts(self) -> dict:
        out: dict[str, int] = {}
        for f in self.log:
            out[f.site] = out.get(f.site, 0) + 1
        return out

    # ------------------------------------------------------ injection sites --
    def chunk_fault(self, rnd: int) -> None:
        """Raise ``ChunkFault`` for this chunk attempt, or return."""
        if self._fire("chunk", self.cfg.fault_rate, self.cfg.fault_rounds):
            self.log.append(InjectedFault("chunk", rnd))
            raise ChunkFault(f"injected chunk fault at round {rnd}")

    def crash(self, rnd: int) -> None:
        """Raise ``EngineCrash`` at this round boundary, or return."""
        if self._fire("crash", self.cfg.crash_rate, self.cfg.crash_rounds):
            self.log.append(InjectedFault("crash", rnd))
            raise EngineCrash(f"injected engine crash at round {rnd}")

    def chunk_latency(self, rnd: int) -> float:
        """Injected straggler latency (seconds of clock skew) for this
        round; 0.0 when the straggler gremlin sleeps."""
        if self._fire("straggle", self.cfg.straggle_rate,
                      self.cfg.straggle_rounds):
            self.log.append(InjectedFault(
                "straggle", rnd, f"+{self.cfg.straggle_s}s"))
            return float(self.cfg.straggle_s)
        return 0.0

    def squeeze_pages(self, n_free: int, rnd: int) -> int:
        """How many free pages to withhold from the allocator this round
        (returned to the pool at the end of the round)."""
        if n_free and self._fire("squeeze", self.cfg.squeeze_rate,
                                 self.cfg.squeeze_rounds):
            n = max(1, int(n_free * self.cfg.squeeze_frac))
            self.log.append(InjectedFault("squeeze", rnd, f"{n} pages"))
            return n
        return 0

    def corrupt_request(self, prompt: np.ndarray, ridx: int,
                        rnd: int) -> np.ndarray:
        """Return the (possibly corrupted) prompt payload for admission:
        corruption writes an out-of-range token id into one position —
        the engine's admission validation must catch it."""
        if self._fire("corrupt", self.cfg.corrupt_rate,
                      self.cfg.corrupt_rids, match=ridx):
            bad = np.array(prompt, np.int64, copy=True)
            pos = int(self._rng["corrupt"].integers(0, len(bad)))
            bad[pos] = np.iinfo(np.int32).max // 2  # far past any vocab
            self.log.append(InjectedFault(
                "corrupt", rnd, f"request {ridx} token {pos}"))
            return bad
        return prompt

