"""Serving-tier resilience: SLO policy, degradation ladder, crash-replay
snapshots, and the restart supervisor.

This module is the host-side half of fault-tolerant serving; the engine
half lives in ``ContinuousBatchingEngine.serve_detailed`` (serving.engine),
which consults a ``ResiliencePolicy`` at every scheduling boundary and
emits a ``ServeReport``.  Nothing here touches compiled code: resilience
is pure scheduling, which is what makes recovery provable — the compiled
decode path stays bit-deterministic, and the fold_in draw-key discipline
(serving.sampling) makes a replayed request's token stream identical to
the undisturbed run.

Failure semantics (what is retried / shed / degraded / replayed):

* **Retried** — transient chunk faults (``chaos.ChunkFault`` or anything
  the injector raises before the compiled step runs): the engine backs
  off (clock skew, no real sleep under a virtual clock) and re-invokes
  the SAME chunk up to ``max_retries`` times; past that the round is
  treated as an engine crash.  Retries never touch emitted tokens: the
  failed attempt never ran.
* **Shed** — load the engine refuses: queued requests whose deadline has
  already passed (``shed_expired``), queue overflow beyond ``max_queue``
  (lowest SLO class first, youngest arrival breaking ties), requests that
  can never fit the page pool ("oom"), and — at the top ladder rung —
  queued requests below ``protect_slo``.  Shed requests get status
  ``"shed"`` and whatever tokens they had already emitted; running
  requests are never shed mid-flight (their pages recycle naturally at
  retire).
* **Rejected** — invalid payloads (corrupted token ids, empty prompts,
  budgets that exceed ``max_seq``): admission validation refuses them
  with status ``"rejected"`` instead of feeding garbage to the compiled
  program.  Without a policy the engine raises, exactly as before.
* **Degraded** — the ladder (below) trades throughput machinery for
  stability one rung at a time; under greedy decode every rung is
  token-preserving (greedy speculation at any ``k`` — including off —
  emits identical tokens), so degradation never changes what a greedy
  request sees, only how fast it sees it.
* **Replayed** — after a crash, the supervisor restores the last
  ``ServeSnapshot`` (in-flight = prompt + emitted tokens + draw counters)
  and the engine re-admits each in-flight request by prefilling
  ``prompt + emitted[:-1]`` straight into fresh pages, resuming the token
  draw counter at ``len(emitted)`` — the (rid, counter) fold_in keys then
  continue the SAME random stream, so replayed requests finish
  token-identically to a run that never crashed
  (tests/test_chaos.py::test_crash_replay*).

Degradation ladder (rung 0 = healthy), driven by the engine's own
signals — retries this round, free-page fraction, deadline sheds,
injected/measured stragglers:

  0. full service (configured speculation window, full chunk)
  1. shrink the speculative verify window ``k`` to ``k // 2``
     (speculation overhead is the first thing to go; greedy tokens are
     invariant to ``k``)
  2. disable speculation entirely (one token per weight stream, but no
     verify-window overdraw on the page pool)
  3. halve the decode chunk (host regains control 2x as often: faster
     retire/admit turnaround and smaller retry units)
  4. shed queued requests below ``protect_slo``

Each bad round escalates one rung; ``cooldown`` consecutive clean rounds
de-escalate one.  The trace of transitions lands in
``ServeReport.ladder_trace``.

``ServingSupervisor`` reuses the training-tier ``runtime.fault``
machinery for liveness: the engine heartbeats host 0 of a
``HeartbeatMonitor`` every scheduling round; a crash (or a hang, under a
virtual clock) is detected by ``sweep()``, logged as a ``FailureEvent``,
and recovered by ``revive`` + snapshot restore — the serving analogue of
``TrainingSupervisor.run``'s restore-replan-continue loop.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Optional, Sequence

import numpy as np

from repro.runtime.fault import FailureEvent, HeartbeatMonitor
from repro.serving.chaos import EngineCrash, FaultInjector, VirtualClock


# ------------------------------------------------------------------ policy --
@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """Degradation-ladder tuning; see the module docstring for the rungs."""

    enabled: bool = True
    cooldown: int = 3           # clean rounds before de-escalating one rung
    free_frac: float = 0.125    # free-page fraction that counts as pressure
    protect_slo: int = 1        # rung 4 sheds queued requests below this


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Request-level robustness knobs for ``serve_detailed``.  The default
    instance is safe everywhere: validation on, modest retry budget,
    ladder on, unbounded queue, snapshot every round."""

    max_retries: int = 3          # per-chunk transient-fault retries
    backoff_s: float = 0.01       # base backoff (doubles per attempt)
    max_queue: Optional[int] = None  # bounded admission queue (None = off)
    shed_expired: bool = True     # shed queued requests past their deadline
    validate: bool = True         # admission payload validation
    ladder: LadderConfig = LadderConfig()
    snapshot_every: int = 1       # rounds between snapshots (0 = off)
    snapshot_sink: Optional[Callable] = None  # called with each ServeSnapshot
    resume_mode: str = "prefill"  # "prefill" (replay by re-prefill) or
    #                               "recompute" (requeue from scratch)
    round_time: float = 0.0       # virtual seconds per round (deterministic
    #                               deadline tests under a VirtualClock)

    def __post_init__(self):
        if self.resume_mode not in ("prefill", "recompute"):
            raise ValueError(f"resume_mode {self.resume_mode!r}")


# ------------------------------------------------------------------ report --
@dataclasses.dataclass
class RequestRecord:
    """Outcome of one request: ``status`` is ``"done"`` (full budget or
    stop token), ``"shed"`` (load-shedding; ``tokens`` holds whatever was
    emitted before the shed), or ``"rejected"`` (admission validation).
    Times are engine-clock seconds from serve start (straggler skew
    included); ``met_deadline`` is None when the request had none.

    ``slot`` is the batch slot the request last occupied; ``events`` is
    its span-event stream — dicts of ``{"name", "ts", ...}`` (``"dur"``
    for spans with extent, plus per-event args: ``cached_tokens``/
    ``prefilled_tokens``/``cow`` on admit, ``tokens``/``round`` on
    decode, ``reason`` on shed).  Event names: ``admit``, ``decode``
    (one per scheduling round the request was live in), ``preempt``,
    ``shed``, ``finish``.  ``tools/trace_export.py`` renders these as
    chrome-tracing/Perfetto tracks; under a VirtualClock the stream is
    deterministic."""

    status: str = "pending"
    reason: str = ""
    tokens: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    met_deadline: Optional[bool] = None
    slot: Optional[int] = None
    events: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeReport:
    """Everything ``serve_detailed`` observed: per-request outcomes plus
    the resilience counters the benches aggregate into goodput/SLO
    attainment (benchmarks/serving_bench.py ``--fault-rate``)."""

    records: list = dataclasses.field(default_factory=list)
    rounds: int = 0
    retries: int = 0
    straggle_s: float = 0.0
    squeezed_pages: int = 0
    sheds: int = 0
    rejects: int = 0
    restarts: int = 0           # filled by the supervisor
    failures: list = dataclasses.field(default_factory=list)
    ladder_trace: list = dataclasses.field(default_factory=list)
    # (round, rung, reason) transitions
    max_ladder_level: int = 0
    # One sample per scheduling round that dispatched a chunk: free /
    # retained page counts, cumulative prefix-hit tokens, effective k,
    # queue depth — the counter tracks of tools/trace_export.py.
    counters: list = dataclasses.field(default_factory=list)
    prefix_hits: int = 0        # admits served (partly) from the prefix trie
    prefix_hit_tokens: int = 0  # prompt tokens aliased instead of prefilled
    prefill_tokens: int = 0     # prompt tokens actually computed
    cow_forks: int = 0          # copy-on-write page forks
    evictions: int = 0          # retained cache pages evicted under pressure

    @property
    def outputs(self) -> list[np.ndarray]:
        return [r.tokens for r in self.records]

    def done(self) -> list[int]:
        return [i for i, r in enumerate(self.records) if r.status == "done"]

    def latencies(self) -> list[float]:
        """Completion latency (serve-start to last token) per done request.

        Granularity: completion times are interpolated WITHIN a scheduling
        round to the chunk iteration the request's slot last emitted in
        (the engine only observes device results at round boundaries), so
        the residual quantization is one chunk iteration — ``round_time /
        eff_chunk`` under a VirtualClock-driven policy — rather than the
        whole round.  Two requests finishing in the same iteration of the
        same round still share a timestamp."""
        return [r.t_done for r in self.records
                if r.status == "done" and r.t_done is not None]

    def slo_attainment(self) -> float:
        """Fraction of requests that finished AND met their deadline;
        requests without deadlines count as met.  Shed/rejected = missed."""
        if not self.records:
            return 1.0
        met = sum(1 for r in self.records
                  if r.status == "done" and r.met_deadline in (True, None))
        return met / len(self.records)

    def goodput_tokens(self) -> int:
        """Tokens of requests that completed within their deadline — the
        numerator of goodput (useful work per second under SLO)."""
        return sum(len(r.tokens) for r in self.records
                   if r.status == "done" and r.met_deadline in (True, None))


# ---------------------------------------------------------------- snapshot --
@dataclasses.dataclass
class InflightState:
    """Replay state for one in-flight request: everything needed to
    re-admit it token-identically — its emissions so far (the prompt lives
    in the request list) and its verify-window draw counter.  The token
    draw counter IS ``len(emitted)`` (draw n samples the n-th emission;
    see serving.sampling).  ``acc_ema`` is the adaptive controller's
    learned per-request acceptance estimate (speculative.SpecConfig
    ``adaptive``) so a crash replay resumes the controller where it left
    off instead of re-paying the warm-up; the default keeps snapshots
    taken before this field existed loadable."""

    emitted: list
    wctr: int = 0
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    acc_ema: float = 0.5


@dataclasses.dataclass
class ServeSnapshot:
    """Lightweight engine snapshot taken at scheduling-round boundaries:
    host-side request state only — no device buffers, no KV pages (those
    are recomputed by the resume prefill).  JSON-serializable so a
    supervisor can persist it across real process death
    (``save_snapshot``/``load_snapshot``)."""

    finished: dict      # ridx -> [tokens] of completed requests
    inflight: dict      # ridx -> InflightState, admit order preserved
    queued: list        # ridx, FIFO order
    closed: dict        # ridx -> (status, reason) for shed/rejected
    round: int = 0

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["finished"] = {str(k): [int(t) for t in v]
                         for k, v in d["finished"].items()}
        d["inflight"] = {str(k): v for k, v in d["inflight"].items()}
        d["closed"] = {str(k): list(v) for k, v in d["closed"].items()}
        return json.dumps(d)

    @classmethod
    def from_json(cls, s: str) -> "ServeSnapshot":
        d = json.loads(s)
        return cls(
            finished={int(k): [int(t) for t in v]
                      for k, v in d["finished"].items()},
            inflight={int(k): InflightState(**v)
                      for k, v in d["inflight"].items()},
            queued=[int(r) for r in d["queued"]],
            closed={int(k): tuple(v) for k, v in d["closed"].items()},
            round=int(d["round"]),
        )


def save_snapshot(path: str, snap: ServeSnapshot) -> None:
    """Atomically persist a snapshot (tmp + fsync + ``os.replace`` — the
    same publish discipline as ``checkpoint.CheckpointManager.save``), so
    a crash mid-write never corrupts the recovery point."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(snap.to_json())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_snapshot(path: str) -> Optional[ServeSnapshot]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return ServeSnapshot.from_json(f.read())


# ------------------------------------------------------------------ ladder --
class DegradationLadder:
    """Escalate on bad rounds, de-escalate after ``cooldown`` clean ones.
    ``params(chunk, k)`` maps the current rung onto effective scheduling
    parameters; rungs that don't apply (no speculation configured) are
    skipped so rung semantics stay stable."""

    def __init__(self, cfg: LadderConfig, *, has_spec: bool):
        self.cfg = cfg
        self.level = 0
        self._clean = 0
        # The actions available to this engine, in escalation order.
        self.actions = ((["halve_k", "no_spec"] if has_spec else [])
                        + ["halve_chunk", "shed_low_slo"])
        self.trace: list = []

    @property
    def max_level(self) -> int:
        return len(self.actions)

    def active(self) -> list:
        return self.actions[: self.level]

    def update(self, rnd: int, bad: bool, reason: str = "") -> None:
        if not self.cfg.enabled:
            return
        if bad:
            self._clean = 0
            if self.level < self.max_level:
                self.level += 1
                self.trace.append((rnd, self.level, reason))
        else:
            self._clean += 1
            if self.level > 0 and self._clean >= self.cfg.cooldown:
                self.level -= 1
                self._clean = 0
                self.trace.append((rnd, self.level, "recovered"))

    def params(self, chunk: int, k: Optional[int]):
        """(effective_chunk, effective_k) — ``None`` k disables
        speculation for the round."""
        active = self.active()
        if k is not None:
            if "no_spec" in active:
                k = None
            elif "halve_k" in active:
                k = max(1, k // 2)
        if "halve_chunk" in active:
            chunk = max(1, chunk // 2)
        return chunk, k

    def shedding(self) -> bool:
        return "shed_low_slo" in self.active()


# -------------------------------------------------------------- supervisor --
class ServingSupervisor:
    """Restart loop for a crashing ``ContinuousBatchingEngine``: run
    ``serve_detailed``; on ``EngineCrash``, detect the death through the
    ``runtime.fault.HeartbeatMonitor`` (the engine heartbeats every
    scheduling round; the supervisor advances the shared clock past the
    timeout, exactly how a missed-heartbeat death manifests), record the
    ``FailureEvent``, ``revive`` the host, restore the engine's last
    snapshot, and replay.  Token streams of replayed requests are
    identical to an undisturbed run (see module docstring).

    ``snapshot_path`` additionally persists every snapshot to disk
    (atomic write), and ``run`` starts from it when present — recovery
    works even when the crash takes the ENGINE OBJECT with it (a fresh
    engine + the file resumes the trace; tests/test_chaos.py exercises
    this with a new engine instance)."""

    def __init__(self, engine, *, policy: Optional[ResiliencePolicy] = None,
                 chaos: Optional[FaultInjector] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 clock: Optional[VirtualClock] = None,
                 max_restarts: int = 8,
                 snapshot_path: Optional[str] = None):
        self.engine = engine
        self.policy = policy or ResiliencePolicy()
        self.chaos = chaos
        self.clock = clock or VirtualClock()
        self.monitor = monitor or HeartbeatMonitor(
            1, timeout_s=30.0, clock=self.clock)
        self.max_restarts = max_restarts
        self.snapshot_path = snapshot_path
        self.restarts = 0
        self.failures: list[FailureEvent] = []

    def _beat(self) -> None:
        self.monitor.beat(0)

    def run(self, requests, **serve_kw) -> ServeReport:
        policy = self.policy
        if self.snapshot_path is not None and policy.snapshot_sink is None:
            policy = dataclasses.replace(
                policy, snapshot_sink=lambda s: save_snapshot(
                    self.snapshot_path, s))
        snap = (load_snapshot(self.snapshot_path)
                if self.snapshot_path is not None else None)
        while True:
            try:
                report = self.engine.serve_detailed(
                    requests, policy=policy, chaos=self.chaos, resume=snap,
                    heartbeat=self._beat, **serve_kw)
                report.restarts = self.restarts
                report.failures = list(self.failures)
                return report
            except EngineCrash as e:
                self.restarts += 1
                # The engine stopped beating: advance the shared clock past
                # the heartbeat timeout so the monitor's sweep genuinely
                # detects the death (not just the exception we caught).
                self.clock.advance(self.monitor.timeout_s + 1.0)
                dead = self.monitor.sweep()
                assert 0 in dead or 0 in self.monitor.dead
                self.failures.append(FailureEvent(
                    0, getattr(self.engine, "last_round", -1), f"crash:{e}"))
                if self.restarts > self.max_restarts:
                    raise
                self.monitor.revive(0)
                snap = self.engine.last_snapshot
                if self.snapshot_path is not None:
                    disk = load_snapshot(self.snapshot_path)
                    if disk is not None:
                        snap = disk
