"""Tensor-sharded decode: distribute the quantized PIM weight tree over a
1-D ``"model"`` mesh and serve from every engine path.

PiCaSO's *Scalable* claim is that PIM throughput grows by replicating
processing elements next to the memory blocks that hold the weights.  The
decode-time analogue: decode is memory-bound on the weight stream, so
partitioning the ``quantize_tree`` output over N devices cuts the per-device
weight bytes per token N-fold, and only the tiny per-token activations cross
the interconnect — the "spread the array, keep compute next to its shard"
argument of the UPMEM study (arXiv:2105.03814).

Layout (one rule, every leaf):

* a quantized leaf dict (``codes``/``scale`` + optional int4 markers) whose
  train-time rule shards it somewhere (``quant.decode_partition_spec``,
  derived from ``launch.sharding.param_spec``) is split over ``TP_AXIS``
  along its OUTPUT (last) dim — codes and scale together, markers (leading
  stack dims only) replicated — and tagged with a ``"tp"`` marker leaf;
* everything else (embeddings, norms, biases, caches, block tables, token
  state) is replicated.

Inside ``shard_map`` the marker drives the collectives:

* ``models.common.linear`` contracts the local shard weight-stationary
  (the ``set_matvec_dispatch`` kernel path applies per-shard) and
  all-gathers the output columns — a pure concatenation, so sharded greedy
  decode is TOKEN-IDENTICAL to the single-device engines;
* einsum consumers (MoE expert stacks, MLA absorbed W_uk/W_uv) go through
  ``models.common.dq``, which all-gathers the dequantized shard instead —
  per-device HBM still streams 1/N of the bytes, exactness preserved.

A rule-shardable leaf whose output dim does not divide the mesh quietly
stays replicated, mirroring ``launch.sharding.sanitize`` (none of the
stock reduced configs hits this — their rule-sharded leaves all have
8-divisible outputs, and e.g. mamba1's N=12 ``x_proj`` is already
replicated by the rule itself — but externally-loaded trees can).

The engines (``serving.engine``) accept ``mesh=``: admit-prefill and the
chunked decode scan lower ONCE under ``shard_map`` with these specs; the
host-side scheduler (admit / retire / preemption / page accounting) never
sees a device count.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import TP_AXIS
from repro.quant import decode_partition_spec


def make_decode_mesh(n_devices: Optional[int] = None,
                     axis: str = TP_AXIS) -> Mesh:
    """A 1-D tensor-parallel mesh over the first ``n_devices`` devices
    (default: all).  CPU tests force virtual devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n > len(devs):
        raise ValueError(f"asked for {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]), (axis,))


def _is_qleaf(tree) -> bool:
    return isinstance(tree, dict) and "codes" in tree


def _last_dim_spec(ndim: int, axis: str) -> P:
    return P(*((None,) * (ndim - 1) + (axis,)))


def shard_quantized_tree(params, mesh: Mesh, axis: str = TP_AXIS):
    """Distribute a (possibly ``quantize_tree``-converted) parameter tree
    over ``mesh``'s ``axis``.

    Shardable quantized leaves (``quant.decode_partition_spec``) whose
    output dim divides the axis get codes+scale split along their last dim
    and a ``"tp"`` marker leaf added; every other leaf is replicated.  All
    leaves are ``device_put`` with their ``NamedSharding``, so per-device
    HBM holds only its shard and ``pim_bytes(..., per_device=True)``
    reports the split.

    Raises if a multi-device mesh ends up distributing NOTHING (e.g. a
    dense tree passed without ``pim_bits``): silently replicating every
    weight N times while paying shard_map overhead is never what a caller
    asking for tensor-sharded decode meant."""
    size = mesh.shape[axis]
    n_marked = 0

    def put(leaf, spec: P):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    def conv(tree, names):
        nonlocal n_marked
        if tree is None:
            return None
        if _is_qleaf(tree):
            nd = tree["codes"].ndim
            spec = decode_partition_spec(names, nd, axis)
            n_out = tree["codes"].shape[-1]
            tp = (axis in spec and n_out % size == 0 and n_out >= size)
            n_marked += tp
            out = {}
            for k, v in tree.items():
                if tp and k in ("codes", "scale"):
                    out[k] = put(v, _last_dim_spec(v.ndim, axis))
                else:
                    out[k] = put(v, P())
            if tp:
                # Like the int4 "nibbles" markers, the tag carries the
                # leading stack dims so lax.scan over stacked layers can
                # slice it alongside codes/scale.
                out["tp"] = put(jnp.zeros(tree["codes"].shape[:-2], jnp.int8),
                                P())
            return out
        if isinstance(tree, dict):
            return {k: conv(v, names + [k]) for k, v in tree.items()}
        return put(tree, P())

    out = conv(params, [])
    if size > 1 and n_marked == 0:
        raise ValueError(
            f"nothing to distribute over the {size}-device '{axis}' mesh: "
            "the tree has no shardable quantized leaves (pass pim_bits=4/8 "
            "to the engine, or quantize_tree the params first)")
    return out


def tree_pspecs(params, axis: str = TP_AXIS):
    """The ``shard_map`` in_specs tree for a (marker-annotated) parameter
    tree: ``"tp"``-marked codes/scale carry ``axis`` on their last dim,
    everything else is replicated.  Derived from the markers themselves so
    the specs can never disagree with what ``linear``/``dq`` will gather."""

    def conv(tree):
        if tree is None:
            return None
        if _is_qleaf(tree):
            tp = "tp" in tree
            return {
                k: (_last_dim_spec(v.ndim, axis)
                    if tp and k in ("codes", "scale") else P())
                for k, v in tree.items()
            }
        if isinstance(tree, dict):
            return {k: conv(v) for k, v in tree.items()}
        return P()

    return conv(params)
