"""Sampled-decoding primitives: counter-derived per-row PRNG keys, the
temperature/top-k warp, and rejection-sampling speculative verification.

Key discipline (the exactness half of the sampled-speculation contract):
every sampled draw is keyed by ``(base key, stream tag, request id, draw
counter)`` via ``fold_in`` — never by splitting one global key through the
decode loop.  The request id is the row index on the fixed-batch engine
and the trace index on the continuous engine, so a request's random
stream depends only on that identity and its own progress — not on slot
assignment, chunk boundaries, page allocation, device count, or which
engine runs it.  The fixed-batch dense engine, the paged
continuous-batching scheduler, and their ``shard_map`` variants emit
IDENTICAL tokens for the same ``key`` when requests keep the same indices
(tests/test_sampled_speculative.py enforces the matrix), and recompute
preemption replays the same stream deterministically.

Two independent streams per request:

* ``TAG_TOKEN`` — plain autoregressive sampling: draw ``n`` samples the
  row's n-th emitted token (draw 0 is the prefill/admit token);
* ``TAG_WINDOW`` — speculative verify windows: draw ``w`` covers the
  row's w-th window, fanning out inside the window to the draft-proposal
  draws and the accept/resample draws of ``rejection_sample``.

Rejection-sampling verification (speculative sampling, Leviathan et al.
2023 / Chen et al. 2023): proposal ``d_i ~ q_i`` is accepted with
probability ``min(1, p_i(d_i) / q_i(d_i))`` against the target's verify
distribution ``p_i``; the first rejection is resampled from the
normalised residual ``max(p_i - q_i, 0)``; if all ``k`` proposals are
accepted, the bonus token is sampled from ``p_{k+1}``.  The emitted
prefix is then distributed EXACTLY as ancestral sampling from ``p`` —
speculation changes how many weight streams are paid per token, never
the output distribution.  With the deterministic n-gram proposer ``q``
is a point mass, so acceptance degenerates to ``u < p(d)`` and the
residual to ``p`` with the proposal zeroed — still exact.  The
chi-square harness in tests/test_sampled_speculative.py verifies the
distribution-preservation claim per model family.

Snapshot/replay contract (what crash recovery must save): because the
streams above are keyed by nothing but (request id, draw counter), a
request's full sampling state is TWO integers — its token-draw counter,
which IS ``len(emitted)`` (draw ``n`` samples the n-th emission, draw 0
is the admit token), and its window counter ``wctr``.  A
``resilience.ServeSnapshot`` therefore stores only the emitted tokens
and ``wctr`` per in-flight request; after a crash the engine re-admits
from ``prompt + emitted``, resumes the counters at exactly those values,
and every subsequent ``fold_in`` key — token or verify-window — continues
the SAME random stream the dead engine was drawing from.  That is the
whole mechanism behind token-identical crash replay
(tests/test_chaos.py::test_crash_replay_sampled_speculative): no PRNG
state is serialized, counters are reconstructed from data that must be
kept anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TAG_TOKEN = 0   # plain per-token sampling stream
TAG_WINDOW = 1  # speculative verify-window stream


def draw_keys(base, rids: jnp.ndarray, idx, tag: int):
    """Per-row PRNG keys for draw ``idx`` of stream ``tag``:
    ``fold_in(fold_in(fold_in(base, tag), rid), idx)`` per row.  ``rids``
    (B,) int32 request ids; ``idx`` a scalar or (B,) per-row draw
    counters.  Inactive slots may pass any rid — their draws are masked
    by the caller."""
    tbase = jax.random.fold_in(base, tag)
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), rids.shape)

    def one(r, i):
        return jax.random.fold_in(jax.random.fold_in(tbase, r), i)

    return jax.vmap(one)(rids.astype(jnp.int32), idx)


def warp_logits(logits: jnp.ndarray, temperature, top_k: int) -> jnp.ndarray:
    """Temperature/top-k warped logits (f32, last axis = vocab): softmax of
    the result is the distribution plain sampled decode draws from, and
    therefore the distribution rejection-sampling verification must
    preserve — ``p`` and ``q`` are both built from this one warp so the
    accept ratio compares like with like."""
    lg = logits.astype(jnp.float32) / jnp.maximum(
        jnp.asarray(temperature, jnp.float32), 1e-6)
    top_k = min(top_k, lg.shape[-1])  # top_k >= vocab is plain sampling
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return lg


def sample_rows(logits: jnp.ndarray, keys, *, greedy: bool, temperature,
                top_k: int) -> jnp.ndarray:
    """(B, V) logits -> (B,) int32 tokens, one independent key per row
    (``keys`` from ``draw_keys``; ignored when greedy)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = warp_logits(logits, temperature, top_k)
    return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)


# ------------------------------------------------------ rejection sampling --
def acceptance_probs(drafts: jnp.ndarray, q: jnp.ndarray,
                     p: jnp.ndarray) -> jnp.ndarray:
    """The textbook acceptance probability ``min(1, p(d)/q(d))`` per
    proposal, (B, k) in [0, 1].  ``drafts`` (B, k) int32; ``q`` (B, k, V)
    proposal distributions; ``p`` (B, k+1, V) target distributions
    (position k is the bonus position, unused here).  Where ``q(d) == 0``
    (a proposal q could never emit) the ratio is 1 if ``p(d) > 0`` else 0
    — the limit the division-free accept rule ``u * q(d) < p(d)`` of
    ``rejection_sample`` realises."""
    k = drafts.shape[1]
    qd = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
    pd = jnp.take_along_axis(p[:, :k], drafts[..., None], axis=-1)[..., 0]
    return jnp.where(qd > 0.0,
                     jnp.minimum(1.0, pd / jnp.maximum(qd, 1e-38)),
                     (pd > 0.0).astype(jnp.float32))


def residual_dist(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Normalised rejection residual ``max(p - q, 0)`` over the last axis.
    Zero residual mass means ``p <= q`` pointwise, i.e. ``p == q`` for
    distributions — rejection is then impossible (the accept rule fires
    with probability 1), so the ``p`` fallback keeps the helper total
    without ever being reachable from ``rejection_sample``."""
    r = jnp.maximum(p - q, 0.0)
    s = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(s > 0.0, r / jnp.maximum(s, 1e-38), p)


def rejection_sample(keys, drafts: jnp.ndarray, q: jnp.ndarray,
                     p: jnp.ndarray, *, kcap: jnp.ndarray | None = None,
                     n_draws: int | None = None):
    """Per-row rejection-sampling verification of a proposal window.

    ``keys`` (B,) per-row window keys (``draw_keys(..., TAG_WINDOW)``);
    ``drafts`` (B, k) proposed tokens; ``q`` (B, k, V) the distributions
    they were proposed from (a one-hot point mass for deterministic
    proposers); ``p`` (B, k+1, V) the target's warped verify
    distributions.

    ``kcap`` (B,) optionally caps the number of proposals each row may
    accept (the adaptive controller's per-request k): positions at or
    past a row's cap are force-rejected without consuming target mass —
    the row behaves exactly as if only its first ``kcap`` proposals had
    been made, so the emitted prefix stays exactly ``p``-distributed for
    any cap.  ``kcap == 0`` degenerates to a plain sample from ``p[0]``.
    ``n_draws`` (static, >= k) fixes the uniform-draw shape so a row's
    random stream does not depend on the round's window size: adaptive
    rounds pass the configured maximum k while running smaller windows,
    and the ``u[:k]`` prefix of one (n_draws,) draw is the same whatever
    k the round happens to use.

    Returns ``(tokens (B, k+1), a (B,))`` laid out like
    ``speculative.greedy_accept``: ``a`` is the number of accepted
    proposals and the row emits ``tokens[:, :a+1]`` — the accepted
    proposals followed by the residual resample (``a < kcap``) or the
    bonus draw from ``p[:, kcap]`` (``a == kcap``).  Positions past ``a``
    repeat the final draw; they are dead filler matching greedy_accept's
    convention that only ``:a+1`` is ever read.

    Acceptance uses the division-free rule ``u * q(d) < p(d)`` (``u ~
    U[0,1)``), equivalent to ``u < min(1, p(d)/q(d))`` and exact even
    when ``q(d)`` underflows; ``q == p`` therefore accepts everything
    (``u < 1``)."""
    b, k = drafts.shape
    nd = k if n_draws is None else int(n_draws)
    if kcap is None:
        kcap = jnp.full((b,), k, jnp.int32)

    def row(key, d, qr, pr, kc):
        ku, kf = jax.random.split(key)
        u = jax.random.uniform(ku, (nd,))[:k]
        qd = jnp.take_along_axis(qr, d[:, None], axis=1)[:, 0]
        pd = jnp.take_along_axis(pr[:k], d[:, None], axis=1)[:, 0]
        acc = ((u * qd < pd) & (jnp.arange(k) < kc)).astype(jnp.int32)
        a = jnp.sum(jnp.cumprod(acc))
        j = jnp.clip(jnp.minimum(a, kc - 1), 0, k - 1)  # residual position
        dist = jnp.where(a == kc, pr[jnp.minimum(kc, k)],
                         residual_dist(pr[j], qr[j]))
        final = jax.random.categorical(kf, jnp.log(dist)).astype(jnp.int32)
        padded = jnp.concatenate([d, d[-1:]])
        return jnp.where(jnp.arange(k + 1) < a, padded, final), a

    return jax.vmap(row)(keys, drafts, q.astype(jnp.float32),
                         p.astype(jnp.float32), kcap.astype(jnp.int32))


def typical_accept_sample(keys, drafts: jnp.ndarray, p: jnp.ndarray, *,
                          kcap: jnp.ndarray | None = None,
                          eps: float = 0.3, delta: float = 0.09):
    """Typical acceptance (entropy-band accept) — the explicitly LOSSY
    fast mode.  A proposal ``d_i`` is accepted iff ``p_i(d_i) >
    min(eps, delta * exp(-H(p_i)))``: under a peaked target (low entropy)
    the draft must carry real target mass, under a flat target almost any
    plausible draft passes.  No rejection residual is drawn — the token
    after the accepted prefix is sampled straight from ``p[a]`` — so the
    emitted prefix is NOT ``p``-distributed (it is biased toward the
    proposer); callers opt in via ``SpecConfig(accept="typical")``.
    Signature and return layout mirror ``rejection_sample`` (same
    ``kcap`` semantics; acceptance itself is deterministic, one
    categorical draw per row keeps the stream discipline)."""
    b, k = drafts.shape
    if kcap is None:
        kcap = jnp.full((b,), k, jnp.int32)

    def row(key, d, pr, kc):
        _, kf = jax.random.split(key)
        pd = jnp.take_along_axis(pr[:k], d[:, None], axis=1)[:, 0]
        ent = -jnp.sum(jax.scipy.special.xlogy(pr[:k], pr[:k]), axis=-1)
        thr = jnp.minimum(eps, delta * jnp.exp(-ent))
        acc = ((pd > thr) & (jnp.arange(k) < kc)).astype(jnp.int32)
        a = jnp.sum(jnp.cumprod(acc))
        final = jax.random.categorical(kf, jnp.log(pr[a])).astype(jnp.int32)
        padded = jnp.concatenate([d, d[-1:]])
        return jnp.where(jnp.arange(k + 1) < a, padded, final), a

    return jax.vmap(row)(keys, drafts, p.astype(jnp.float32),
                         kcap.astype(jnp.int32))


def tree_reject_sample(keys, chains: jnp.ndarray, p_nodes: jnp.ndarray, *,
                       kcap: jnp.ndarray | None = None):
    """Exact rejection-sampling verification of a fan-of-chains candidate
    tree against point-mass proposals (the multi-candidate n-gram
    drafter).

    ``chains`` (B, F, D): F candidate continuations of depth D; chain f's
    node i sits at node index ``1 + f*D + i`` of the verify window (node
    0 is the shared root = the current token).  ``p_nodes`` (B, 1+F*D, V)
    are the target's warped distributions in node order: ``p_nodes[0]``
    is the next-token distribution at the root, ``p_nodes[1+f*D+i]`` the
    distribution after chain f's prefix through depth i+1.

    Verification is SpecInfer-style sequential elimination at the root:
    chains are tried in order f = 0..F-1; head ``chains[f, 0]`` is
    accepted with probability ``p_cur(head)`` (point-mass proposal), on
    rejection the head's mass is zeroed out of ``p_cur`` and the
    distribution renormalised (duplicate heads auto-reject — their mass
    is already gone).  The first accepted head selects its chain, which
    is then verified by standard single-candidate rejection; a rejection
    resamples from that node's residual, full acceptance draws the bonus
    from the last node's distribution, and F straight head rejections
    sample from the final root residual.  Each outcome is distributed
    EXACTLY as ancestral sampling from ``p`` (multi-draft speculative
    sampling).  ``kcap`` caps accepted depth per row exactly as in
    ``rejection_sample`` (0 = plain sample from the root distribution);
    draw shapes are fixed at (F + D - 1,) uniforms + one categorical, so
    the stream is cap-independent.

    Returns ``(tokens (B, D+1), a (B,), cf (B,))``: the row emits
    ``tokens[:, :a+1]`` and ``cf`` names the accepted chain (0 when
    ``a == 0``) for cache relocation / SSM state commit."""
    b, nf, nd = chains.shape
    if kcap is None:
        kcap = jnp.full((b,), nd, jnp.int32)

    def row(key, ch, pr, kc):
        ku, kf = jax.random.split(key)
        u = jax.random.uniform(ku, (nf + nd - 1,))
        uh, uc = u[:nf], u[nf:]

        def head_step(carry, f):
            p_cur, done, cf = carry
            h = ch[f, 0]
            tried = jnp.logical_and(jnp.logical_not(done), kc >= 1)
            acc = jnp.logical_and(tried, uh[f] < p_cur[h])
            pz = p_cur.at[h].set(0.0)
            s = jnp.sum(pz)
            p_rej = jnp.where(s > 0.0, pz / jnp.maximum(s, 1e-38), p_cur)
            p_cur = jnp.where(jnp.logical_and(tried, jnp.logical_not(acc)),
                              p_rej, p_cur)
            cf = jnp.where(acc, f, cf)
            done = jnp.logical_or(done, acc)
            return (p_cur, done, cf), acc

        (p_res, got_head, cf), _ = jax.lax.scan(
            head_step, (pr[0], jnp.bool_(False), jnp.int32(0)),
            jnp.arange(nf))

        # Chain descent: draft #j (j = 2..D) is ch[cf, j-1], verified
        # against p_nodes[1 + cf*D + j - 2].
        base = 1 + cf * nd
        pdj = jax.vmap(lambda j: pr[base + j - 2][ch[cf, j - 1]])(
            jnp.arange(2, nd + 1)) if nd > 1 else jnp.zeros((0,))
        accj = ((uc < pdj) & (jnp.arange(2, nd + 1) <= kc)).astype(jnp.int32)
        a = jnp.where(got_head, 1 + jnp.sum(jnp.cumprod(accj)), 0)

        cap = jnp.minimum(kc, nd)
        # a == 0: the final root state — pr[0] untouched when kc == 0
        # (heads never tried), the eliminated-heads residual otherwise.
        # a == cap: bonus from the last accepted node's distribution.
        # 0 < a < cap: residual of node a's distribution with the
        # rejected draft ch[cf, a] zeroed (point-mass proposal).
        last = jnp.clip(base + a - 1, 0, pr.shape[0] - 1)
        rej_tok = ch[cf, jnp.clip(a, 0, nd - 1)]
        onehot = jax.nn.one_hot(rej_tok, pr.shape[1], dtype=jnp.float32)
        dist = jnp.where(a == 0, p_res,
                         jnp.where(a == cap, pr[last],
                                   residual_dist(pr[last], onehot)))
        final = jax.random.categorical(kf, jnp.log(dist)).astype(jnp.int32)
        padded = jnp.concatenate([ch[cf], ch[cf, -1:]])
        return (jnp.where(jnp.arange(nd + 1) < a, padded, final), a, cf)

    return jax.vmap(row)(keys, chains, p_nodes.astype(jnp.float32),
                         kcap.astype(jnp.int32))
