"""Sampled-decoding primitives: counter-derived per-row PRNG keys, the
temperature/top-k warp, and rejection-sampling speculative verification.

Key discipline (the exactness half of the sampled-speculation contract):
every sampled draw is keyed by ``(base key, stream tag, request id, draw
counter)`` via ``fold_in`` — never by splitting one global key through the
decode loop.  The request id is the row index on the fixed-batch engine
and the trace index on the continuous engine, so a request's random
stream depends only on that identity and its own progress — not on slot
assignment, chunk boundaries, page allocation, device count, or which
engine runs it.  The fixed-batch dense engine, the paged
continuous-batching scheduler, and their ``shard_map`` variants emit
IDENTICAL tokens for the same ``key`` when requests keep the same indices
(tests/test_sampled_speculative.py enforces the matrix), and recompute
preemption replays the same stream deterministically.

Two independent streams per request:

* ``TAG_TOKEN`` — plain autoregressive sampling: draw ``n`` samples the
  row's n-th emitted token (draw 0 is the prefill/admit token);
* ``TAG_WINDOW`` — speculative verify windows: draw ``w`` covers the
  row's w-th window, fanning out inside the window to the draft-proposal
  draws and the accept/resample draws of ``rejection_sample``.

Rejection-sampling verification (speculative sampling, Leviathan et al.
2023 / Chen et al. 2023): proposal ``d_i ~ q_i`` is accepted with
probability ``min(1, p_i(d_i) / q_i(d_i))`` against the target's verify
distribution ``p_i``; the first rejection is resampled from the
normalised residual ``max(p_i - q_i, 0)``; if all ``k`` proposals are
accepted, the bonus token is sampled from ``p_{k+1}``.  The emitted
prefix is then distributed EXACTLY as ancestral sampling from ``p`` —
speculation changes how many weight streams are paid per token, never
the output distribution.  With the deterministic n-gram proposer ``q``
is a point mass, so acceptance degenerates to ``u < p(d)`` and the
residual to ``p`` with the proposal zeroed — still exact.  The
chi-square harness in tests/test_sampled_speculative.py verifies the
distribution-preservation claim per model family.

Snapshot/replay contract (what crash recovery must save): because the
streams above are keyed by nothing but (request id, draw counter), a
request's full sampling state is TWO integers — its token-draw counter,
which IS ``len(emitted)`` (draw ``n`` samples the n-th emission, draw 0
is the admit token), and its window counter ``wctr``.  A
``resilience.ServeSnapshot`` therefore stores only the emitted tokens
and ``wctr`` per in-flight request; after a crash the engine re-admits
from ``prompt + emitted``, resumes the counters at exactly those values,
and every subsequent ``fold_in`` key — token or verify-window — continues
the SAME random stream the dead engine was drawing from.  That is the
whole mechanism behind token-identical crash replay
(tests/test_chaos.py::test_crash_replay_sampled_speculative): no PRNG
state is serialized, counters are reconstructed from data that must be
kept anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TAG_TOKEN = 0   # plain per-token sampling stream
TAG_WINDOW = 1  # speculative verify-window stream


def draw_keys(base, rids: jnp.ndarray, idx, tag: int):
    """Per-row PRNG keys for draw ``idx`` of stream ``tag``:
    ``fold_in(fold_in(fold_in(base, tag), rid), idx)`` per row.  ``rids``
    (B,) int32 request ids; ``idx`` a scalar or (B,) per-row draw
    counters.  Inactive slots may pass any rid — their draws are masked
    by the caller."""
    tbase = jax.random.fold_in(base, tag)
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), rids.shape)

    def one(r, i):
        return jax.random.fold_in(jax.random.fold_in(tbase, r), i)

    return jax.vmap(one)(rids.astype(jnp.int32), idx)


def warp_logits(logits: jnp.ndarray, temperature, top_k: int) -> jnp.ndarray:
    """Temperature/top-k warped logits (f32, last axis = vocab): softmax of
    the result is the distribution plain sampled decode draws from, and
    therefore the distribution rejection-sampling verification must
    preserve — ``p`` and ``q`` are both built from this one warp so the
    accept ratio compares like with like."""
    lg = logits.astype(jnp.float32) / jnp.maximum(
        jnp.asarray(temperature, jnp.float32), 1e-6)
    top_k = min(top_k, lg.shape[-1])  # top_k >= vocab is plain sampling
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return lg


def sample_rows(logits: jnp.ndarray, keys, *, greedy: bool, temperature,
                top_k: int) -> jnp.ndarray:
    """(B, V) logits -> (B,) int32 tokens, one independent key per row
    (``keys`` from ``draw_keys``; ignored when greedy)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = warp_logits(logits, temperature, top_k)
    return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)


# ------------------------------------------------------ rejection sampling --
def acceptance_probs(drafts: jnp.ndarray, q: jnp.ndarray,
                     p: jnp.ndarray) -> jnp.ndarray:
    """The textbook acceptance probability ``min(1, p(d)/q(d))`` per
    proposal, (B, k) in [0, 1].  ``drafts`` (B, k) int32; ``q`` (B, k, V)
    proposal distributions; ``p`` (B, k+1, V) target distributions
    (position k is the bonus position, unused here).  Where ``q(d) == 0``
    (a proposal q could never emit) the ratio is 1 if ``p(d) > 0`` else 0
    — the limit the division-free accept rule ``u * q(d) < p(d)`` of
    ``rejection_sample`` realises."""
    k = drafts.shape[1]
    qd = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
    pd = jnp.take_along_axis(p[:, :k], drafts[..., None], axis=-1)[..., 0]
    return jnp.where(qd > 0.0,
                     jnp.minimum(1.0, pd / jnp.maximum(qd, 1e-38)),
                     (pd > 0.0).astype(jnp.float32))


def residual_dist(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Normalised rejection residual ``max(p - q, 0)`` over the last axis.
    Zero residual mass means ``p <= q`` pointwise, i.e. ``p == q`` for
    distributions — rejection is then impossible (the accept rule fires
    with probability 1), so the ``p`` fallback keeps the helper total
    without ever being reachable from ``rejection_sample``."""
    r = jnp.maximum(p - q, 0.0)
    s = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(s > 0.0, r / jnp.maximum(s, 1e-38), p)


def rejection_sample(keys, drafts: jnp.ndarray, q: jnp.ndarray,
                     p: jnp.ndarray):
    """Per-row rejection-sampling verification of a proposal window.

    ``keys`` (B,) per-row window keys (``draw_keys(..., TAG_WINDOW)``);
    ``drafts`` (B, k) proposed tokens; ``q`` (B, k, V) the distributions
    they were proposed from (a one-hot point mass for deterministic
    proposers); ``p`` (B, k+1, V) the target's warped verify
    distributions.

    Returns ``(tokens (B, k+1), a (B,))`` laid out like
    ``speculative.greedy_accept``: ``a`` is the number of accepted
    proposals and the row emits ``tokens[:, :a+1]`` — the accepted
    proposals followed by the residual resample (``a < k``) or the bonus
    draw from ``p[:, k]`` (``a == k``).  Positions past ``a`` repeat the
    final draw; they are dead filler matching greedy_accept's convention
    that only ``:a+1`` is ever read.

    Acceptance uses the division-free rule ``u * q(d) < p(d)`` (``u ~
    U[0,1)``), equivalent to ``u < min(1, p(d)/q(d))`` and exact even
    when ``q(d)`` underflows; ``q == p`` therefore accepts everything
    (``u < 1``)."""
    b, k = drafts.shape

    def row(key, d, qr, pr):
        ku, kf = jax.random.split(key)
        u = jax.random.uniform(ku, (k,))
        qd = jnp.take_along_axis(qr, d[:, None], axis=1)[:, 0]
        pd = jnp.take_along_axis(pr[:k], d[:, None], axis=1)[:, 0]
        acc = (u * qd < pd).astype(jnp.int32)
        a = jnp.sum(jnp.cumprod(acc))
        j = jnp.minimum(a, k - 1)  # residual position (clip: a==k uses p[k])
        dist = jnp.where(a == k, pr[k], residual_dist(pr[j], qr[j]))
        final = jax.random.categorical(kf, jnp.log(dist)).astype(jnp.int32)
        padded = jnp.concatenate([d, d[-1:]])
        return jnp.where(jnp.arange(k + 1) < a, padded, final), a

    return jax.vmap(row)(keys, drafts, q.astype(jnp.float32),
                         p.astype(jnp.float32))
