"""Synthetic, deterministic, restart-safe token pipeline.

Production data loaders are I/O systems; what the *framework* must guarantee
is (a) determinism given (seed, step) — so a restarted job resumes mid-epoch
without data skew, (b) host-sharding — each data-parallel host materialises
only its slice, and (c) shape stability.  This pipeline provides all three
with a counter-based generator (stateless: batch = f(seed, step)), the same
contract a tf.data/Grain loader would satisfy.

The synthetic distribution is a order-2 Markov chain over the vocab so the
LM loss has actual structure to learn (used by the quickstart example and
the learnability tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 256
    markov_order: int = 2


def _fold(seed: int, step: int, shard: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.fold_in(jax.random.fold_in(key, step), shard)


def token_stream(
    cfg: DataConfig, step: int, shape: tuple[int, int], shard: int = 0
) -> jnp.ndarray:
    """Markov-chain token batch for (seed, step, shard) — stateless/resumable."""
    key = _fold(cfg.seed, step, shard)
    b, s = shape
    # Deterministic per-vocab transition preferences (cheap structured source).
    k_tab, k_tok = jax.random.split(key)
    shift = jax.random.randint(k_tab, (cfg.vocab,), 1, cfg.vocab)
    first = jax.random.randint(k_tok, (b, 1), 0, cfg.vocab)

    def step_fn(tok, noise):
        nxt = jnp.where(noise < 0.85, (tok + shift[tok]) % cfg.vocab,
                        (tok * 7 + 13) % cfg.vocab)
        return nxt, nxt

    noise = jax.random.uniform(jax.random.fold_in(k_tok, 1), (s - 1, b, 1))
    _, rest = jax.lax.scan(step_fn, first, noise)
    return jnp.concatenate([first[None], rest], axis=0).transpose(1, 0, 2)[..., 0]


def make_batch(
    model_cfg: ModelConfig,
    shape: ShapeConfig,
    step: int = 0,
    data_cfg: Optional[DataConfig] = None,
    batch_override: Optional[int] = None,
    seq_override: Optional[int] = None,
) -> dict:
    """Materialise one global batch for an (arch, shape) cell."""
    dc = data_cfg or DataConfig(vocab=min(model_cfg.vocab, 4096))
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    toks = token_stream(dc, step, (b, s)) % model_cfg.vocab
    batch = {"tokens": toks.astype(jnp.int32)}
    if model_cfg.family == "encdec":
        batch["dec_tokens"] = batch.pop("tokens")
        nf = model_cfg.audio.n_frames
        batch["frames"] = jax.random.normal(
            _fold(dc.seed, step, 1), (b, nf, model_cfg.d_model), jnp.float32
        )
    if model_cfg.family == "vlm":
        ni = model_cfg.vision.n_image_tokens
        batch["image_embeds"] = jax.random.normal(
            _fold(dc.seed, step, 2), (b, ni, model_cfg.d_model), jnp.float32
        )
    return batch


def batch_spec(model_cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    spec = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if model_cfg.family == "encdec":
        spec["dec_tokens"] = spec.pop("tokens")
        spec["frames"] = jax.ShapeDtypeStruct(
            (b, model_cfg.audio.n_frames, model_cfg.d_model), jnp.float32
        )
    if model_cfg.family == "vlm":
        spec["image_embeds"] = jax.ShapeDtypeStruct(
            (b, model_cfg.vision.n_image_tokens, model_cfg.d_model), jnp.float32
        )
    return spec
