"""Deterministic synthetic data pipeline (host-sharded)."""
from .pipeline import DataConfig, batch_spec, make_batch, token_stream

__all__ = ["DataConfig", "make_batch", "token_stream", "batch_spec"]
