"""Gradient compression with error feedback (distributed-optimization trick).

The paper's PIM thesis — move less data, compute near where it lives — applied
to the gradient all-reduce: gradients are quantized to int8 per-leaf-row
before crossing the interconnect and the quantization residual is carried to
the next step (error feedback keeps SGD convergence).  At 1000+ nodes the
data-parallel all-reduce is the dominant cross-pod collective; int8 cuts its
bytes 4x (see EXPERIMENTS.md §Perf collective-term iterations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _rowwise_scale(g: jnp.ndarray) -> jnp.ndarray:
    flat = g.reshape(g.shape[0] if g.ndim > 1 else 1, -1)
    amax = jnp.max(jnp.abs(flat), axis=-1)
    return jnp.maximum(amax / 127.0, 1e-12)


def compress_gradients(grads):
    """f32 grads -> (int8 codes, f32 row scales) per leaf."""

    def comp(g):
        g32 = g.astype(jnp.float32)
        scale = _rowwise_scale(g32)
        bshape = (-1,) + (1,) * (g.ndim - 1) if g.ndim > 1 else (1,)
        codes = jnp.clip(jnp.round(g32 / scale.reshape(bshape)), -127, 127)
        return {"codes": codes.astype(jnp.int8), "scale": scale}

    return jax.tree.map(comp, grads)


def decompress_gradients(comp):
    def dec(c):
        bshape = (-1,) + (1,) * (c["codes"].ndim - 1) if c["codes"].ndim > 1 else (1,)
        return c["codes"].astype(jnp.float32) * c["scale"].reshape(bshape)

    return jax.tree.map(dec, comp, is_leaf=lambda x: isinstance(x, dict) and "codes" in x)


def error_feedback_update(grads, residual):
    """Add carried residual, compress, and compute the new residual.

    Returns (compressed, new_residual).  The all-reduce happens on the
    compressed representation; callers decompress after the collective.
    """
    if residual is not None:
        grads = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    comp = compress_gradients(grads)
    recon = decompress_gradients(comp)
    new_residual = jax.tree.map(lambda g, r: g - r, grads, recon)
    return comp, new_residual
