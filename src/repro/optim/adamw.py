"""AdamW with f32 master weights over (possibly bf16) model params."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        # copy=True: for f32 params astype would alias the same buffer, which
        # breaks double-donation in jit(train_step, donate_argnums=(0, 1)).
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        ),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state["v"], grads)

    def upd(master, m_, v_):
        mhat = m_ / b1c
        vhat = v_ / b2c
        return master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                              + cfg.weight_decay * master)

    master = jax.tree.map(upd, state["master"], m, v)
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    new_state = {"step": step, "m": m, "v": v, "master": master}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
