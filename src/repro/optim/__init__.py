"""Optimizer substrate: AdamW, schedules, clipping, gradient compression."""
from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .compression import compress_gradients, decompress_gradients, error_feedback_update
from .distributed import compressed_psum_mean, dp_train_step_factory
from .schedule import cosine_schedule

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "global_norm",
    "cosine_schedule",
    "compress_gradients", "decompress_gradients", "error_feedback_update",
    "compressed_psum_mean", "dp_train_step_factory",
]
