"""Explicit-collective data-parallel gradient exchange (shard_map).

The pjit trainer's gradient all-reduce is implicit (GSPMD).  For the
cross-pod axis — the slowest links at 1000+ nodes — this module provides the
explicit alternative: per-host grads are int8-compressed (with error
feedback, optim.compression), the *codes* cross the interconnect, and the
scales travel as a tiny side channel.  4x fewer bytes on the pod axis than
bf16 all-reduce; convergence is preserved by the error-feedback residual
(tests/test_substrate.py) — the same store-less-move-less thesis as the
paper's reduced-precision PIM operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .compression import compress_gradients, decompress_gradients


def compressed_psum_mean(grads, mesh, axis: str = "data"):
    """Mean of ``grads`` across ``axis`` using int8 codes on the wire.

    Each shard compresses its gradient leaf-wise; codes are summed with an
    integer psum (int32 accumulation); each shard's scale is all-gathered
    (negligible bytes) so the weighted sum reconstructs exactly
    sum_i scale_i * codes_i / N.
    """
    n = mesh.shape[axis]

    def exchange(g):
        comp = compress_gradients({"g": g})["g"]
        codes, scale = comp["codes"], comp["scale"]
        # codes stay int8 on the wire for the heavy tensor; scales are a
        # negligible side channel.  The reconstruction-then-psum below is
        # numerically identical to summing codes and combining scales.
        bshape = (-1,) + (1,) * (codes.ndim - 1) if codes.ndim > 1 else (1,)
        contrib = codes.astype(jnp.float32) * scale.reshape(bshape)
        return jax.lax.psum(contrib, axis) / n

    def body(flat_grads):
        return [exchange(g) for g in flat_grads]

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    specs = tuple(P(*((None,) * l.ndim)) for l in leaves)
    # check_rep=False: jax 0.4.37's static replication checker cannot see
    # through the integer-psum + gathered-scale reconstruction; the outputs
    # ARE replicated (each shard computes the same weighted sum).
    out = shard_map(
        body, mesh=mesh, in_specs=(specs,), out_specs=specs, check_rep=False,
    )(leaves)
    return jax.tree_util.tree_unflatten(treedef, out)


def dp_train_step_factory(loss_fn, mesh, axis: str = "data"):
    """Data-parallel step with explicit compressed gradient exchange.

    ``loss_fn(params, batch) -> scalar``.  Params replicated; batch sharded
    on dim 0 across ``axis``.  Returns step(params, batch, residual) ->
    (grads_mean, new_residual, loss_mean) where grads crossed the wire int8.
    """

    def per_shard(params, batch, residual):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if residual is not None:
            grads = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                                 grads, residual)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        comp = compress_gradients(grads)
        recon = decompress_gradients(comp)
        new_residual = jax.tree.map(lambda g, r: g - r, grads, recon)
        g_mean = jax.tree.map(
            lambda r: jax.lax.pmean(r, axis), recon
        )
        return g_mean, new_residual, jax.lax.pmean(loss, axis)

    @functools.partial(jax.jit, static_argnums=())
    def step(params, batch, residual):
        pspec = jax.tree.map(lambda l: P(*((None,) * jnp.ndim(l))), params)
        bspec = jax.tree.map(
            lambda l: P(*((axis,) + (None,) * (jnp.ndim(l) - 1))), batch
        )
        rspec = jax.tree.map(lambda l: P(*((None,) * jnp.ndim(l))), params)
        return shard_map(
            per_shard, mesh=mesh,
            in_specs=(pspec, bspec, rspec),
            out_specs=(pspec, rspec, P()),
            check_rep=False,
        )(params, batch, residual)

    return step
