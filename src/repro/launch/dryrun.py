import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   Set here and ONLY here — smoke tests and benches must see 1 device.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the appropriate
step (train_step / prefill / serve_step) on the production meshes:

  single-pod: (16, 16)      = 256 chips  (data, model)
  multi-pod : (2, 16, 16)   = 512 chips  (pod, data, model)

and record memory_analysis / cost_analysis / collective stats for the
roofline.  Any failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the framework.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b  # one arch
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh multi                               # one cell
  ... --out results/dryrun.json                                   # persist
"""
import argparse
import json
import time
import traceback


def run_cell(cfg, shape, mesh, *, compile_: bool = True, verbose: bool = True,
             save_hlo: str | None = None):
    from repro.launch.roofline import analyze
    from repro.launch.steps import lower_cell

    t0 = time.time()
    cell = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    out = {
        "arch": cfg.arch_id, "shape": shape.name,
        "mesh": cell.mesh_desc, "kind": cell.kind,
        "lower_s": round(t_lower, 1), "ok": True,
    }
    if not compile_:
        return out
    t0 = time.time()
    roof = analyze(cell, cfg, shape, save_hlo=save_hlo)
    out["compile_s"] = round(time.time() - t0, 1)
    out.update({k: v for k, v in roof.row().items() if k not in ("arch", "shape", "mesh")})
    out["bytes_per_device_gb"] = roof.bytes_per_device / 2**30
    out["collectives"] = {
        k: {"bytes": roof.collectives.bytes_by_kind[k],
            "count": roof.collectives.count_by_kind[k]}
        for k in roof.collectives.bytes_by_kind
    }
    if verbose:
        print(
            f"  OK  {cfg.arch_id:24s} {shape.name:12s} mesh={cell.mesh_desc:8s} "
            f"lower={out['lower_s']:6.1f}s compile={out['compile_s']:6.1f}s "
            f"bottleneck={roof.bottleneck:10s} "
            f"t=(c {roof.t_compute*1e3:9.3f} | m {roof.t_memory*1e3:9.3f} | "
            f"x {roof.t_collective*1e3:9.3f}) ms  "
            f"useful={roof.useful_flops_ratio:5.2f} "
            f"mem/dev={out['bytes_per_device_gb']:.2f}GiB",
            flush=True,
        )
    return out


def main() -> None:
    import jax

    from repro.configs import SHAPES, get_config, registry, shapes_for
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="write JSON results here")
    ap.add_argument("--no-compile", action="store_true", help="lower only")
    ap.add_argument("--save-hlo", default=None,
                    help="directory to dump compiled HLO (gzip) per cell")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 host devices, got {jax.device_count()} "
        "(XLA_FLAGS must be set before any jax import)"
    )

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single-pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi-pod", make_production_mesh(multi_pod=True)))

    arch_ids = [args.arch] if args.arch else registry.ARCH_IDS
    results, failures = [], []
    for arch_id in arch_ids:
        cfg = get_config(arch_id)
        shapes = shapes_for(cfg)
        if args.shape:
            shapes = [s for s in shapes if s.name == args.shape]
        for shape in shapes:
            for mesh_name, mesh in meshes:
                try:
                    results.append(
                        run_cell(cfg, shape, mesh, compile_=not args.no_compile,
                                 save_hlo=args.save_hlo)
                    )
                except Exception as e:  # noqa: BLE001 — report all failures
                    traceback.print_exc()
                    failures.append(
                        {"arch": arch_id, "shape": shape.name, "mesh": mesh_name,
                         "error": f"{type(e).__name__}: {e}", "ok": False}
                    )
                    print(f"  FAIL {arch_id} {shape.name} {mesh_name}: {e}",
                          flush=True)

    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"ok": results, "failed": failures}, f, indent=1)
        print(f"wrote {args.out}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
