"""Distributed train / prefill / decode step builders.

``lower_cell`` is the single entry point the dry-run, roofline, and perf
iterations all share: given (arch config, shape config, mesh) it constructs
the right step function, the ShapeDtypeStruct inputs (no allocation), the
in/out shardings, and returns the jax.jit lowered artifact.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import batch_spec
from repro.models import decode_step, forward, init_cache, init_params, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.serving import quantize_tree

from .sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    replicated,
    sanitize,
)

# decode_32k / long_500k lower serve_step with a KV cache of this length.
DECODE_CACHE_LEN = {"decode_32k": 32_768, "long_500k": 524_288}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        lr_scale = cosine_schedule(
            opt_state["step"], opt_cfg.warmup_steps, opt_cfg.total_steps
        )
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg, lr_scale)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch)
        # serving prefill returns the last-position logits (next-token)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig, extras_keys: tuple = ()):
    def serve_step(params, cache, tokens, pos, extras):
        logits, cache = decode_step(params, cfg, tokens, cache, pos, extras)
        return logits, cache

    return serve_step


@dataclass
class LoweredCell:
    arch: str
    shape: str
    mesh_desc: str
    kind: str
    lowered: Any
    n_devices: int


def _params_shape(cfg: ModelConfig, quantized: bool):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    if quantized:
        shapes = jax.eval_shape(lambda: quantize_tree_shapes(shapes, cfg.pim_bits))
    return shapes


def quantize_tree_shapes(shapes, bits):
    """quantize_tree lifted to ShapeDtypeStructs via eval_shape tricks."""
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    dummies = [jnp.zeros(l.shape, l.dtype) if 0 not in l.shape else l for l in leaves]
    tree = jax.tree_util.tree_unflatten(treedef, dummies)
    return quantize_tree(tree, bits)


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    *,
    opt_cfg: Optional[AdamWConfig] = None,
    pim: Optional[bool] = None,
    donate: bool = True,
    variant: Optional[dict] = None,
) -> LoweredCell:
    """Lower (don't run) one (arch x shape) cell on a mesh.

    train_4k  -> train_step(params, opt_state, batch)
    prefill_* -> prefill_step(quantized_params, batch)
    decode_*  -> serve_step(quantized_params, cache, tokens, pos, extras)

    ``variant``: hillclimb knobs — any of
      fsdp (bool), pim_bits (int), kv_chunk (int), remat (bool),
      logits_f32 (bool), moe_group (int).  Absent keys = baseline.
    """
    variant = dict(variant or {})
    fsdp_enabled = variant.pop("fsdp", True)
    if "moe_group" in variant and cfg.moe is not None:
        import dataclasses as _dc

        cfg = cfg.replace(moe=_dc.replace(cfg.moe, group_tokens=variant.pop("moe_group")))
    variant.pop("moe_group", None)
    cfg_knobs = {k: v for k, v in variant.items()
                 if k in ("pim_bits", "kv_chunk", "remat", "logits_f32",
                          "act_shard", "kv_cache_bits")}
    if cfg_knobs:
        cfg = cfg.replace(**cfg_knobs)
    use_pim = cfg.pim_bits > 0 if pim is None else pim
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)

    if shape.kind == "train":
        params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        p_sh = sanitize(param_shardings(mesh, params_shape, cfg, fsdp_enabled),
                        params_shape)
        o_sh = sanitize(opt_state_shardings(mesh, opt_shape, cfg), opt_shape)
        b_spec = batch_spec(cfg, shape)
        b_sh = sanitize(batch_shardings(mesh, b_spec), b_spec)
        step = make_train_step(cfg, opt_cfg or AdamWConfig())
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(params_shape, opt_shape, b_spec)
        return LoweredCell(cfg.arch_id, shape.name, mesh_desc, "train", lowered,
                           mesh.devices.size)

    # inference cells use PIM-quantized weights when the arch enables them
    params_shape = _params_shape(cfg, quantized=use_pim)
    p_sh = sanitize(param_shardings(mesh, params_shape, cfg, fsdp_enabled),
                    params_shape)

    if shape.kind == "prefill":
        b_spec = batch_spec(cfg, shape)
        b_sh = sanitize(batch_shardings(mesh, b_spec), b_spec)
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=None)
        with mesh:
            lowered = jitted.lower(params_shape, b_spec)
        return LoweredCell(cfg.arch_id, shape.name, mesh_desc, "prefill", lowered,
                           mesh.devices.size)

    # decode: one new token against a cache of shape.seq_len
    cache_len = shape.seq_len
    b = shape.global_batch
    cache_shape = jax.eval_shape(lambda: init_cache(cfg, b, cache_len))
    c_sh = sanitize(cache_shardings(mesh, cache_shape, cfg, shape), cache_shape)
    tok_spec = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_dp = 1
    for a in dp:
        n_dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    batch_ok = b % n_dp == 0 and b >= n_dp
    dp_axis = dp if len(dp) > 1 else dp[0]
    tok_sh = NamedSharding(mesh, P(dp_axis, None) if batch_ok else P(None, None))
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    extras_spec = {}
    if cfg.family == "vlm":
        extras_spec["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision.n_image_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        extras_spec["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.audio.n_frames, cfg.d_model), jnp.float32
        )
    e_sh = (
        sanitize(batch_shardings(mesh, extras_spec), extras_spec)
        if (extras_spec and batch_ok)
        else jax.tree.map(lambda _: replicated(mesh), extras_spec)
    )

    step = make_decode_step(cfg)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, tok_sh, replicated(mesh), e_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,) if donate else (),
    )
    with mesh:
        lowered = jitted.lower(params_shape, cache_shape, tok_spec, pos_spec, extras_spec)
    return LoweredCell(cfg.arch_id, shape.name, mesh_desc, "decode", lowered,
                       mesh.devices.size)
