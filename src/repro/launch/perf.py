import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver (§Perf).

Lowers + compiles one (arch x shape x mesh) cell under a set of variants and
prints the roofline-term deltas vs baseline.  Used to drive the
hypothesis -> change -> measure -> validate iterations recorded in
EXPERIMENTS.md §Perf.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch qwen2-1.5b \
      --shape decode_32k --variants no_fsdp,pim4
  PYTHONPATH=src python -m repro.launch.perf --arch deepseek-v2-lite-16b \
      --shape train_4k --variants moe_group_2048,no_remat,logits_bf16
"""
import argparse
import json

VARIANTS = {
    "baseline": {},
    "no_fsdp": {"fsdp": False},
    "pim4": {"pim_bits": 4},
    "no_remat": {"remat": False},
    "logits_bf16": {"logits_f32": False},
    "kv_chunk_1024": {"kv_chunk": 1024},
    "kv_chunk_2048": {"kv_chunk": 2048},
    "kv_chunk_256": {"kv_chunk": 256},
    "moe_group_1024": {"moe_group": 1024},
    "moe_group_2048": {"moe_group": 2048},
    "moe_group_8192": {"moe_group": 8192},
    "act_shard": {"act_shard": True},
    "kv8": {"kv_cache_bits": 8},
    "kv8_no_fsdp": {"kv_cache_bits": 8, "fsdp": False},
    "act_shard_no_fsdp": {"act_shard": True, "fsdp": False},
}


def run(arch: str, shape_name: str, mesh_kind: str, variant_names: list[str],
        out_path: str | None = None):
    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze
    from repro.launch.steps import lower_cell

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    rows = []
    base = None
    for vname in ["baseline"] + [v for v in variant_names if v != "baseline"]:
        spec = VARIANTS[vname] if vname in VARIANTS else json.loads(vname)
        cell = lower_cell(cfg, shape, mesh, variant=spec)
        cell.arch = f"{cell.arch}+{vname}"
        roof = analyze(cell, cfg, shape, save_hlo="results/hlo_perf")
        row = {
            "variant": vname,
            "t_compute_ms": roof.t_compute * 1e3,
            "t_memory_ms": roof.t_memory * 1e3,
            "t_collective_ms": roof.t_collective * 1e3,
            "bottleneck": roof.bottleneck,
            "t_bound_ms": roof.t_bound * 1e3,
            "useful": roof.useful_flops_ratio,
            "roofline_fraction": roof.roofline_fraction,
            "coll_by_kind": {k: round(v / 2**20, 1)
                             for k, v in roof.collectives.bytes_by_kind.items()},
        }
        if base is None:
            base = row
        row["bound_vs_baseline"] = row["t_bound_ms"] / base["t_bound_ms"]
        rows.append(row)
        print(
            f"{vname:16s} bound={row['t_bound_ms']:10.3f}ms "
            f"({row['bound_vs_baseline']:.3f}x) [{row['bottleneck']:10s}] "
            f"c={row['t_compute_ms']:9.3f} m={row['t_memory_ms']:10.3f} "
            f"x={row['t_collective_ms']:10.3f} rf={row['roofline_fraction']:.3f}",
            flush=True,
        )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variants", default="baseline",
                    help="comma-separated variant names (see VARIANTS) or "
                         "inline JSON dicts")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(args.arch, args.shape, args.mesh, args.variants.split(","), args.out)


if __name__ == "__main__":
    main()
