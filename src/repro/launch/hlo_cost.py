"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while-loop *body once* — for a
framework built on ``lax.scan`` (layer stacks, KV chunks, SSM chunks) that
under-reports FLOPs/bytes/collectives by the trip count (verified:
a scan of 8 matmuls reports 1/8 the flops of the unrolled form).

This module re-derives the three roofline inputs directly from the optimized
HLO text with loop multipliers applied:

  1. parse the module into computations;
  2. find every ``while`` op, resolve its body/condition computations, and
     extract the trip count from the condition's comparison constant;
  3. propagate multipliers: multiplier(body) = multiplier(parent) x trip,
     through nested whiles, calls, and fusions;
  4. FLOPs: 2 x prod(result dims) x prod(contracting dims) per ``dot``
     (operand shapes resolved via a per-computation symbol table);
  5. bytes: operand + result bytes of every memory-level op (fusion, dot,
     copy, convert, collective, dynamic-slice/update, scatter/gather, ...);
  6. collective bytes: result bytes of each collective x multiplier.

All values are PER-DEVICE (the module is SPMD-partitioned).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r"known_trip_count[^}]*\"n\":\"(\d+)\"")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Ops whose operands+results we count as HBM traffic.  Deliberately at
# *fusion granularity for the TPU target*: pure-elementwise chains
# (add/mul/convert/compare/...) are assumed fused into their producers —
# XLA:TPU does this; the XLA:CPU backend we dry-run on fuses far less, and
# counting its unfused elementwise ops would inflate the TPU memory-term
# estimate several-fold.  What remains is the traffic that cannot fuse away:
# matmuls, explicit copies, gathers/scatters/dynamic slices (KV caches,
# embeddings), reductions, and collectives.
_MEM_OPS = (
    "fusion", "dot", "convolution", "copy",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "reduce-window", "sort",
) + _COLLECTIVES


def _shape_info(shape_str: str) -> tuple[int, list[int]]:
    """(bytes, dims-of-first-array) for an HLO shape string (tuples summed)."""
    total, first_dims = 0, None
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims_s = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


@dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rhs: str

    @property
    def result_bytes(self) -> int:
        return _shape_info(self.shape_str)[0]

    @property
    def result_dims(self) -> list[int]:
        return _shape_info(self.shape_str)[1]


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    params: dict = field(default_factory=dict)  # name -> shape_str


_OPCODE_RE = re.compile(r"^([a-z][\w\-]*)\(")


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        m = _COMP_HEADER_RE.match(s)
        if m and s.endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            # (parameter shapes come from the 'parameter(i)' instructions)
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(s)
        if not im:
            continue
        name, rest = im.group(1), im.group(2)
        # rest = '<shape>{layout} opcode(...)...' — find the opcode token
        om = re.search(r"\s([a-z][\w\-]*)\(", rest)
        if om is None:
            # parameter(0) style appears as 'shape parameter(0)'
            continue
        opcode = om.group(1)
        shape_str = rest[: om.start()]
        cur.instrs.append(Instr(name, shape_str, opcode, rest))
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from the condition computation: the comparison constant."""
    consts = []
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = _CONST_RE.search(ins.rhs)
            if m:
                consts.append(int(m.group(1)))
        if ins.opcode == "compare":
            # operands reference a constant by name; fall back to max const
            pass
    return max(consts) if consts else 1


def compute_multipliers(
    comps: dict[str, Computation], entry: str
) -> tuple[dict[str, float], dict[str, int]]:
    """(multiplier per computation, owning-loop trip count per computation).

    The trip map lets the byte model recognise scan xs/ys buffers (leading
    dim == trip) and charge them at slice granularity.
    """
    mult = {entry: 1.0}
    trips: dict[str, int] = {}
    stack = [entry]
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        comp = comps[cname]
        m = mult.get(cname, 1.0)
        for ins in comp.instrs:
            if ins.opcode == "while":
                bm = _BODY_RE.search(ins.rhs)
                cm = _COND_RE.search(ins.rhs)
                tm = _TRIP_RE.search(ins.rhs)  # backend_config known_trip_count
                if tm:
                    trip = int(tm.group(1))
                elif cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)])
                else:
                    trip = 1
                for target, factor in ((bm, trip), (cm, trip + 1)):
                    if target and target.group(1) in comps:
                        t = target.group(1)
                        mult[t] = mult.get(t, 0.0) + m * factor
                        trips[t] = trip
                        stack.append(t)
            else:
                for callee_m in _CALLS_RE.finditer(ins.rhs):
                    t = callee_m.group(1)
                    if t in comps:
                        mult[t] = mult.get(t, 0.0) + m
                        trips.setdefault(t, trips.get(cname, 0))
                        stack.append(t)
    return mult, trips


def _find_entry(hlo: str, comps: dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation named 'main*'
    for name in comps:
        if name.startswith("main"):
            return name
    return next(iter(comps))


@dataclass
class ScaledCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    loops: dict = field(default_factory=dict)  # body name -> multiplier

    def merge_kind(self, kind, nbytes):
        self.collective_by_kind[kind] = self.collective_by_kind.get(kind, 0) + nbytes


def _dot_flops(ins: Instr, symtab: dict[str, str]) -> float:
    out_dims = ins.result_dims
    n_out = 1
    for d in out_dims:
        n_out *= d
    # contracting dims from lhs operand shape
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    operands = _OPERAND_RE.findall(ins.rhs[ins.rhs.index("(") :])
    k = 1
    if cm and operands:
        lhs_shape = symtab.get(operands[0], "")
        _, lhs_dims = _shape_info(lhs_shape)
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * n_out * k


def instr_bytes(ins: Instr, symtab: dict, trip: int = 0,
                flash_seq: int = 0) -> float:
    """Estimated HBM traffic of one instruction (see _MEM_OPS notes).

    ``trip``: trip count of the owning while loop; tensors whose leading dim
    equals it are scan xs/ys stacks — each iteration touches one slice, so
    they are charged at size/trip (result too, for the DUS-root stacking
    fusions that alias the stacked output).

    ``flash_seq``: if > 0, tensors containing two dims == flash_seq (the
    S x S attention interior: scores, probabilities, their grads) are charged
    0 bytes — modelling the Pallas flash-attention kernel
    (kernels/flash_attn), which keeps them VMEM-resident.  FLOPs are NOT
    adjusted (the kernel does the same math).
    """
    op = ins.opcode

    def _sized(shape_str: str) -> float:
        b, dims = _shape_info(shape_str)
        if flash_seq and sum(1 for d in dims if d == flash_seq) >= 2:
            return 0.0
        if trip > 1 and dims and dims[0] == trip:
            return b / trip
        return b

    rb = _sized(ins.shape_str)
    operands = (
        _OPERAND_RE.findall(ins.rhs[ins.rhs.index("(") :]) if "(" in ins.rhs else []
    )
    op_bytes = [_sized(symtab[o]) for o in operands if o in symtab]
    if op in ("dynamic-slice", "gather") or (
        op == "fusion" and "dynamic-slice" in ins.name and "update" not in ins.name
    ):
        return 2 * rb
    if op in ("dynamic-update-slice", "scatter") or (
        op == "fusion" and "dynamic-update-slice" in ins.name
    ):
        if op == "fusion":
            return 2 * (sum(op_bytes) - (max(op_bytes) if op_bytes else 0))
        upd = 0
        if len(operands) >= 2 and operands[1] in symtab:
            upd = _sized(symtab[operands[1]])
        return 2 * upd
    return rb + sum(op_bytes)


def analyze_hlo(hlo: str, flash_seq: int = 0) -> ScaledCost:
    comps = parse_module(hlo)
    entry = _find_entry(hlo, comps)
    mult, trips = compute_multipliers(comps, entry)
    cost = ScaledCost()

    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # unreachable (e.g. dead computations)
        # symbol table: params + instruction results
        symtab = dict(comp.params)
        for ins in comp.instrs:
            symtab[ins.name] = ins.shape_str
        # also register 'shape name' style params found inline
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                cost.flops += m * _dot_flops(ins, symtab)
            if op in _MEM_OPS:
                cost.bytes_accessed += m * instr_bytes(
                    ins, symtab, trips.get(cname, 0), flash_seq
                )
            for kind in _COLLECTIVES:
                if op == kind or op == kind + "-start":
                    nb = ins.result_bytes
                    cost.collective_bytes += m * nb
                    cost.merge_kind(kind, m * nb)
                    break
    cost.loops = {k: v for k, v in mult.items() if v > 1.0}
    return cost
