"""End-to-end training driver.

On hardware this runs under the TrainingSupervisor with the production mesh;
on CPU (this container) it drives REDUCED configs for real (examples/
quickstart.py) — same code path, small shapes.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data import DataConfig, make_batch
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.configs.base import ShapeConfig

from .steps import make_train_step


def train_loop(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    log_every: int = 10,
    opt_cfg: AdamWConfig | None = None,
    resume: bool = True,
):
    """Single-host training loop with checkpoint/resume. Returns metrics log."""
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume:
        got_step, restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if got_step is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = got_step
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    dc = DataConfig(seed=seed, vocab=min(cfg.vocab, 4096))
    shape = ShapeConfig("cli", seq, batch, "train")

    log = []
    t0 = time.time()
    for step in range(start_step, steps):
        b = make_batch(cfg, shape, step=step, data_cfg=dc,
                       batch_override=batch, seq_override=seq)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = round(time.time() - t0, 2)
            log.append(m)
            print(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} ({m['wall_s']}s)", flush=True)
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state})
    return params, opt_state, log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    _, _, log = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, seed=args.seed,
    )
    losses = [m["loss"] for m in log]
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
    assert np.isfinite(losses[-1])


if __name__ == "__main__":
    main()
