"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds:
  compute    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  IMPORTANT:
cost_analysis runs on the SPMD-*partitioned* module, so flops/bytes are
already PER-DEVICE; the terms below therefore divide by per-chip rates only.
``useful_flops_ratio`` compares against global MODEL_FLOPS via
hlo_flops * n_devices.  Collective bytes are NOT in cost_analysis, so we
parse the optimized HLO text and sum result sizes of all-gather / all-reduce
/ reduce-scatter / all-to-all / collective-permute ops — also per-shard, i.e.
the bytes each device's link carries (1-pass model; ring all-reduce moves
~2x, recorded as a known underestimate).  Hardware constants: TPU v5e.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e per-chip constants (per the system spec).
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape like 'f32[128,256]' or a tuple of them."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output shapes of every collective op in (optimized) HLO text.

    Counts the *result* shape of each collective instruction — the data that
    actually crosses links (start/done pairs counted once via the -start op;
    plain (non-async) forms counted directly).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        lhs_rhs = s.split(" = ", 1)
        if len(lhs_rhs) != 2:
            continue
        _, rhs = lhs_rhs
        # HLO: '%name = <shape-with-layout> <opcode>(operands...), attrs'
        op = None
        for kind in _COLLECTIVES:
            # match '<shape> all-reduce(' / '-start(' but not '-done('
            if re.search(rf"\}}?\s{kind}(-start)?\(", rhs):
                op = kind
                break
        if op is None:
            continue
        shape_str = rhs.split(f" {op}")[0]
        nbytes = _shape_bytes(shape_str)
        stats.bytes_by_kind[op] = stats.bytes_by_kind.get(op, 0) + nbytes
        stats.count_by_kind[op] = stats.count_by_kind.get(op, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    collectives: CollectiveStats = None
    bytes_per_device: float = 0.0  # peak memory from memory_analysis (if any)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS  # per-device flops / per-chip rate

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x devices) — fraction of compiled compute
        that is 'useful' (catches remat/redundancy/padding waste)."""
        total = self.hlo_flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of the compute roofline if perfectly overlapped:
        t_compute / max(terms)."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
            "hlo_gflops": self.hlo_flops / 1e9, "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.collective_bytes / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N_active*D for inference steps."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(lowered_cell, cfg, shape, save_hlo: str | None = None) -> Roofline:
    """Compile a lowered cell and derive its roofline terms.

    Primary source: the trip-count-aware HLO analyzer (hlo_cost) — XLA's own
    cost_analysis counts while-loop bodies once, under-reporting scan-based
    models by the trip count.  XLA numbers are kept as a lower-bound
    cross-check (max is taken, in case a construct escapes our parser).

    ``save_hlo``: directory to write the compiled HLO text (gzip) so perf
    iterations can re-analyze without recompiling.
    """
    from .hlo_cost import analyze_hlo

    compiled = lowered_cell.lowered.compile()
    if save_hlo:
        import gzip
        import os

        os.makedirs(save_hlo, exist_ok=True)
        tag = f"{lowered_cell.arch}_{lowered_cell.shape}_{lowered_cell.mesh_desc}"
        with gzip.open(os.path.join(save_hlo, tag + ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    scaled = analyze_hlo(hlo)
    flops = max(scaled.flops, xla_flops)
    nbytes = max(scaled.bytes_accessed, xla_bytes)
    colls = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in scaled.collective_by_kind.items()},
        count_by_kind={k: 1 for k in scaled.collective_by_kind},
    )

    mem_per_dev = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            # Per-partition peak (buffer assignment). The XLA:CPU backend's
            # temp accounting is unreliable for scan-heavy modules, so we
            # report peak_memory (args+outputs+live temps at peak).
            mem_per_dev = float(getattr(ma, "peak_memory_in_bytes", 0))
    except Exception:
        pass

    return Roofline(
        arch=lowered_cell.arch,
        shape=lowered_cell.shape,
        mesh=lowered_cell.mesh_desc,
        n_devices=lowered_cell.n_devices,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective_bytes=float(colls.total_bytes),
        model_flops=model_flops_for(cfg, shape),
        collectives=colls,
        bytes_per_device=mem_per_dev,
    )
