"""Sharding rules: parameter / batch / cache PartitionSpecs.

Strategy (DESIGN.md §6):
  * weights: FSDP over the batch axes (pod, data) x tensor-parallel over
    'model' (attention heads / d_ff / experts / vocab);
  * activations: batch over (pod, data); intermediate shardings left to
    GSPMD propagation (constraints added only where the perf iteration
    showed propagation picked wrong — see EXPERIMENTS.md §Perf);
  * MoE: experts over 'model' (EP) — the dispatch einsum reshards tokens
    group->expert, which GSPMD lowers to the canonical all-to-all pair;
  * decode caches: batch over (pod, data) when divisible, else KV-heads over
    'model' with the sequence dim over 'data' (long_500k, batch=1).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]


def param_spec(path_names: list[str], ndim: int, fsdp) -> P:
    """PartitionSpec for a parameter leaf, identified by its path tail.

    ``ndim`` is the leaf rank *including* any leading stack dims; the rule
    describes the trailing (semantic) dims and is left-padded with None.
    """
    name = path_names[-1]
    in_moe = "moe" in path_names
    base: tuple
    if name in ("wq", "wk", "wv"):
        base = (fsdp, "model")
    elif name == "wo":
        base = ("model", fsdp)
    elif name in ("gate", "up"):
        base = ("model", fsdp, None) if in_moe else (fsdp, "model")
    elif name == "down":
        base = ("model", None, fsdp) if in_moe else ("model", fsdp)
    elif name == "router":
        base = (None, None)
    elif name == "embed":
        base = ("model", None)
    elif name == "head":
        base = (None, "model")
    elif name == "w_dkv":
        base = (fsdp, None)
    elif name in ("w_uk", "w_uv"):
        base = ("model", None, None)
    elif name == "in_proj":  # mamba: shard d_model rows; packed cols stay whole
        base = (fsdp, None)
    elif name == "out_proj":
        base = (None, fsdp)
    elif name in ("x_proj", "dt_proj", "conv_w"):
        base = (None, None)
    elif name == "A_log" and ndim >= 2:
        base = (None, None)
    else:
        # norms, biases, scalars, 1D dynamics params: replicate
        base = tuple(None for _ in range(min(ndim, 1)))
    pad = ndim - len(base)
    if pad < 0:  # scalar or smaller than rule (e.g. unstacked)
        base = base[-ndim:] if ndim else ()
        pad = 0
    return P(*((None,) * pad + tuple(base)))


def param_shardings(mesh, params_shape, cfg: ModelConfig, fsdp_enabled: bool = True):
    """Tree of NamedShardings matching a params (shape-)tree.

    ``fsdp_enabled=False`` replicates weights across the batch axes (pure
    DP+TP): fewer per-layer all-gathers at the cost of per-device weight
    memory — a hillclimb variant for collective-bound cells.
    """
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    fsdp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    if not fsdp_enabled:
        fsdp = None

    def conv(path, leaf):
        names = _names(path)
        # PIM-quantized leaf: codes use the weight rule; scale follows last dim.
        if names and names[-1] == "codes":
            spec = param_spec(names[:-1] or names, leaf.ndim, fsdp)
            return NamedSharding(mesh, spec)
        if names and names[-1] == "scale":
            wspec = param_spec(names[:-1] or names, leaf.ndim, fsdp)
            last = wspec[-1] if len(wspec) else None
            return NamedSharding(mesh, P(*((None,) * (leaf.ndim - 1) + (last,))))
        return NamedSharding(mesh, param_spec(names, leaf.ndim, fsdp))

    return jax.tree_util.tree_map_with_path(conv, params_shape)


def opt_state_shardings(mesh, opt_shape, cfg: ModelConfig, fsdp_enabled: bool = True):
    """Optimizer state: m/v/master follow the param shardings; step replicated.

    Note: even with fsdp_enabled=False for the *params*, optimizer state
    stays FSDP-sharded (ZeRO-1 style) — it is only touched once per step.
    """
    p_shard = {
        k: param_shardings(mesh, v, cfg)
        for k, v in opt_shape.items()
        if k in ("m", "v", "master")
    }
    return {"step": NamedSharding(mesh, P()), **p_shard}


def batch_shardings(mesh, batch_spec_tree):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_axis = dp if len(dp) > 1 else dp[0]

    def conv(leaf):
        spec = (dp_axis,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(conv, batch_spec_tree)


def cache_shardings(mesh, cache_shape, cfg: ModelConfig, shape: ShapeConfig):
    """Decode caches. Leaves have leading stack dims then (B, ...) payload.

    Identified by trailing-dim semantics:
      kv cache k/v: (..., B, S, KV, hd)
      mla cache c/kr: (..., B, S, lora)
      ssm h: (..., B, *state dims), conv: (..., B, K-1, C)
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_axis = dp if len(dp) > 1 else dp[0]
    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    batch_ok = shape.global_batch % n_dp == 0 and shape.global_batch >= n_dp

    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    def conv_kv_scale(leaf):
        nd = leaf.ndim
        kv_heads = leaf.shape[-2]
        kv_div = kv_heads % model_size == 0 and kv_heads >= model_size
        if batch_ok:
            return (None,) * (nd - 3) + (
                (dp_axis, "model", None) if kv_div else (dp_axis, None, "model")
            )
        return (None,) * (nd - 3) + (
            (None, "model", "data") if kv_div else (None, None, ("data", "model"))
        )

    def conv(path, leaf):
        names = _names(path)
        name = names[-1]
        nd = leaf.ndim
        if name in ("k", "v"):  # (..., B, KV, S, hd) — head-major cache
            # KV heads shard over 'model' when they divide it; otherwise use
            # sequence-parallel caches (seq over 'model'): softmax max/denom
            # and the attn@V contraction psum are tiny vs replicating the
            # cache (e.g. llama-90B decode_32k: 86 -> 5.4 GiB/device).
            kv_heads = leaf.shape[-3]
            kv_div = kv_heads % model_size == 0 and kv_heads >= model_size
            if batch_ok:
                spec = (None,) * (nd - 4) + (
                    (dp_axis, "model", None, None) if kv_div
                    else (dp_axis, None, "model", None)
                )
            else:
                spec = (None,) * (nd - 4) + (
                    (None, "model", "data", None) if kv_div
                    else (None, None, ("data", "model"), None)
                )
        elif name in ("k_scale", "v_scale"):  # (..., B, KV, S)
            base = conv_kv_scale(leaf)
            spec = base
        elif name in ("c", "kr"):  # (..., B, S, lora)
            if batch_ok:
                spec = (None,) * (nd - 3) + (dp_axis, None, None)
            else:
                spec = (None,) * (nd - 3) + (None, "data", None)
        elif name == "h":  # ssm state (..., B, d_in/nh, ...)
            spec = ((None,) * (nd - 3)
                    + ((dp_axis,) if batch_ok else (None,)) + ("model", None))
            spec = spec[:nd]
        elif name == "conv":  # (..., B, K-1, C)
            spec = (None,) * (nd - 3) + ((dp_axis if batch_ok else None), None, None)
        else:
            spec = (None,) * nd
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(conv, cache_shape)


def replicated(mesh):
    return NamedSharding(mesh, P())


def sanitize(shard_tree, shape_tree):
    """Drop named axes from dims they don't divide evenly.

    pjit requires explicit argument shardings to divide the dims exactly
    (e.g. kv_heads=2 cannot shard over model=16); GSPMD may pad
    *intermediates* but not arguments.  Applied to every sharding tree right
    before lower().
    """

    def fix(sh, leaf):
        if not isinstance(sh, NamedSharding):
            return sh
        mesh = sh.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        out = []
        for dim, names in zip(leaf.shape, spec):
            if names is None:
                out.append(None)
                continue
            group = names if isinstance(names, tuple) else (names,)
            prod = 1
            for a in group:
                prod *= sizes[a]
            out.append(names if dim % prod == 0 and dim >= prod else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, shard_tree, shape_tree)
