"""Sharding rules: parameter / batch / cache PartitionSpecs.

Strategy (DESIGN.md §6):
  * weights: FSDP over the batch axes (pod, data) x tensor-parallel over
    'model' (attention heads / d_ff / experts / vocab);
  * activations: batch over (pod, data); intermediate shardings left to
    GSPMD propagation (constraints added only where the perf iteration
    showed propagation picked wrong — see EXPERIMENTS.md §Perf);
  * MoE: experts over 'model' (EP) — the dispatch einsum reshards tokens
    group->expert, which GSPMD lowers to the canonical all-to-all pair;
  * decode caches: batch over (pod, data) when divisible, else KV-heads over
    'model' with the sequence dim over 'data' (long_500k, batch=1).

Decode-time specs (serving engines; quant.decode_partition_spec derives the
weight side from ``param_spec`` so train and decode stay cross-checked):

  leaf                              spec                       rationale
  ------------------------------    -----------------------   -------------
  quantized codes/scale (wq, wk,    (..., 'model')             output-column
    wv, wo, gate/up/down, head,                                shard: exact
    w_dkv, w_uk/w_uv, in/out_proj)                             all-gather
  int4 / tp marker leaves           replicated                 stack dims only
  dense leaves (embed, norms,       replicated                 gathered or
    router, biases, conv, A_log)                               tiny
  dense KV cache k/v                batch over 'data'          slots are the
  paged pool k/v / scales / c/kr    pages over 'data'          batch analogue
  block_tables                      replicated                 every device
                                                               resolves pages
  per-slot SSM h / conv state       batch over 'data'          O(1) per slot
  token state (tok/pos/done/...)    replicated                 scheduler carry

On the engines' 1-D 'model' mesh there is no 'data' axis, so every cache
row above replicates (``sanitize`` drops absent/non-dividing axes) — the
weight shards are the point; the cache is tiny next to the weight stream.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]


def param_spec(path_names: list[str], ndim: int, fsdp) -> P:
    """PartitionSpec for a parameter leaf, identified by its path tail.

    ``ndim`` is the leaf rank *including* any leading stack dims; the rule
    describes the trailing (semantic) dims and is left-padded with None.
    """
    name = path_names[-1]
    in_moe = "moe" in path_names
    base: tuple
    if name in ("wq", "wk", "wv"):
        base = (fsdp, "model")
    elif name == "wo":
        base = ("model", fsdp)
    elif name in ("gate", "up"):
        base = ("model", fsdp, None) if in_moe else (fsdp, "model")
    elif name == "down":
        base = ("model", None, fsdp) if in_moe else ("model", fsdp)
    elif name == "router":
        base = (None, None)
    elif name == "embed":
        base = ("model", None)
    elif name == "head":
        base = (None, "model")
    elif name == "w_dkv":
        base = (fsdp, None)
    elif name in ("w_uk", "w_uv"):
        base = ("model", None, None)
    elif name == "in_proj":  # mamba: shard d_model rows; packed cols stay whole
        base = (fsdp, None)
    elif name == "out_proj":
        base = (None, fsdp)
    elif name in ("x_proj", "dt_proj", "conv_w"):
        base = (None, None)
    elif name == "A_log" and ndim >= 2:
        base = (None, None)
    else:
        # norms, biases, scalars, 1D dynamics params: replicate
        base = tuple(None for _ in range(min(ndim, 1)))
    pad = ndim - len(base)
    if pad < 0:  # scalar or smaller than rule (e.g. unstacked)
        base = base[-ndim:] if ndim else ()
        pad = 0
    return P(*((None,) * pad + tuple(base)))


def param_shardings(mesh, params_shape, cfg: ModelConfig, fsdp_enabled: bool = True):
    """Tree of NamedShardings matching a params (shape-)tree.

    ``fsdp_enabled=False`` replicates weights across the batch axes (pure
    DP+TP): fewer per-layer all-gathers at the cost of per-device weight
    memory — a hillclimb variant for collective-bound cells.
    """
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    fsdp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    if not fsdp_enabled:
        fsdp = None

    def conv(path, leaf):
        names = _names(path)
        # PIM-quantized leaf: codes use the weight rule; scale follows last dim.
        if names and names[-1] == "codes":
            spec = param_spec(names[:-1] or names, leaf.ndim, fsdp)
            return NamedSharding(mesh, spec)
        if names and names[-1] == "scale":
            wspec = param_spec(names[:-1] or names, leaf.ndim, fsdp)
            last = wspec[-1] if len(wspec) else None
            return NamedSharding(mesh, P(*((None,) * (leaf.ndim - 1) + (last,))))
        return NamedSharding(mesh, param_spec(names, leaf.ndim, fsdp))

    return jax.tree_util.tree_map_with_path(conv, params_shape)


def opt_state_shardings(mesh, opt_shape, cfg: ModelConfig, fsdp_enabled: bool = True):
    """Optimizer state: m/v/master follow the param shardings; step replicated.

    Note: even with fsdp_enabled=False for the *params*, optimizer state
    stays FSDP-sharded (ZeRO-1 style) — it is only touched once per step.
    """
    p_shard = {
        k: param_shardings(mesh, v, cfg)
        for k, v in opt_shape.items()
        if k in ("m", "v", "master")
    }
    return {"step": NamedSharding(mesh, P()), **p_shard}


def batch_shardings(mesh, batch_spec_tree):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_axis = dp if len(dp) > 1 else dp[0]

    def conv(leaf):
        spec = (dp_axis,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(conv, batch_spec_tree)


def cache_shardings(mesh, cache_shape, cfg: ModelConfig, shape: ShapeConfig):
    """Decode caches. Leaves have leading stack dims then (B, ...) payload.

    Identified by trailing-dim semantics:
      kv cache k/v: (..., B, S, KV, hd)
      mla cache c/kr: (..., B, S, lora)
      ssm h: (..., B, *state dims), conv: (..., B, K-1, C)
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_axis = dp if len(dp) > 1 else dp[0]
    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    batch_ok = shape.global_batch % n_dp == 0 and shape.global_batch >= n_dp

    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    def conv_kv_scale(leaf):
        nd = leaf.ndim
        kv_heads = leaf.shape[-2]
        kv_div = kv_heads % model_size == 0 and kv_heads >= model_size
        if batch_ok:
            return (None,) * (nd - 3) + (
                (dp_axis, "model", None) if kv_div else (dp_axis, None, "model")
            )
        return (None,) * (nd - 3) + (
            (None, "model", "data") if kv_div else (None, None, ("data", "model"))
        )

    def conv(path, leaf):
        names = _names(path)
        name = names[-1]
        nd = leaf.ndim
        if name in ("k", "v"):  # (..., B, KV, S, hd) — head-major cache
            # KV heads shard over 'model' when they divide it; otherwise use
            # sequence-parallel caches (seq over 'model'): softmax max/denom
            # and the attn@V contraction psum are tiny vs replicating the
            # cache (e.g. llama-90B decode_32k: 86 -> 5.4 GiB/device).
            kv_heads = leaf.shape[-3]
            kv_div = kv_heads % model_size == 0 and kv_heads >= model_size
            if batch_ok:
                spec = (None,) * (nd - 4) + (
                    (dp_axis, "model", None, None) if kv_div
                    else (dp_axis, None, "model", None)
                )
            else:
                spec = (None,) * (nd - 4) + (
                    (None, "model", "data", None) if kv_div
                    else (None, None, ("data", "model"), None)
                )
        elif name in ("k_scale", "v_scale"):  # (..., B, KV, S)
            base = conv_kv_scale(leaf)
            spec = base
        elif name in ("c", "kr"):  # (..., B, S, lora)
            if batch_ok:
                spec = (None,) * (nd - 3) + (dp_axis, None, None)
            else:
                spec = (None,) * (nd - 3) + (None, "data", None)
        elif name == "h":  # ssm state (..., B, d_in/nh, ...)
            spec = ((None,) * (nd - 3)
                    + ((dp_axis,) if batch_ok else (None,)) + ("model", None))
            spec = spec[:nd]
        elif name == "conv":  # (..., B, K-1, C)
            spec = (None,) * (nd - 3) + ((dp_axis if batch_ok else None), None, None)
        else:
            spec = (None,) * nd
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(conv, cache_shape)


def paged_cache_pspecs(cache_shape, cfg: ModelConfig = None,
                       data_axis: str = "data"):
    """Decode-time PartitionSpecs for an ``init_paged_cache`` tree (see the
    module docstring's decode spec table).

    The page-pool leaves are the batch analogue of the dense cache: pages
    belong to live requests, so the pool dim shards over the data axis while
    heads/positions stay whole.  ``block_tables`` replicate — the host
    rewrites them at chunk boundaries and every device must resolve any
    slot's page ids.  Per-slot SSM/conv state batch-shards over data; the
    batch dim of ``h`` sits below a version-dependent payload (mamba1
    ``(B, d_in, N)``, mamba2 ``(B, nh, hd, N)``), so pass ``cfg`` for
    hybrid/ssm trees — without it mamba2 state is assumed.  Leaf ranks
    include any leading layer/group stack dims (left-padded with None, same
    convention as ``param_spec``)."""
    h_payload = 3 if (cfg is not None and cfg.ssm
                      and cfg.ssm.version == 1) else 4

    def _slot_state(nd: int, payload: int):
        lead = max(nd - payload, 0)
        return (None,) * lead + (data_axis,) + (None,) * (nd - lead - 1)

    def conv(path, leaf):
        name = _names(path)[-1]
        nd = leaf.ndim
        if name == "block_tables":
            return P(*(None,) * nd)
        if name in ("k", "v"):  # (..., P, KV, page, D)
            spec = (None,) * (nd - 4) + (data_axis, None, None, None)
        elif name in ("k_scale", "v_scale"):  # (..., P, KV, page)
            spec = (None,) * (nd - 3) + (data_axis, None, None)
        elif name in ("c", "kr"):  # (..., P, page, rank)
            spec = (None,) * (nd - 3) + (data_axis, None, None)
        elif name == "h":  # per-slot state (..., B, *payload)
            spec = _slot_state(nd, h_payload)
        elif name == "conv":  # per-slot state (..., B, K-1, C)
            spec = _slot_state(nd, 3)
        else:
            spec = (None,) * nd
        return P(*spec)

    return jax.tree_util.tree_map_with_path(conv, cache_shape)


def paged_cache_shardings(mesh, cache_shape, cfg: ModelConfig = None,
                          data_axis: str = "data"):
    """``paged_cache_pspecs`` as NamedShardings on ``mesh``, with axes the
    mesh lacks (or that do not divide) dropped via ``sanitize``.

    For callers placing a paged cache on a (data, model) mesh explicitly —
    the serving engines themselves don't call this: their 1-D 'model' mesh
    has no data axis, so their caches replicate via shard_map P() specs,
    which is exactly what this function degenerates to there."""
    axes = set(mesh.axis_names)

    def conv(spec):
        kept = tuple(
            (e if (e is None or e in axes) else None) for e in spec)
        return NamedSharding(mesh, P(*kept))

    specs = paged_cache_pspecs(cache_shape, cfg, data_axis)
    return sanitize(jax.tree.map(conv, specs,
                                 is_leaf=lambda x: isinstance(x, P)),
                    cache_shape)


def replicated(mesh):
    return NamedSharding(mesh, P())


def sanitize(shard_tree, shape_tree):
    """Drop named axes from dims they don't divide evenly.

    pjit requires explicit argument shardings to divide the dims exactly
    (e.g. kv_heads=2 cannot shard over model=16); GSPMD may pad
    *intermediates* but not arguments.  Applied to every sharding tree right
    before lower().
    """

    def fix(sh, leaf):
        if not isinstance(sh, NamedSharding):
            return sh
        mesh = sh.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        out = []
        for dim, names in zip(leaf.shape, spec):
            if names is None:
                out.append(None)
                continue
            group = names if isinstance(names, tuple) else (names,)
            prod = 1
            for a in group:
                prod *= sizes[a]
            out.append(names if dim % prod == 0 and dim >= prod else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, shard_tree, shape_tree)
