"""End-to-end serving driver (batched requests).

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 8 --new-tokens 16 --pim-bits 8
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_reduced
from repro.models import init_params
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--pim-bits", type=int, default=8)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params,
                           max_seq=args.prompt_len + args.new_tokens,
                           pim_bits=args.pim_bits)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    t0 = time.time()
    out = engine.generate(prompts, n_new=args.new_tokens)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, PIM bits={args.pim_bits})")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
