"""Launch layer: production mesh, sharding rules, step builders, dry-run."""
from .mesh import data_axes, make_mesh_from_plan, make_production_mesh

__all__ = ["make_production_mesh", "make_mesh_from_plan", "data_axes"]
