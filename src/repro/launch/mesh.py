"""Production mesh definition (deliverable e).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    """axis_types=Auto where the installed jax has it (>= 0.4.38); older
    jax only has Auto behavior, so no kwarg is needed."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh_from_plan(plan):
    """Mesh for an ElasticPlan (runtime.plan_elastic_remesh)."""
    return jax.make_mesh(
        plan.shape,
        ("pod", "data", "model")[-len(plan.shape):],
        **_axis_kwargs(len(plan.shape)),
    )


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
