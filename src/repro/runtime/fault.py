"""Fault tolerance, straggler mitigation, and elastic scaling.

On a 1000+-node cluster the control plane must answer three questions every
step: *who is alive* (heartbeats), *who is slow* (straggler statistics), and
*what mesh do we run on now* (elastic re-planning).  These are plain-Python
control paths — they run identically under simulation on CPU (tested in
tests/test_runtime.py) and against a real cluster agent, because all device
interaction goes through the injected callbacks.

Recovery contract: training state is (params, opt_state, data step) — all
reconstructable from the CheckpointManager + the stateless data pipeline, so
recovery = restore latest atomic checkpoint, re-plan the mesh over the
surviving hosts, re-lower the step, continue.  That is exactly what
``TrainingSupervisor.run`` implements.

The serving tier reuses the same liveness primitives: the continuous-
batching engine heartbeats a ``HeartbeatMonitor`` every scheduling round,
and ``serving.resilience.ServingSupervisor`` detects crashes through
``sweep``, ``revive``-s the restarted engine, and replays in-flight
requests from the engine's last snapshot — serving state is (request
queue, emitted tokens, draw counters), all host-side and tiny, so its
"checkpoint" is a JSON snapshot rather than a parameter tree.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional


# -------------------------------------------------------------- heartbeat ---
@dataclass
class FailureEvent:
    host: int
    at_step: int
    kind: str  # "dead" | "straggler"


class HeartbeatMonitor:
    """Detects dead hosts from missed heartbeats."""

    def __init__(self, n_hosts: int, timeout_s: float = 30.0, clock=time.monotonic):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self.last_seen = {h: now for h in range(n_hosts)}
        self.dead: set[int] = set()

    def beat(self, host: int) -> None:
        if host not in self.dead:
            self.last_seen[host] = self._clock()

    def sweep(self) -> list[int]:
        """Mark and return newly-dead hosts."""
        now = self._clock()
        newly = [
            h
            for h, t in self.last_seen.items()
            if h not in self.dead and now - t > self.timeout_s
        ]
        self.dead.update(newly)
        return newly

    def revive(self, host: int) -> None:
        """Re-admit a restarted host: clears its dead mark and restarts its
        heartbeat window at now.  Used by the serving supervisor
        (``serving.resilience.ServingSupervisor``), which restarts a
        crashed engine process and replays its in-flight requests — the
        serving analogue of ``TrainingSupervisor``'s restore path."""
        self.dead.discard(host)
        self.last_seen[host] = self._clock()

    @property
    def healthy(self) -> list[int]:
        return [h for h in range(self.n_hosts) if h not in self.dead]


# -------------------------------------------------------------- straggler ---
class StragglerDetector:
    """Flags hosts whose step time exceeds ``factor`` x the fleet median.

    Mitigation at the framework level: flagged hosts are reported to the
    supervisor, which (a) excludes them at the next elastic re-plan, and
    (b) in the meantime relies on within-step overlap (backup-task style
    mitigation belongs to the cluster scheduler; the framework's job is to
    *detect and re-plan*).
    """

    def __init__(self, n_hosts: int, window: int = 16, factor: float = 2.0):
        self.window = window
        self.factor = factor
        self.times: dict[int, deque] = {h: deque(maxlen=window) for h in range(n_hosts)}

    def record(self, host: int, step_time_s: float) -> None:
        self.times[host].append(step_time_s)

    def medians(self) -> dict[int, float]:
        out = {}
        for h, ts in self.times.items():
            if ts:
                s = sorted(ts)
                out[h] = s[len(s) // 2]
        return out

    def stragglers(self) -> list[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        fleet = sorted(med.values())[len(med) // 2]
        return [h for h, m in med.items() if m > self.factor * fleet]


# ----------------------------------------------------------------- elastic --
@dataclass(frozen=True)
class ElasticPlan:
    pods: int
    data: int
    model: int
    hosts_used: int
    batch_scale: float  # fraction of the nominal global batch this mesh carries

    @property
    def shape(self) -> tuple:
        return (self.pods, self.data, self.model) if self.pods > 1 else (self.data, self.model)


def plan_elastic_remesh(
    healthy_hosts: int,
    *,
    model_parallel: int = 16,
    nominal_data: int = 32,  # pods*data at full strength
    hosts_per_device_row: int = 1,
) -> ElasticPlan:
    """Largest power-of-two data extent that fits the surviving hosts.

    The model axis is preserved (changing TP factor would invalidate the
    parameter sharding); elasticity comes from shrinking the data axis and
    rescaling the per-step token budget — the standard elastic-DP design.
    """
    if healthy_hosts < model_parallel * hosts_per_device_row:
        raise RuntimeError(
            f"only {healthy_hosts} hosts healthy; cannot sustain model_parallel={model_parallel}"
        )
    max_rows = healthy_hosts // (model_parallel * hosts_per_device_row)
    data = 2 ** int(math.log2(max_rows))
    data = min(data, nominal_data)
    pods = 1
    if data > 16:  # split across pods in rows of 16
        pods, data = data // 16, 16
    return ElasticPlan(
        pods=pods,
        data=data,
        model=model_parallel,
        hosts_used=pods * data * model_parallel * hosts_per_device_row,
        batch_scale=(pods * data) / nominal_data,
    )


# -------------------------------------------------------------- supervisor --
@dataclass
class ClusterState:
    step: int = 0
    restarts: int = 0
    failures: list = field(default_factory=list)
    plans: list = field(default_factory=list)


class TrainingSupervisor:
    """Drives the train loop with failure recovery + elastic re-planning.

    Injected callbacks keep it runnable in simulation:
      run_step(step, plan) -> step_time_s            (raises on device loss)
      save(step), restore() -> step | None           (checkpoint manager)
      replan(healthy_hosts) -> ElasticPlan
    """

    def __init__(
        self,
        n_hosts: int,
        run_step: Callable,
        save: Callable,
        restore: Callable,
        replan: Callable[[int], ElasticPlan],
        monitor: Optional[HeartbeatMonitor] = None,
        detector: Optional[StragglerDetector] = None,
        ckpt_every: int = 50,
        max_restarts: int = 8,
    ):
        self.monitor = monitor or HeartbeatMonitor(n_hosts)
        self.detector = detector or StragglerDetector(n_hosts)
        self.run_step = run_step
        self.save = save
        self.restore = restore
        self.replan = replan
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.state = ClusterState()

    def run(self, total_steps: int) -> ClusterState:
        st = self.state
        plan = self.replan(len(self.monitor.healthy))
        st.plans.append(plan)
        while st.step < total_steps:
            try:
                dead = self.monitor.sweep()
                if dead:
                    raise RuntimeError(f"hosts died: {dead}")
                t = self.run_step(st.step, plan)
                for h in self.monitor.healthy:
                    self.detector.record(h, t)
                st.step += 1
                if st.step % self.ckpt_every == 0:
                    self.save(st.step)
                slow = self.detector.stragglers()
                if slow:
                    st.failures.append(FailureEvent(slow[0], st.step, "straggler"))
                    plan = self.replan(len(self.monitor.healthy) - len(slow))
                    st.plans.append(plan)
            except RuntimeError as e:
                st.restarts += 1
                if st.restarts > self.max_restarts:
                    raise
                st.failures.append(FailureEvent(-1, st.step, f"dead:{e}"))
                restored = self.restore()
                st.step = restored if restored is not None else 0
                plan = self.replan(len(self.monitor.healthy))
                st.plans.append(plan)
        return st
