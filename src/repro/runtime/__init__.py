"""Distributed runtime: fault tolerance, stragglers, elastic scaling."""
from .fault import (
    ClusterState,
    ElasticPlan,
    FailureEvent,
    HeartbeatMonitor,
    StragglerDetector,
    TrainingSupervisor,
    plan_elastic_remesh,
)

__all__ = [
    "ClusterState", "FailureEvent", "HeartbeatMonitor", "StragglerDetector",
    "ElasticPlan", "plan_elastic_remesh", "TrainingSupervisor",
]
