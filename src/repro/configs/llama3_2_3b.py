"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256. Small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=256, param_dtype="float32",
    )
