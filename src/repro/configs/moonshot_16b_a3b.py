"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) d_ff(expert)=1408
vocab=163840, MoE 64e top-6 + 2 shared (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11264,  # dense first layer (moonlight style)
    vocab=163840,
    n_dense_layers=1,
    rope_theta=50_000.0,
    moe=MoEConfig(n_experts=64, n_shared=2, top_k=6, d_ff_expert=1408),
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, n_dense_layers=1, param_dtype="float32",
        moe=MoEConfig(n_experts=8, n_shared=1, top_k=2, d_ff_expert=32),
    )
