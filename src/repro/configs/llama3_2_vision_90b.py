"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision;
unverified].

The vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (batch, n_image_tokens, d_model).  A cross-attention layer follows
every 4 self-attention layers (20 cross layers in the 100-layer stack).
"""
from .base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    vision=VisionConfig(n_image_tokens=1600, cross_attn_every=5),
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, param_dtype="float32",
        vision=VisionConfig(n_image_tokens=16, cross_attn_every=5),
    )
