"""Architecture configs: one module per assigned architecture + registry."""
from .base import ModelConfig, ShapeConfig, SHAPES, shapes_for
from .registry import ARCH_IDS, all_cells, get_config, get_reduced

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shapes_for",
    "ARCH_IDS", "all_cells", "get_config", "get_reduced",
]
