"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 blocks + a shared attention block [arXiv:2411.15242; hf].

Realisation (DESIGN.md §4): 36 Mamba2 layers scanned in 6 groups of 6, a
single *shared-weight* attention+MLP block applied after each group (Zamba's
parameter-sharing trick), plus 2 trailing Mamba2 layers = 38 SSM layers.
Sub-quadratic (the shared attn block is O(seq^2) only at prefill; decode
state is O(1) SSM + one KV cache), so long_500k runs.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    attn_every=6,
    ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, head_dim=64, version=2, chunk=256),
    sub_quadratic=True,
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, attn_every=2, param_dtype="float32",
        ssm=SSMConfig(state_dim=8, conv_dim=4, expand=2, head_dim=16, version=2, chunk=8),
    )
