"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — Mamba1 architecture [arXiv:2410.05355; unverified].

Attention-free: runs the long_500k shape (sub-quadratic)."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2, version=1, chunk=256),
    sub_quadratic=True,
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab=256, param_dtype="float32",
        ssm=SSMConfig(state_dim=4, conv_dim=4, expand=2, version=1, chunk=16),
    )
