"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig``; every input-shape cell is a
``ShapeConfig``.  ``configs.registry`` maps ``--arch`` ids to configs; each
arch also ships a ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    n_shared: int = 2
    top_k: int = 6
    d_ff_expert: int = 1408
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2
    group_tokens: int = 4096  # dispatch-group size (perf knob)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> d_model // 16 (mamba1 only)
    head_dim: int = 64  # mamba2 only
    chunk: int = 256
    version: int = 1  # 1 = Mamba1 selective scan, 2 = Mamba2 SSD


@dataclass(frozen=True)
class VisionConfig:
    """Modality frontend STUB: input_specs provides precomputed embeddings."""

    n_image_tokens: int = 1600
    cross_attn_every: int = 5  # a cross-attn layer after every N self layers


@dataclass(frozen=True)
class AudioConfig:
    """Audio frontend STUB: precomputed frame embeddings feed the encoder."""

    n_frames: int = 1024


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    vision: Optional[VisionConfig] = None
    audio: Optional[AudioConfig] = None
    dec_layers: int = 0  # encdec only
    n_dense_layers: int = 0  # leading non-MoE layers (deepseek)
    attn_every: int = 0  # hybrid: shared attn block applied every N ssm layers
    sub_quadratic: bool = False  # may run long_500k
    # PIM-mode (the paper's technique): weight bits for serving; 0 = off.
    pim_bits: int = 0
    param_dtype: str = "bfloat16"
    # --- perf knobs (hillclimb variants; defaults = baseline) ---
    kv_chunk: int = 512      # online-softmax KV block size (prefill)
    remat: bool = True       # checkpoint scanned layer bodies
    logits_f32: bool = True  # cross-entropy in f32 (False: bf16 logits)
    act_shard: bool = False  # explicit head-sharding constraints on q/k/v
    kv_cache_bits: int = 16  # 16 = param dtype; 8 = int8 cache + f32 scales

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- sizing ---
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (matches init_params within ~1%)."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            dt_rank = s.dt_rank or d // 16
            per = (
                d * (2 * d_in)  # in_proj (x, z)
                + d_in * s.conv_dim
                + d_in * (dt_rank + 2 * s.state_dim)
                + dt_rank * d_in
                + d_in * s.state_dim  # A
                + d_in * d  # out_proj
            )
            return emb + l * per
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mla:
            m = self.mla
            q_head = m.qk_nope_dim + m.qk_rope_dim
            attn = (
                d * self.n_heads * q_head
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        mlp = 3 * d * self.d_ff
        if self.family == "moe":
            e = self.moe
            moe_mlp = (e.n_experts + e.n_shared) * 3 * d * e.d_ff_expert + d * e.n_experts
            n_moe = l - self.n_dense_layers
            return emb + l * attn + self.n_dense_layers * mlp + n_moe * moe_mlp
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per_ssm = (
                d * (2 * d_in + 2 * s.state_dim * nh + nh)  # in_proj fused (m2)
                + d_in * s.conv_dim
                + nh  # A
                + d_in * d
            ) + 3 * d * self.d_ff
            shared = attn + 3 * d * self.d_ff
            return emb + l * per_ssm + shared
        n_dec = self.dec_layers
        if self.family == "encdec":
            return emb + l * (attn + 2 * d * self.d_ff) + n_dec * (
                2 * attn + 2 * d * self.d_ff
            )
        if self.family == "vlm":
            n_cross = l // (self.vision.cross_attn_every or l)
            return emb + l * (attn + mlp) + n_cross * attn
        return emb + l * (attn + mlp)

    def active_param_count(self) -> int:
        """Active (per-token) parameters: MoE counts top_k + shared experts."""
        if self.family != "moe":
            return self.param_count()
        e = self.moe
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * 2
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mla:
            total = self.param_count()
            full_moe = (l - self.n_dense_layers) * (
                (e.n_experts + e.n_shared) * 3 * d * e.d_ff_expert
            )
            active_moe = (l - self.n_dense_layers) * (
                (e.top_k + e.n_shared) * 3 * d * e.d_ff_expert
            )
            return total - full_moe + active_moe
        mlp_dense = self.n_dense_layers * 3 * d * self.d_ff
        moe_active = (l - self.n_dense_layers) * (
            (e.top_k + e.n_shared) * 3 * d * e.d_ff_expert + d * e.n_experts
        )
        return emb + l * attn + mlp_dense + moe_active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells an arch runs: long_500k only for sub-quadratic archs
    (pure full-attention archs skip it — recorded in DESIGN.md §4)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out
