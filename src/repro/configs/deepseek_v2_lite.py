"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared
[arXiv:2405.04434; hf].

Assignment note (DESIGN.md §4): the shape sheet lists both "64e top-6" and
"2 shared+160 routed"; we implement 64 routed experts (+2 shared), which is
consistent with the 16B total-parameter budget at d_ff_expert=1408.
Layer 0 is a dense-MLP layer (d_ff=10944), the rest are MoE — per the HF
config.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # the single dense layer
    vocab=102400,
    n_dense_layers=1,
    moe=MoEConfig(n_experts=64, n_shared=2, top_k=6, d_ff_expert=1408),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, n_dense_layers=1, param_dtype="float32",
        moe=MoEConfig(n_experts=8, n_shared=1, top_k=2, d_ff_expert=32),
        mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    )
