"""--arch registry: id -> (full config, reduced smoke config)."""
from __future__ import annotations

from . import (
    deepseek_v2_lite,
    falcon_mamba_7b,
    llama3_2_3b,
    llama3_2_vision_90b,
    moonshot_16b_a3b,
    qwen2_1_5b,
    seamless_m4t_medium,
    starcoder2_15b,
    starcoder2_7b,
    zamba2_1_2b,
)
from .base import ModelConfig, ShapeConfig, SHAPES, shapes_for

_MODULES = {
    "zamba2-1.2b": zamba2_1_2b,
    "qwen2-1.5b": qwen2_1_5b,
    "starcoder2-7b": starcoder2_7b,
    "llama3.2-3b": llama3_2_3b,
    "starcoder2-15b": starcoder2_15b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "moonshot-v1-16b-a3b": moonshot_16b_a3b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "llama-3.2-vision-90b": llama3_2_vision_90b,
    "falcon-mamba-7b": falcon_mamba_7b,
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].reduced()


def all_cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    """Every (arch x shape) dry-run cell (32 after documented long_500k skips)."""
    cells = []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in shapes_for(cfg):
            cells.append((cfg, shape))
    return cells


__all__ = ["ARCH_IDS", "get_config", "get_reduced", "all_cells", "SHAPES", "shapes_for"]
