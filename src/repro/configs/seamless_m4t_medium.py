"""seamless-m4t-medium [audio]: enc-dec, 12L enc + 12L dec, d_model=1024
16H d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].

The audio/modality frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (batch, n_frames, d_model) feeding the text/unit encoder
backbone, per the assignment sheet.
"""
from .base import AudioConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="encdec",
    n_layers=12,       # encoder layers
    dec_layers=12,     # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    audio=AudioConfig(n_frames=1024),
    pim_bits=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, dec_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, param_dtype="float32",
        audio=AudioConfig(n_frames=32),
    )
