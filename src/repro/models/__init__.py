"""Model zoo: functional JAX implementations of the assigned architectures."""
from .lm import (
    commit_verify,
    decode_step,
    encode,
    forward,
    init_cache,
    init_paged_cache,
    init_params,
    loss_fn,
    paged_insert,
    prefill,
    tree_relocate,
    verify_step,
)

__all__ = [
    "init_params", "forward", "loss_fn", "init_cache", "decode_step",
    "encode", "prefill", "init_paged_cache", "paged_insert",
    "verify_step", "commit_verify", "tree_relocate",
]
