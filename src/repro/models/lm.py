"""Full model assembly for all six architecture families.

Homogeneous layer stacks are *scanned* (``lax.scan`` over stacked params):
one trace per block type regardless of depth, which keeps HLO size and
compile time bounded for the 100-layer dry-run cells.  Heterogeneous
patterns (hybrid shared-attention, VLM cross-attn groups) scan over repeating
groups.  Every scanned block body is wrapped in ``jax.checkpoint`` so
training remat saves only layer boundaries.

Public API:
  init_params(cfg, key)          -> params pytree
  forward(params, cfg, batch)    -> (logits, aux)      [train / prefill]
  loss_fn(params, cfg, batch)    -> (loss, metrics)
  init_cache(cfg, batch, max_seq)-> cache pytree        [decode]
  decode_step(params, cfg, tokens, cache, pos, extras) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import blocks as bk
from .attention import (
    attn_apply,
    attn_decode,
    attn_decode_paged,
    attn_init,
    attn_prefill,
    attn_verify,
    kv_cache_init,
    paged_kv_cache_init,
    paged_kv_insert,
)
from .common import (
    cross_entropy,
    dtype_of,
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    split_keys,
    unembed,
)


def _stack_init(key, n: int, fn):
    if n == 0:
        return None
    return jax.vmap(fn)(jax.random.split(key, n))


def _stack_cache(cache, n: int):
    return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype), cache)


def _scan(stack, x, body, remat: bool = True):
    b = jax.checkpoint(body) if remat else body

    def f(h, lp):
        out = b(lp, h)
        if isinstance(out, tuple):
            return out
        return out, None

    return jax.lax.scan(f, x, stack)


def _scan_cached(stack, caches, x, body):
    def f(h, xs):
        lp, c = xs
        h, c_new = body(lp, h, c)
        return h, c_new

    return jax.lax.scan(f, x, (stack, caches))


# ---------------------------------------------------------------- init ------
def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_extra, k_head = split_keys(key, 4)
    p: dict[str, Any] = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(k_head, cfg.vocab, cfg.d_model, dtype).T

    fam = cfg.family
    if fam == "dense":
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers, lambda k: bk.dense_block_init(k, cfg, dtype)
        )
    elif fam == "moe":
        nd = cfg.n_dense_layers
        p["dense_layers"] = _stack_init(
            k_extra, nd, lambda k: bk.dense_block_init(k, cfg, dtype, d_ff=cfg.d_ff)
        )
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers - nd, lambda k: bk.moe_block_init(k, cfg, dtype)
        )
    elif fam == "ssm":
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers, lambda k: bk.ssm_block_init(k, cfg, dtype)
        )
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every  # groups of ssm layers
        tail = cfg.n_layers - g * cfg.attn_every
        k1, k2, k3 = split_keys(k_layers, 3)
        p["groups"] = _stack_init(
            k1, g,
            lambda k: _stack_init(k, cfg.attn_every,
                                  lambda kk: bk.ssm_block_init(kk, cfg, dtype)),
        )
        p["tail"] = _stack_init(
            k2, tail, lambda k: bk.ssm_block_init(k, cfg, dtype)
        )
        p["shared_attn"] = bk.dense_block_init(k3, cfg, dtype)  # ONE shared block
    elif fam == "vlm":
        every = cfg.vision.cross_attn_every
        g = cfg.n_layers // every
        k1, k2 = split_keys(k_layers, 2)
        p["groups"] = {
            "self": _stack_init(
                k1, g,
                lambda k: _stack_init(k, every - 1,
                                      lambda kk: bk.dense_block_init(kk, cfg, dtype)),
            ),
            "cross": _stack_init(
                k2, g, lambda k: bk.cross_block_init(k, cfg, dtype)
            ),
        }
    elif fam == "encdec":
        k1, k2 = split_keys(k_layers, 2)
        p["encoder"] = _stack_init(
            k1, cfg.n_layers, lambda k: bk.dense_block_init(k, cfg, dtype)
        )
        p["decoder"] = _stack_init(
            k2, cfg.dec_layers, lambda k: _encdec_dec_block_init(k, cfg, dtype)
        )
    else:
        raise ValueError(fam)
    return p


def _encdec_dec_block_init(key, cfg: ModelConfig, dtype) -> dict:
    ks, kx, km = split_keys(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "self": attn_init(ks, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "cross": attn_init(kx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim, dtype),
        "ln3": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _encdec_dec_block_apply(p, x, enc_out, cfg: ModelConfig):
    h = attn_apply(p["self"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                   n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                   rope_theta=cfg.rope_theta, causal=True)
    x = x + h
    h = attn_apply(p["cross"], rmsnorm(x, p["ln2"], cfg.norm_eps),
                   n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                   rope_theta=0.0, causal=False, kv_input=enc_out)
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln3"], cfg.norm_eps))


# -------------------------------------------------------------- forward -----
def forward(params: dict, cfg: ModelConfig, batch: dict) -> tuple[jnp.ndarray, dict]:
    """Teacher-forced forward. Returns (logits, aux-losses)."""
    aux = {"aux_total": jnp.float32(0.0)}
    fam = cfg.family

    if fam == "encdec":
        enc_x = batch["frames"].astype(dtype_of(cfg.param_dtype))  # audio STUB
        enc_x, _ = _scan(params["encoder"], enc_x,
                         lambda lp, h: bk.dense_block_apply(lp, h, cfg, causal=False))
        x = embed_lookup(params["embed"], batch["dec_tokens"])
        x, _ = _scan(params["decoder"], x,
                     lambda lp, h: _encdec_dec_block_apply(lp, h, enc_x, cfg))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return unembed(x, params.get("head", params["embed"])), aux

    x = embed_lookup(params["embed"], batch["tokens"])

    if fam == "dense":
        x, _ = _scan(params["layers"], x,
                     lambda lp, h: bk.dense_block_apply(lp, h, cfg),
                     remat=cfg.remat)
    elif fam == "moe":
        if params.get("dense_layers") is not None:
            x, _ = _scan(params["dense_layers"], x,
                         lambda lp, h: bk.dense_block_apply(lp, h, cfg))
        x, auxs = _scan(params["layers"], x,
                        lambda lp, h: bk.moe_block_apply(lp, h, cfg))
        aux = {k: jnp.sum(v) for k, v in auxs.items()}
    elif fam == "ssm":
        x, _ = _scan(params["layers"], x,
                     lambda lp, h: bk.ssm_block_apply(lp, h, cfg))
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group_body(gp, h):
            h, _ = _scan(gp, h, lambda lp, hh: bk.ssm_block_apply(lp, hh, cfg),
                         remat=False)
            return bk.dense_block_apply(shared, h, cfg)

        x, _ = _scan(params["groups"], x, group_body)
        if params.get("tail") is not None:
            x, _ = _scan(params["tail"], x,
                         lambda lp, h: bk.ssm_block_apply(lp, h, cfg))
    elif fam == "vlm":
        img = batch["image_embeds"].astype(x.dtype)  # vision STUB

        def group_body(gp, h):
            h, _ = _scan(gp["self"], h,
                         lambda lp, hh: bk.dense_block_apply(lp, hh, cfg),
                         remat=False)
            return bk.cross_block_apply(gp["cross"], h, img, cfg)

        x, _ = _scan(params["groups"], x, group_body)
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed(x, params.get("head", params["embed"])), aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict):
    logits, aux = forward(params, cfg, batch)
    tokens = batch["dec_tokens"] if cfg.family == "encdec" else batch["tokens"]
    labels = batch.get("labels")
    if labels is None:
        logits, labels = logits[:, :-1], tokens[:, 1:]
    if not cfg.logits_f32:
        logits = logits.astype(jnp.bfloat16)
    ce = cross_entropy(logits, labels)
    loss = ce + aux.get("aux_total", 0.0)
    metrics = {"loss": loss, "ce": ce, **{k: v for k, v in aux.items()}}
    return loss, metrics


# -------------------------------------------------------------- prefill -----
def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S)
    cache: dict,
    extras: Optional[dict] = None,
    length=None,  # scalar int32: true prompt length for right-padded prompts
    pages=None,  # (n,) int32 pool page ids: write a paged cache directly
    slot=None,  # scalar int32: per-slot state row (SSM/conv) for paged admit
) -> tuple[jnp.ndarray, dict]:
    """Single-pass prefill: lowers the full-sequence forward ONCE over the
    whole prompt while filling the decode cache for all S positions.

    Replaces S sequential ``decode_step`` calls (the seed hot path): one XLA
    program instead of S Python dispatches, and the prompt's weight reads are
    amortised over S tokens — prefill runs compute-bound while decode stays
    in the paper's memory-bound regime.  ``cache`` must be fresh from
    ``init_cache`` (positions 0..S-1 empty).  Returns (logits (B,S,V), cache).

    ``length`` supports right-padded prompts (the continuous-batching admit
    path pads to a page multiple): causal attention already ignores trailing
    pads for the valid positions' logits and their K/V rows are overwritten
    or masked downstream, but SSM/conv state is sequential — ``length``
    masks pad steps so the carried state equals an unpadded prefill.

    With ``pages``/``slot``, ``cache`` is a PAGED tree (``init_paged_cache``)
    and ``tokens`` must be batch-1 with ``S == len(pages) * page_size``: the
    prompt's K/V (or MLA latents) scatter straight into the slot's pool
    pages and SSM/conv state lands in its per-slot row — the admit half of
    the continuous-batching scheduler without the temporary dense cache
    round-trip that ``models.paged_insert`` needed (paged_insert survives as
    the reference implementation for the equivalence test).
    """
    extras = extras or {}
    fam = cfg.family
    x = embed_lookup(params["embed"], tokens)
    new_cache = dict(cache)

    if fam == "dense":
        x, cs = _scan_cached(
            params["layers"], cache["layers"], x,
            lambda lp, h, c: bk.dense_block_prefill(lp, h, c, cfg, pages=pages),
        )
        new_cache["layers"] = cs
    elif fam == "moe":
        if params.get("dense_layers") is not None:
            x, cs = _scan_cached(
                params["dense_layers"], cache["dense_layers"], x,
                lambda lp, h, c: bk.dense_block_prefill(lp, h, c, cfg,
                                                        pages=pages),
            )
            new_cache["dense_layers"] = cs
        x, cs = _scan_cached(
            params["layers"], cache["layers"], x,
            lambda lp, h, c: bk.moe_block_prefill(lp, h, c, cfg, pages=pages),
        )
        new_cache["layers"] = cs
    elif fam == "ssm":
        x, cs = _scan_cached(
            params["layers"], cache["layers"], x,
            lambda lp, h, c: bk.ssm_block_prefill(lp, h, c, cfg, length=length,
                                                  slot=slot),
        )
        new_cache["layers"] = cs
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def f(h, xs):
            gp, sc, ac = xs
            h, ssm_new = _scan_cached(
                gp, sc, h,
                lambda lp, hh, cc: bk.ssm_block_prefill(lp, hh, cc, cfg,
                                                        length=length,
                                                        slot=slot)
            )
            h, attn_new = bk.dense_block_prefill(shared, h, ac, cfg,
                                                 pages=pages)
            return h, (ssm_new, attn_new)

        x, (ssm_cs, attn_cs) = jax.lax.scan(
            f, x, (params["groups"], cache["groups_ssm"], cache["groups_attn"])
        )
        new_cache["groups_ssm"], new_cache["groups_attn"] = ssm_cs, attn_cs
        if params.get("tail") is not None:
            x, cs = _scan_cached(
                params["tail"], cache["tail"], x,
                lambda lp, h, c: bk.ssm_block_prefill(lp, h, c, cfg,
                                                      length=length,
                                                      slot=slot),
            )
            new_cache["tail"] = cs
    elif fam == "vlm":
        img = extras["image_embeds"].astype(x.dtype)

        def f(h, xs):
            gp, c = xs
            h, cs = _scan_cached(
                gp["self"], c, h,
                lambda lp, hh, cc: bk.dense_block_prefill(lp, hh, cc, cfg,
                                                          pages=pages),
            )
            h = bk.cross_block_apply(gp["cross"], h, img, cfg)
            return h, cs

        x, cs = jax.lax.scan(f, x, (params["groups"], cache["groups_self"]))
        new_cache["groups_self"] = cs
    elif fam == "encdec":
        enc_out = extras["enc_out"].astype(x.dtype)

        def dec_block_prefill(lp, h, c):
            hh, c_new = attn_prefill(
                lp["self"], rmsnorm(h, lp["ln1"], cfg.norm_eps), c,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, pages=pages,
            )
            h = h + hh
            hh = attn_apply(
                lp["cross"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=0.0, causal=False, kv_input=enc_out,
            )
            h = h + hh
            return h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps)), c_new

        x, cs = _scan_cached(params["decoder"], cache["decoder"], x, dec_block_prefill)
        new_cache["decoder"] = cs
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed(x, params.get("head", params["embed"])), new_cache


# --------------------------------------------------------------- decode -----
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    fam = cfg.family
    bits = cfg.kv_cache_bits
    if fam == "dense":
        c = kv_cache_init(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype,
                          bits=bits)
        return {"layers": _stack_cache(c, cfg.n_layers)}
    if fam == "moe":
        if cfg.mla:
            from .mla import mla_cache_init

            c = mla_cache_init(batch, max_seq, cfg.mla, dtype)
        else:
            c = kv_cache_init(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype)
        out = {"layers": _stack_cache(c, cfg.n_layers - cfg.n_dense_layers)}
        if cfg.n_dense_layers:
            cd = kv_cache_init(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype,
                               bits=bits)
            out["dense_layers"] = _stack_cache(cd, cfg.n_dense_layers)
        return out
    if fam == "ssm":
        return {"layers": _stack_cache(bk.ssm_cache_init(cfg, batch), cfg.n_layers)}
    if fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers - g * cfg.attn_every
        ssm_c = bk.ssm_cache_init(cfg, batch)
        attn_c = kv_cache_init(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype,
                               bits=bits)
        out = {
            "groups_ssm": _stack_cache(_stack_cache(ssm_c, cfg.attn_every), g),
            "groups_attn": _stack_cache(attn_c, g),
        }
        if tail:
            out["tail"] = _stack_cache(ssm_c, tail)
        return out
    if fam == "vlm":
        every = cfg.vision.cross_attn_every
        g = cfg.n_layers // every
        c = kv_cache_init(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype,
                          bits=bits)
        return {"groups_self": _stack_cache(_stack_cache(c, every - 1), g)}
    if fam == "encdec":
        c = kv_cache_init(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype,
                          bits=bits)
        return {"decoder": _stack_cache(c, cfg.dec_layers)}
    raise ValueError(fam)


def init_paged_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     num_pages: int, page_size: int) -> dict:
    """Paged decode cache: the attention/MLA sequence state lives in
    per-layer page pools of ``num_pages`` pages of ``page_size`` tokens,
    shared across the ``batch`` slots; SSM/conv state stays per-slot dense
    (it is O(1) per slot — there is nothing to page).

    ``block_tables`` (batch, ceil(max_seq/page_size)) maps each slot's
    logical page i to a pool page id; ``decode_step`` dispatches to the
    paged attention path whenever this key is present.  Page 0 is the trash
    page for inactive slots, so usable capacity is ``num_pages - 1`` pages.
    Structure mirrors ``init_cache`` family-by-family."""
    if max_seq % page_size:
        max_seq += page_size - max_seq % page_size
    width = max_seq // page_size
    dtype = dtype_of(cfg.param_dtype)
    fam = cfg.family
    bits = cfg.kv_cache_bits
    out: dict[str, Any] = {
        "block_tables": jnp.zeros((batch, width), jnp.int32)}
    if fam == "dense":
        c = paged_kv_cache_init(num_pages, page_size, cfg.n_kv_heads,
                                cfg.head_dim, dtype, bits=bits)
        out["layers"] = _stack_cache(c, cfg.n_layers)
    elif fam == "moe":
        if cfg.mla:
            from .mla import mla_paged_cache_init

            c = mla_paged_cache_init(num_pages, page_size, cfg.mla, dtype)
        else:
            c = paged_kv_cache_init(num_pages, page_size, cfg.n_kv_heads,
                                    cfg.head_dim, dtype)
        out["layers"] = _stack_cache(c, cfg.n_layers - cfg.n_dense_layers)
        if cfg.n_dense_layers:
            cd = paged_kv_cache_init(num_pages, page_size, cfg.n_kv_heads,
                                     cfg.head_dim, dtype, bits=bits)
            out["dense_layers"] = _stack_cache(cd, cfg.n_dense_layers)
    elif fam == "ssm":
        out["layers"] = _stack_cache(bk.ssm_cache_init(cfg, batch), cfg.n_layers)
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers - g * cfg.attn_every
        ssm_c = bk.ssm_cache_init(cfg, batch)
        attn_c = paged_kv_cache_init(num_pages, page_size, cfg.n_kv_heads,
                                     cfg.head_dim, dtype, bits=bits)
        out["groups_ssm"] = _stack_cache(_stack_cache(ssm_c, cfg.attn_every), g)
        out["groups_attn"] = _stack_cache(attn_c, g)
        if tail:
            out["tail"] = _stack_cache(ssm_c, tail)
    elif fam == "vlm":
        every = cfg.vision.cross_attn_every
        g = cfg.n_layers // every
        c = paged_kv_cache_init(num_pages, page_size, cfg.n_kv_heads,
                                cfg.head_dim, dtype, bits=bits)
        out["groups_self"] = _stack_cache(_stack_cache(c, every - 1), g)
    elif fam == "encdec":
        c = paged_kv_cache_init(num_pages, page_size, cfg.n_kv_heads,
                                cfg.head_dim, dtype, bits=bits)
        out["decoder"] = _stack_cache(c, cfg.dec_layers)
    else:
        raise ValueError(fam)
    return out


def _copy_slot(paged_tree, dense_tree, slot, lead: int):
    """Copy a batch-1 dense state tree into per-slot state at ``slot``.
    ``lead`` counts leading stack dims before the batch axis."""
    idx = (slice(None),) * lead

    def cp(pt, dt):
        return pt.at[idx + (slot,)].set(dt[idx + (0,)].astype(pt.dtype))

    return jax.tree.map(cp, paged_tree, dense_tree)


def paged_insert(cfg: ModelConfig, paged: dict, dense: dict, slot,
                 pages) -> dict:
    """Insert a freshly prefilled batch-1 dense cache into the paged cache:
    sequence leaves (attention K/V, MLA latents) are scattered into pool
    pages ``pages`` (n,) — the slot's block-table entries — and per-slot
    state leaves (SSM h / conv tail) are copied into row ``slot``.

    No longer on the serving hot path: admit now prefills STRAIGHT into the
    pages (``prefill(pages=, slot=)``).  Kept as the independent reference
    implementation the direct path is checked against byte-for-byte
    (tests/test_sharded_decode.py::test_direct_admit_matches_paged_insert_reference)."""
    fam = cfg.family
    out = dict(paged)
    if fam == "dense":
        out["layers"] = paged_kv_insert(paged["layers"], dense["layers"],
                                        pages, lead=1)
    elif fam == "moe":
        if cfg.mla:
            from .mla import mla_paged_insert

            out["layers"] = mla_paged_insert(paged["layers"], dense["layers"],
                                             pages, lead=1)
        else:
            out["layers"] = paged_kv_insert(paged["layers"], dense["layers"],
                                            pages, lead=1)
        if "dense_layers" in paged:
            out["dense_layers"] = paged_kv_insert(
                paged["dense_layers"], dense["dense_layers"], pages, lead=1)
    elif fam == "ssm":
        out["layers"] = _copy_slot(paged["layers"], dense["layers"], slot,
                                   lead=1)
    elif fam == "hybrid":
        out["groups_ssm"] = _copy_slot(paged["groups_ssm"],
                                       dense["groups_ssm"], slot, lead=2)
        out["groups_attn"] = paged_kv_insert(paged["groups_attn"],
                                             dense["groups_attn"], pages,
                                             lead=1)
        if "tail" in paged:
            out["tail"] = _copy_slot(paged["tail"], dense["tail"], slot,
                                     lead=1)
    elif fam == "vlm":
        out["groups_self"] = paged_kv_insert(paged["groups_self"],
                                             dense["groups_self"], pages,
                                             lead=2)
    elif fam == "encdec":
        out["decoder"] = paged_kv_insert(paged["decoder"], dense["decoder"],
                                         pages, lead=1)
    else:
        raise ValueError(fam)
    return out


def decode_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, 1)
    cache: dict,
    pos: jnp.ndarray,  # scalar int32; paged cache: (B,) per-slot lengths
    extras: Optional[dict] = None,
    page_size: int = 0,
) -> tuple[jnp.ndarray, dict]:
    """One decode step.  With a dense cache (``init_cache``) ``pos`` is a
    scalar shared by the whole batch.  With a paged cache
    (``init_paged_cache`` — detected by its ``block_tables`` key) ``pos`` is
    a per-slot (B,) vector and ``page_size`` must match the pool's page
    size: attention scatters/gathers through the block tables, which is what
    lets the continuous-batching scheduler step slots at different depths in
    one program."""
    extras = extras or {}
    fam = cfg.family
    bt = cache.get("block_tables")
    x = embed_lookup(params["embed"], tokens)
    new_cache = dict(cache)

    if bt is None:
        dense_body = lambda lp, h, c: bk.dense_block_decode(lp, h, c, pos, cfg)
        moe_body = lambda lp, h, c: bk.moe_block_decode(lp, h, c, pos, cfg)
    else:
        dense_body = lambda lp, h, c: bk.dense_block_decode_paged(
            lp, h, c, bt, pos, cfg, page_size)
        moe_body = lambda lp, h, c: bk.moe_block_decode_paged(
            lp, h, c, bt, pos, cfg, page_size)

    if fam == "dense":
        x, cs = _scan_cached(params["layers"], cache["layers"], x, dense_body)
        new_cache["layers"] = cs
    elif fam == "moe":
        if params.get("dense_layers") is not None:
            x, cs = _scan_cached(
                params["dense_layers"], cache["dense_layers"], x, dense_body,
            )
            new_cache["dense_layers"] = cs
        x, cs = _scan_cached(params["layers"], cache["layers"], x, moe_body)
        new_cache["layers"] = cs
    elif fam == "ssm":
        x, cs = _scan_cached(
            params["layers"], cache["layers"], x,
            lambda lp, h, c: bk.ssm_block_decode(lp, h, c, cfg),
        )
        new_cache["layers"] = cs
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group_decode(gp, h, c):
            ssm_c, attn_c = c
            h, ssm_new = _scan_cached(
                gp, ssm_c, h, lambda lp, hh, cc: bk.ssm_block_decode(lp, hh, cc, cfg)
            )
            if bt is None:
                h, attn_new = bk.dense_block_decode(shared, h, attn_c, pos, cfg)
            else:
                h, attn_new = bk.dense_block_decode_paged(
                    shared, h, attn_c, bt, pos, cfg, page_size)
            return h, (ssm_new, attn_new)

        def f(h, xs):
            gp, sc, ac = xs
            h, (sn, an) = group_decode(gp, h, (sc, ac))
            return h, (sn, an)

        x, (ssm_cs, attn_cs) = jax.lax.scan(
            f, x, (params["groups"], cache["groups_ssm"], cache["groups_attn"])
        )
        new_cache["groups_ssm"], new_cache["groups_attn"] = ssm_cs, attn_cs
        if params.get("tail") is not None:
            x, cs = _scan_cached(
                params["tail"], cache["tail"], x,
                lambda lp, h, c: bk.ssm_block_decode(lp, h, c, cfg),
            )
            new_cache["tail"] = cs
    elif fam == "vlm":
        img = extras["image_embeds"].astype(x.dtype)

        def f(h, xs):
            gp, c = xs
            h, cs = _scan_cached(gp["self"], c, h, dense_body)
            h = bk.cross_block_apply(gp["cross"], h, img, cfg)
            return h, cs

        x, cs = jax.lax.scan(f, x, (params["groups"], cache["groups_self"]))
        new_cache["groups_self"] = cs
    elif fam == "encdec":
        enc_out = extras["enc_out"].astype(x.dtype)

        def dec_block_decode(lp, h, c):
            h_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            if bt is None:
                hh, c_new = attn_decode(
                    lp["self"], h_in, c, pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                )
            else:
                hh, c_new = attn_decode_paged(
                    lp["self"], h_in, c, bt, pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                    page_size=page_size,
                )
            h = h + hh
            hh = attn_apply(
                lp["cross"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=0.0, causal=False, kv_input=enc_out,
            )
            h = h + hh
            return h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps)), c_new

        x, cs = _scan_cached(params["decoder"], cache["decoder"], x, dec_block_decode)
        new_cache["decoder"] = cs
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed(x, params.get("head", params["embed"])), new_cache


# ---------------------------------------------------------- verify (spec) ---
def _tree_to_chains(x: jnp.ndarray, fan: int, depth: int) -> jnp.ndarray:
    """Node-order tree window ``(B, 1+fan*depth, ...)`` -> chain batch
    ``(B*fan, 1+depth, ...)``: each candidate chain gets the shared root
    prepended, so per-slot recurrences (SSM/conv state) can run every chain
    as an ordinary sequential verify window."""
    b = x.shape[0]
    root = jnp.broadcast_to(x[:, None, 0:1], (b, fan, 1) + x.shape[2:])
    chains = x[:, 1:].reshape((b, fan, depth) + x.shape[2:])
    return jnp.concatenate([root, chains], axis=2).reshape(
        (b * fan, 1 + depth) + x.shape[2:])


def _chains_to_tree(y: jnp.ndarray, fan: int, depth: int,
                    axis: int = 0) -> jnp.ndarray:
    """Inverse of ``_tree_to_chains`` along ``(axis, axis+1)``: chain batch
    ``(..., B*fan, 1+depth, ...)`` -> node order ``(..., B, 1+fan*depth,
    ...)``.  The root step is identical across a row's chains (same input,
    same starting state), so chain 0's copy stands for node 0."""
    y = jnp.moveaxis(y, (axis, axis + 1), (0, 1))
    b = y.shape[0] // fan
    y = y.reshape((b, fan, 1 + depth) + y.shape[2:])
    out = jnp.concatenate(
        [y[:, 0, 0:1], y[:, :, 1:].reshape((b, fan * depth) + y.shape[3:])],
        axis=1)
    return jnp.moveaxis(out, (0, 1), (axis, axis + 1))


def verify_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, T): last accepted token + T-1 proposed tokens
    cache: dict,
    pos: jnp.ndarray,  # (B,) int32 per-row lengths (tokens already cached)
    extras: Optional[dict] = None,
    page_size: int = 0,
    tree: Optional[tuple[int, int]] = None,
) -> tuple[jnp.ndarray, dict]:
    """Speculative-verify forward: run the target model ONCE over a window
    of T proposed tokens at per-row positions ``pos .. pos+T-1`` against an
    existing decode cache — one weight stream for up to T emitted tokens,
    the multiplier on the paper's weight-bytes-per-token bound.

    Per window position the math matches ``decode_step`` exactly (same
    projections, masks, and float association — the SSM families run the
    sequential per-token recurrence, not the chunked scan), so greedy
    acceptance against these logits reproduces the per-token decode's
    tokens.  The FULL logits matter, not just their argmax: sampled
    speculation (``serving.sampling.rejection_sample``) warps them into
    the target distribution ``p`` that proposals are accepted against, and
    the draft model's own verify-step logits supply the aligned proposal
    distribution ``q`` at the same positions — bit-equality with
    ``decode_step``'s logits is what makes rejection-sampled output
    distributionally identical to plain sampled decode AND
    key-deterministic across the dense/paged engines.

    Returns ``(logits (B,T,V), cache')`` where attention/MLA sequence
    leaves are already written in place for all T positions (rejected
    positions need no rollback: they are never attended by later frontiers
    and the next window rewrites them) and SSM/conv per-slot state leaves
    come back STACKED with a time axis after the batch axis — pass the
    result through ``commit_verify`` with the per-row accepted step to get
    a normal cache back.

    ``tree=(fan, depth)`` verifies a fan-of-chains candidate tree of
    ``T == 1 + fan*depth`` tokens in node order (``attention.tree_layout``):
    attention scores each node against the cached prefix plus its own
    root-path via the shared-prefix mask, and SSM/conv recurrences run each
    chain as an ordinary sequential window (``_tree_to_chains``) so every
    chain's logits are bit-identical to verifying that chain alone.  The
    stacked state time axis and the returned logits stay in node order —
    ``commit_verify`` selects by node index, and the accepted chain's
    attention rows are moved into linear positions by ``tree_relocate``."""
    extras = extras or {}
    fam = cfg.family
    bt = cache.get("block_tables")
    x = embed_lookup(params["embed"], tokens)
    new_cache = dict(cache)

    dense_body = lambda lp, h, c: bk.dense_block_verify(
        lp, h, c, bt, pos, cfg, page_size, tree=tree)
    moe_body = lambda lp, h, c: bk.moe_block_verify(
        lp, h, c, bt, pos, cfg, page_size, tree=tree)
    ssm_body = lambda lp, h, c: bk.ssm_block_verify(lp, h, c, cfg)
    if tree is not None:
        fan, dpt = tree
        tile = lambda c: jax.tree.map(lambda l: jnp.repeat(l, fan, axis=0), c)
        ssm_body = lambda lp, h, c: bk.ssm_block_verify(lp, h, tile(c), cfg)

    def ssm_stack(stack, caches, h):
        """Run an SSM layer stack; in tree mode convert the node-order
        window to per-chain windows around it (states come back stacked
        (L, B, T, ...) in node order either way)."""
        if tree is None:
            return _scan_cached(stack, caches, h, ssm_body)
        hc, cs = _scan_cached(stack, caches, _tree_to_chains(h, fan, dpt),
                              ssm_body)
        return (_chains_to_tree(hc, fan, dpt),
                jax.tree.map(lambda l: _chains_to_tree(l, fan, dpt, axis=1),
                             cs))

    if fam == "dense":
        x, cs = _scan_cached(params["layers"], cache["layers"], x, dense_body)
        new_cache["layers"] = cs
    elif fam == "moe":
        if params.get("dense_layers") is not None:
            x, cs = _scan_cached(
                params["dense_layers"], cache["dense_layers"], x, dense_body,
            )
            new_cache["dense_layers"] = cs
        x, cs = _scan_cached(params["layers"], cache["layers"], x, moe_body)
        new_cache["layers"] = cs
    elif fam == "ssm":
        x, cs = ssm_stack(params["layers"], cache["layers"], x)
        new_cache["layers"] = cs
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def f(h, xs):
            gp, sc, ac = xs
            h, ssm_new = ssm_stack(gp, sc, h)
            h, attn_new = dense_body(shared, h, ac)
            return h, (ssm_new, attn_new)

        x, (ssm_cs, attn_cs) = jax.lax.scan(
            f, x, (params["groups"], cache["groups_ssm"], cache["groups_attn"])
        )
        new_cache["groups_ssm"], new_cache["groups_attn"] = ssm_cs, attn_cs
        if params.get("tail") is not None:
            x, cs = ssm_stack(params["tail"], cache["tail"], x)
            new_cache["tail"] = cs
    elif fam == "vlm":
        img = extras["image_embeds"].astype(x.dtype)

        def f(h, xs):
            gp, c = xs
            h, cs = _scan_cached(gp["self"], c, h, dense_body)
            h = bk.cross_block_apply(gp["cross"], h, img, cfg)
            return h, cs

        x, cs = jax.lax.scan(f, x, (params["groups"], cache["groups_self"]))
        new_cache["groups_self"] = cs
    elif fam == "encdec":
        enc_out = extras["enc_out"].astype(x.dtype)

        def dec_block_verify(lp, h, c):
            hh, c_new = attn_verify(
                lp["self"], rmsnorm(h, lp["ln1"], cfg.norm_eps), c, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                block_tables=bt, page_size=page_size, tree=tree,
            )
            h = h + hh
            hh = attn_apply(
                lp["cross"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=0.0, causal=False, kv_input=enc_out,
            )
            h = h + hh
            return h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps)), c_new

        x, cs = _scan_cached(params["decoder"], cache["decoder"], x,
                             dec_block_verify)
        new_cache["decoder"] = cs
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed(x, params.get("head", params["embed"])), new_cache


def _select_step(tree, sel: jnp.ndarray, lead: int):
    """Select per-row step ``sel`` (B,) from verify-stacked state leaves
    shaped ``lead-dims + (B, T, ...)``, dropping the time axis."""

    def f(leaf):
        ax = lead + 1
        idx = sel.reshape((1,) * lead + (-1, 1) + (1,) * (leaf.ndim - lead - 2))
        picked = jnp.take_along_axis(leaf, idx.astype(jnp.int32), axis=ax)
        return jnp.squeeze(picked, axis=ax)

    return jax.tree.map(f, tree)


def stack_verify_caches(cfg: ModelConfig, caches: list) -> dict:
    """Merge a CHAIN of verify caches (successive windows over consecutive
    positions, each committed into the next) into one verify cache whose
    stacked time axis spans the whole chain: SSM/conv state leaves
    concatenate along their time axis, attention/MLA leaves take the last
    cache's (its in-place writes already accumulate the chain's).  Lets a
    draft's k+1 single-token steps be committed once at any accepted length
    without re-running the window."""
    fam = cfg.family
    out = dict(caches[-1])

    def cat(key, lead):
        return jax.tree.map(
            lambda *ls: jnp.concatenate(ls, axis=lead + 1),
            *[c[key] for c in caches])

    if fam == "ssm":
        out["layers"] = cat("layers", lead=1)
    elif fam == "hybrid":
        out["groups_ssm"] = cat("groups_ssm", lead=2)
        if "tail" in out:
            out["tail"] = cat("tail", lead=1)
    return out


def commit_verify(cfg: ModelConfig, cache: dict, sel: jnp.ndarray) -> dict:
    """Commit a ``verify_step`` cache: per batch row, keep the SSM/conv
    state after step ``sel[b]`` (0-indexed within the verify window — the
    row's accepted length minus one) and drop the stacked time axis.
    Attention/MLA leaves pass through: their rejected positions are rolled
    back implicitly by masking and the next window's rewrites."""
    fam = cfg.family
    out = dict(cache)
    if fam == "ssm":
        out["layers"] = _select_step(cache["layers"], sel, lead=1)
    elif fam == "hybrid":
        out["groups_ssm"] = _select_step(cache["groups_ssm"], sel, lead=2)
        if "tail" in cache:
            out["tail"] = _select_step(cache["tail"], sel, lead=1)
    return out


def _reloc_dense(arr, nlead: int, sax: int, pos, a, cf, depth: int):
    """Move ``a[b]`` rows of a dense sequence leaf ``lead-dims + (B, ...,
    S@sax, ...)`` from chain ``cf[b]``'s tree columns ``pos+1+cf*depth+i``
    to linear columns ``pos+1+i`` (masked scatter, gather-before-scatter so
    chain 0 relocation is the identity)."""
    sh = arr.shape
    x = arr.reshape((-1,) + sh[nlead:])  # (LL, B, ..., S, ...)
    x = jnp.moveaxis(x, sax, 2)  # (LL, B, S, rest...)
    x = jnp.moveaxis(x, 0, 1)  # (B, LL, S, rest...)
    seq = x.shape[2]
    steps = jnp.arange(depth, dtype=pos.dtype)
    src = pos[:, None] + 1 + cf[:, None] * depth + steps[None, :]  # (B, D)
    dst = pos[:, None] + 1 + steps[None, :]

    def one(xb, s_row, d_row, a_b):
        rows = xb[:, jnp.clip(s_row, 0, seq - 1)]  # (LL, D, rest...)
        d_ok = jnp.where(steps < a_b, d_row, seq)  # out-of-range -> dropped
        return xb.at[:, d_ok].set(rows, mode="drop")

    x = jax.vmap(one)(x, src, dst, a)
    x = jnp.moveaxis(x, 1, 0)
    return jnp.moveaxis(x, 2, sax).reshape(sh)


def _reloc_paged(arr, nlead: int, sax: int, bt, pos, a, cf, depth: int,
                 ps: int):
    """Paged-pool variant of ``_reloc_dense``: source/destination columns go
    through the block tables; masked or out-of-store destinations route to
    an out-of-range page and are dropped."""
    sh = arr.shape
    x = arr.reshape((-1,) + sh[nlead:])  # (LL, NP, ...)
    x = jnp.moveaxis(x, sax, 2)  # (LL, NP, ps, rest...)
    npg = x.shape[1]
    w = bt.shape[1]
    steps = jnp.arange(depth, dtype=pos.dtype)
    src = pos[:, None] + 1 + cf[:, None] * depth + steps[None, :]  # (B, D)
    dst = pos[:, None] + 1 + steps[None, :]
    sp = jnp.take_along_axis(bt, jnp.clip(src // ps, 0, w - 1), axis=1)
    sp = jnp.where(src < w * ps, sp, 0)
    dp = jnp.take_along_axis(bt, jnp.clip(dst // ps, 0, w - 1), axis=1)
    dp = jnp.where((steps[None, :] < a[:, None]) & (dst < w * ps), dp, npg)
    rows = x[:, sp, src % ps]  # (LL, B, D, rest...)
    x = x.at[:, dp, dst % ps].set(rows, mode="drop")
    return jnp.moveaxis(x, 2, sax).reshape(sh)


def tree_relocate(cfg: ModelConfig, cache: dict, pos: jnp.ndarray,
                  a: jnp.ndarray, cf: jnp.ndarray, *, fan: int, depth: int,
                  page_size: int = 0) -> dict:
    """After tree verification accepted ``a[b]`` draft tokens from chain
    ``cf[b]``, rewrite the accepted chain's attention/MLA rows from their
    tree columns ``pos+1+cf*depth .. pos+cf*depth+a`` into the linear
    columns ``pos+1 .. pos+a`` the next window's frontier mask expects.
    SSM/conv per-slot state is positionless — ``commit_verify`` with the
    node-order step index already handles it.  Requires the store to be
    over-provisioned by ``fan*depth`` columns past ``max_seq`` so tree
    columns of rows near the cap stay addressable (mirrors the draft-mode
    reserve in the engines)."""
    bt = cache.get("block_tables")
    fam = cfg.family
    out = dict(cache)

    def reloc(sub: dict, nlead: int) -> dict:
        new = {}
        for kk, vv in sub.items():
            sax = -1 if kk.endswith("_scale") else -2
            if bt is None:
                new[kk] = _reloc_dense(vv, nlead, sax, pos, a, cf, depth)
            else:
                new[kk] = _reloc_paged(vv, nlead, sax, bt, pos, a, cf, depth,
                                       page_size)
        return new

    if fam in ("dense", "moe"):
        out["layers"] = reloc(cache["layers"], 1)
        if fam == "moe" and "dense_layers" in cache:
            out["dense_layers"] = reloc(cache["dense_layers"], 1)
    elif fam == "hybrid":
        out["groups_attn"] = reloc(cache["groups_attn"], 1)
    elif fam == "vlm":
        out["groups_self"] = reloc(cache["groups_self"], 2)
    elif fam == "encdec":
        out["decoder"] = reloc(cache["decoder"], 1)
    return out


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Encoder-only pass (enc-dec serving: run once, feed decode_step)."""
    assert cfg.family == "encdec"
    x = frames.astype(dtype_of(cfg.param_dtype))
    x, _ = _scan(params["encoder"], x,
                 lambda lp, h: bk.dense_block_apply(lp, h, cfg, causal=False))
    return x
