"""Shared functional building blocks: init, norms, RoPE, PIM-aware linear."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ------------------------------------------------------------ PIM linear ----
# Decode-shaped (M <= MATVEC_MAX_M rows) quantized matmuls can route through
# the epilogue-fused kernels.pim_matvec instead of the XLA overlay path:
#   "auto"  — dispatch only on real TPU (compiled Mosaic; CPU interpret mode
#             is orders of magnitude slower than XLA, so never auto on CPU)
#   "force" — dispatch everywhere (interpret mode off-TPU; used by tests)
#   "off"   — always use the XLA overlay path
MATVEC_MAX_M = 8
_MATVEC_DISPATCH = "auto"

# Named mesh axis the decode-sharding subsystem (serving.sharded) partitions
# quantized weights over.  A quantized leaf carrying the "tp" marker holds
# only this device's shard of codes/scale along the OUTPUT (last) dim;
# ``linear``/``dq`` must then run inside shard_map over a mesh with this axis.
TP_AXIS = "model"


def set_matvec_dispatch(mode: str) -> str:
    """Set the pim_matvec dispatch mode; returns the previous mode.

    The mode is read at trace time, so cached jitted programs would keep
    their baked-in path — clear the jit caches on a mode change so the next
    call re-traces under the new mode."""
    global _MATVEC_DISPATCH
    if mode not in ("auto", "off", "force"):
        raise ValueError(f"matvec dispatch must be auto|off|force, got {mode!r}")
    prev, _MATVEC_DISPATCH = _MATVEC_DISPATCH, mode
    if prev != mode:
        jax.clear_caches()
    return prev


def _matvec_enabled() -> bool:
    if _MATVEC_DISPATCH == "off":
        return False
    if _MATVEC_DISPATCH == "force":
        return True
    return jax.default_backend() == "tpu"


def _linear_matvec(x: jnp.ndarray, w: dict, b) -> jnp.ndarray:
    """Route a decode-shaped quantized linear through kernels.pim_matvec
    (bias fused into the kernel epilogue — no HBM round-trip)."""
    from repro.kernels.ops import _interpret
    from repro.kernels.pim_matvec import pim_matvec

    bits = 4 if ("nibbles" in w or "nibbles_odd" in w) else 8
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if "nibbles_odd" in w:
        # The packed weight carries one zero pad row (odd true K); a zero
        # activation column keeps the contraction aligned and contributes 0.
        x2 = jnp.pad(x2, ((0, 0), (0, 1)))
    n = w["codes"].shape[-1]
    y = pim_matvec(
        x2, w["codes"], w["scale"].reshape(1, n),
        bits=bits, bias=b, interpret=_interpret(),
    )
    return y.reshape(lead + (n,)).astype(x.dtype)


def linear(x: jnp.ndarray, w, b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Matmul against a dense weight or a PIM-quantized leaf.

    A PIM leaf is ``{"codes": int8 (..., K, N), "scale": f32}`` produced by
    ``serving.quantize_tree``; the dequant happens at the matmul operand (XLA
    fuses it into the producing fusion — the 'overlay' path).  Decode-shaped
    calls (<= MATVEC_MAX_M activation rows, 2-D weight) route through the
    epilogue-fused kernels.pim_matvec (the 'overhaul' path) when the
    dispatch mode allows it — see ``set_matvec_dispatch``.

    A leaf carrying the ``"tp"`` marker (serving.sharded) holds only this
    device's columns: the contraction runs weight-stationary on the local
    shard (full K, N/devices outputs — the matvec kernel dispatch applies
    per-shard), then ONE all-gather of the tiny activation tile along
    ``TP_AXIS`` reassembles the full output.  Gathering output columns is a
    pure concatenation, so sharded decode stays bit-identical to
    single-device decode — a K-sharded psum would reorder the float
    contraction.  The (replicated) bias is added after the gather.
    """
    if isinstance(w, dict) and "codes" in w:
        tp = "tp" in w
        matvec = (w["codes"].ndim == 2 and _matvec_enabled()
                  and math.prod(x.shape[:-1]) <= MATVEC_MAX_M)
        if matvec:
            y = _linear_matvec(x, w, None if tp else b)
            if not tp:
                return y  # bias already fused in the kernel epilogue
        else:
            y = x @ _dq_local(w, x.dtype)
        if tp:
            y = jax.lax.all_gather(y, TP_AXIS, axis=y.ndim - 1, tiled=True)
    else:
        y = x @ w
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def weight_kn(w) -> tuple[int, int]:
    """(K, N) of a dense or PIM-quantized weight leaf."""
    s = weight_shape(w)
    return s[-2], s[-1]


def weight_shape(w) -> tuple:
    if isinstance(w, dict) and "codes" in w:
        s = w["codes"].shape
        if "nibbles" in w:  # int4: two K rows per byte
            return s[:-2] + (2 * s[-2], s[-1])
        if "nibbles_odd" in w:  # int4, odd true K: last byte's high nibble is pad
            return s[:-2] + (2 * s[-2] - 1, s[-1])
        return s
    return w.shape


def dq(w, dtype=None) -> jnp.ndarray:
    """Densify a weight leaf (dequantize PIM codes) for matmul/einsum use.

    Handles nibble-packed int4 ('nibbles' marker): two K rows per byte,
    unpacked with sign extension at the compute boundary.  The
    'nibbles_odd' marker flags an odd true K — the zero pad row added by
    ``serving.quantize_tree`` before packing is dropped after unpack (a
    static slice, so this stays scan/jit-safe).

    A ``"tp"``-marked leaf (serving.sharded) dequantizes its local column
    shard and all-gathers the FULL dense weight along ``TP_AXIS`` — the
    exactness escape hatch for consumers that contract a quantized leaf in
    an einsum instead of ``linear`` (MoE expert stacks, MLA absorbed
    W_uk/W_uv): per-device HBM still streams only the 1/devices shard, and
    the gathered weight is a bit-exact concatenation, so the downstream
    einsum is identical to the single-device one.
    """
    out = _dq_local(w, dtype)
    if isinstance(w, dict) and "tp" in w:
        out = jax.lax.all_gather(out, TP_AXIS, axis=out.ndim - 1, tiled=True)
    return out


def _dq_local(w, dtype=None) -> jnp.ndarray:
    """``dq`` without the tensor-parallel gather: a tp-marked leaf yields its
    local column shard (what ``linear`` contracts before its activation
    all-gather)."""
    if isinstance(w, dict) and "codes" in w:
        codes = w["codes"]
        if "nibbles" in w or "nibbles_odd" in w:
            lo = ((codes & 0xF) ^ 8) - 8
            hi = (((codes >> 4) & 0xF) ^ 8) - 8
            k2 = codes.shape[-2]
            stacked = jnp.stack([lo, hi], axis=-2)  # (..., K//2, 2, N)
            codes = stacked.reshape(codes.shape[:-2] + (2 * k2, codes.shape[-1]))
            if "nibbles_odd" in w:
                codes = codes[..., :-1, :]
        out = codes.astype(w["scale"].dtype) * w["scale"]
        return out.astype(dtype) if dtype is not None else out
    return w.astype(dtype) if dtype is not None else w


# ------------------------------------------------------------------ norms ---
def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * g.astype(x.dtype)


# ------------------------------------------------------------------- RoPE ---
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B?, S, D/2)
    while ang.ndim < x.ndim:
        ang = ang[..., None, :] if ang.ndim == x.ndim - 1 else ang[None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP ----
def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = split_keys(key, 3)
    return {
        "gate": dense_init(kg, (d, d_ff), dtype),
        "up": dense_init(ku, (d, d_ff), dtype),
        "down": dense_init(kd, (d_ff, d), dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP."""
    return linear(jax.nn.silu(linear(x, p["gate"])) * linear(x, p["up"]), p["down"])


# ------------------------------------------------------------- embeddings ---
def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return dense_init(key, (vocab, d), dtype, scale=0.02)


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def unembed(x: jnp.ndarray, table_or_w) -> jnp.ndarray:
    """Logits. ``table_or_w``: (V, D) tied table or (D, V) head weight."""
    if isinstance(table_or_w, dict) and "codes" in table_or_w:
        return linear(x, table_or_w)
    if table_or_w.shape[0] > table_or_w.shape[1]:  # (V, D) tied
        return x @ table_or_w.T
    return x @ table_or_w


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
