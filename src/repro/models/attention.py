"""GQA attention: direct, chunked (online-softmax), decode-with-cache, cross.

Chunked attention scans KV blocks with a running (max, denom, acc) triple so
prefill at 32k+ never materialises the (S x S) score matrix — the pure-JAX
equivalent of flash attention, and the TPU analogue of PiCaSO streaming
partial products through the reduction network instead of buffering them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import apply_rope, dense_init, linear, split_keys

CHUNKED_THRESHOLD = 8192
KV_CHUNK = 512


def _shard_heads(x):
    """Pin (B, S, H, D) activations to batch x head sharding.

    GSPMD propagation sometimes contracts attention over a sharded head_dim
    and all-reduces the S^2 score tensor (309 GB/step on starcoder2-7b
    train_4k — EXPERIMENTS.md §Perf cell B); this constraint forces the
    scores to be computed head-local.  No-op off-mesh.
    """
    from jax.sharding import PartitionSpec as P
    import jax

    try:
        # Requires an enclosing `with mesh:` whose axes include data/model —
        # exactly how launch.steps lowers; plain CPU tests take the except.
        return jax.lax.with_sharding_constraint(x, P("data", None, "model", None))
    except Exception:
        return x


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype,
              bias: bool = False) -> dict:
    kq, kk, kv, ko = split_keys(key, 4)
    p = {
        "wq": dense_init(kq, (d, n_heads * head_dim), dtype),
        "wk": dense_init(kk, (d, n_kv * head_dim), dtype),
        "wv": dense_init(kv, (d, n_kv * head_dim), dtype),
        "wo": dense_init(ko, (n_heads * head_dim, d), dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _split_heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


@functools.lru_cache(maxsize=None)
def tree_layout(fan: int, depth: int):
    """Static layout of a fan-of-chains candidate tree of ``1 + fan*depth``
    nodes in *node order*: node 0 is the shared root (the last accepted
    token), node ``1 + f*depth + i`` is step ``i`` of candidate chain ``f``.

    Returns ``(dep, vis)`` numpy arrays: ``dep[j]`` is node j's logical
    depth (rope position offset from the root), ``vis[q, j]`` is True when
    node j is an ancestor-or-self of query node q — the shared-prefix
    attention mask.  With ``fan == 1`` this degenerates to the linear
    window: ``dep == arange`` and ``vis`` lower-triangular, making the tree
    code path boolean-identical to the plain verify mask."""
    t = 1 + fan * depth
    dep = np.zeros((t,), np.int32)
    vis = np.zeros((t, t), np.bool_)
    vis[:, 0] = True  # the root is every node's ancestor
    for f in range(fan):
        for i in range(depth):
            j = 1 + f * depth + i
            dep[j] = i + 1
            vis[j, 1 + f * depth : j + 1] = True  # own-chain prefix + self
    return dep, vis


def _tree_valid(vis, pos, t: int, store: int):
    """(B, T, S) bool: query node q of the window rooted at per-row ``pos``
    may attend store column c iff c is in the cached prefix (c < pos) or c
    holds a window node on q's root-path (``vis[q, c - pos]``)."""
    rel = jnp.arange(store, dtype=pos.dtype)[None, :] - pos[:, None]  # (B, S)
    inwin = (rel >= 0) & (rel < t)
    vm = jnp.asarray(vis)[:, jnp.clip(rel, 0, t - 1)]  # (T, B, S)
    return (rel < 0)[:, None, :] | (inwin[:, None, :] & jnp.moveaxis(vm, 0, 1))


def _direct_attention(q, k, v, causal: bool, q_offset: int = 0):
    """q: (B,Sq,KV,G,D); k,v: (B,Sk,KV,D)."""
    b, sq, kvh, g, d = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(qi >= ki, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def _chunked_attention(q, k, v, causal: bool, kv_chunk: int = KV_CHUNK):
    """Online-softmax over KV chunks. q: (B,Sq,KV,G,D); k,v: (B,Sk,KV,D)."""
    b, sq, kvh, g, d = q.shape
    sk = k.shape[1]
    c = min(kv_chunk, sk)
    while sk % c:  # fall back to the largest divisor (defensive)
        c -= 1
    n_chunks = sk // c
    kc = k.reshape(b, n_chunks, c, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, c, kvh, d).transpose(1, 0, 2, 3, 4)

    qf = q.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d)
    qi = jnp.arange(sq)[:, None]

    def body(carry, xs):
        m, l, acc = carry
        j, kj, vj = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kj.astype(jnp.float32)) * scale
        if causal:
            ki = j * c + jnp.arange(c)[None, :]
            s = jnp.where(qi >= ki, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # Guard fully-masked rows (all -inf) against NaNs.
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.astype(v.dtype)


def attn_apply(
    p: dict,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 0.0,
    causal: bool = True,
    kv_input: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
    kv_chunk: int = KV_CHUNK,
    act_shard: bool = False,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). Cross-attn if kv_input."""
    b, s, _ = x.shape
    kv_src = x if kv_input is None else kv_input
    q = _split_heads(linear(x, p["wq"], p.get("bq")), n_heads, head_dim)
    k = _split_heads(linear(kv_src, p["wk"], p.get("bk")), n_kv, head_dim)
    v = _split_heads(linear(kv_src, p["wv"], p.get("bv")), n_kv, head_dim)
    if act_shard:
        q, k, v = _shard_heads(q), _shard_heads(k), _shard_heads(v)
    if positions is None:
        positions = jnp.arange(s)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        kpos = jnp.arange(k.shape[1]) if kv_input is not None else positions
        k = apply_rope(k, kpos, rope_theta)
    g = n_heads // n_kv
    qg = q.reshape(b, s, n_kv, g, head_dim)
    # Chunk on KV length: long-KV self-attn streams blocks (online softmax);
    # cross-attn over a short modality memory (e.g. 1600 image tokens) stays
    # direct regardless of query length.
    if k.shape[1] > CHUNKED_THRESHOLD:
        o = _chunked_attention(qg, k, v, causal, kv_chunk=kv_chunk)
    else:
        o = _direct_attention(qg, k, v, causal)
    o = o.reshape(b, s, n_heads * head_dim)
    return linear(o, p["wo"])


# ---------------------------------------------------------------- prefill ---
def attn_prefill(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    cache: dict,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 0.0,
    kv_chunk: int = KV_CHUNK,
    pages: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, dict]:
    """Single-pass prefill: full-sequence causal attention that also writes
    all S prompt tokens' K/V into the preallocated decode cache at once.

    Replaces S sequential ``attn_decode`` calls with one lowered program —
    the host-dispatch overhead the paper's PIM argument says must not
    dominate the memory-bound regime.  Numerics match the per-token path:
    with an int8 cache the prompt attends against the quantize->dequantize
    K/V, i.e. exactly what later decode steps will read back.

    With ``pages`` (n,) the cache is a PAGED pool (``paged_kv_cache_init``
    leaves) and x must be batch-1 with ``S == n * page_size``: the prompt's
    K/V scatter straight into the slot's pool pages — the admit path writes
    pages directly instead of round-tripping a temporary dense cache
    through ``models.paged_insert``.
    """
    b, s, _ = x.shape
    q = _split_heads(linear(x, p["wq"], p.get("bq")), n_heads, head_dim)
    k = _split_heads(linear(x, p["wk"], p.get("bk")), n_kv, head_dim)
    v = _split_heads(linear(x, p["wv"], p.get("bv")), n_kv, head_dim)
    if rope_theta:
        positions = jnp.arange(s)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    k_t = k.transpose(0, 2, 1, 3)  # (B, KV, S, D) — the cache layout
    v_t = v.transpose(0, 2, 1, 3)

    if pages is not None:
        n, ps = pages.shape[0], cache["k"].shape[2]

        def to_pages(t):  # (1, KV, n*ps, ...) -> (n, KV, ps, ...)
            t = t[0].reshape((t.shape[1], n, ps) + t.shape[3:])
            return jnp.moveaxis(t, 1, 0)

        if "k_scale" in cache:
            k_codes, k_sc = _quant_kv(k_t)
            v_codes, v_sc = _quant_kv(v_t)
            new_cache = {
                "k": cache["k"].at[pages].set(to_pages(k_codes)),
                "v": cache["v"].at[pages].set(to_pages(v_codes)),
                "k_scale": cache["k_scale"].at[pages].set(to_pages(k_sc)),
                "v_scale": cache["v_scale"].at[pages].set(to_pages(v_sc)),
            }
            k = (k_codes.astype(x.dtype)
                 * k_sc[..., None].astype(x.dtype)).transpose(0, 2, 1, 3)
            v = (v_codes.astype(x.dtype)
                 * v_sc[..., None].astype(x.dtype)).transpose(0, 2, 1, 3)
        else:
            new_cache = {
                "k": cache["k"].at[pages].set(
                    to_pages(k_t).astype(cache["k"].dtype)),
                "v": cache["v"].at[pages].set(
                    to_pages(v_t).astype(cache["v"].dtype)),
            }
    elif "k_scale" in cache:
        k_codes, k_sc = _quant_kv(k_t)
        v_codes, v_sc = _quant_kv(v_t)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k_codes, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v_codes, (0, 0, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], k_sc, (0, 0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], v_sc, (0, 0, 0)),
        }
        k = (k_codes.astype(x.dtype) * k_sc[..., None].astype(x.dtype)).transpose(0, 2, 1, 3)
        v = (v_codes.astype(x.dtype) * v_sc[..., None].astype(x.dtype)).transpose(0, 2, 1, 3)
    else:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k_t.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v_t.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
    g = n_heads // n_kv
    qg = q.reshape(b, s, n_kv, g, head_dim)
    if k.shape[1] > CHUNKED_THRESHOLD:
        o = _chunked_attention(qg, k, v, causal=True, kv_chunk=kv_chunk)
    else:
        o = _direct_attention(qg, k, v, causal=True)
    o = o.reshape(b, s, n_heads * head_dim)
    return linear(o, p["wo"]), new_cache


# ----------------------------------------------------------------- decode ---
def kv_cache_init(batch: int, max_seq: int, n_kv: int, head_dim: int, dtype,
                  bits: int = 16) -> dict:
    """Head-major cache (B, KV, S, D): the decode contraction then reads the
    cache in its stored layout — the (B,S,KV,D) layout forced two ~1.4 GB
    transpose copies per layer per step on starcoder2-15b decode_32k
    (EXPERIMENTS.md §Perf cell A, iteration 4).

    ``bits=8``: int8 storage + per-token f32 scales — the paper's
    reduced-precision-operand thesis (Fig 7) applied to the decode cache,
    halving cache HBM bytes vs bf16."""
    if bits == 8:
        return {
            "k": jnp.zeros((batch, n_kv, max_seq, head_dim), jnp.int8),
            "v": jnp.zeros((batch, n_kv, max_seq, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, n_kv, max_seq), jnp.float32),
            "v_scale": jnp.zeros((batch, n_kv, max_seq), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, n_kv, max_seq, head_dim), dtype),
        "v": jnp.zeros((batch, n_kv, max_seq, head_dim), dtype),
    }


def _quant_kv(x):
    """(B,KV,1,D) -> int8 codes + per-token scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


# ------------------------------------------------------------ paged cache ---
def paged_kv_cache_init(num_pages: int, page_size: int, n_kv: int,
                        head_dim: int, dtype, bits: int = 16) -> dict:
    """Page-pool KV storage: ``(P, KV, page_size, D)`` instead of the dense
    ``(B, KV, max_seq, D)``.  Page ``i`` of a slot's block table covers that
    slot's positions ``[i*page_size, (i+1)*page_size)``; the pool is shared
    across batch slots, so cache memory scales with live tokens (pages in
    use), not ``B * max_seq``.  Page 0 is reserved as the trash page for
    inactive slots."""
    if bits == 8:
        return {
            "k": jnp.zeros((num_pages, n_kv, page_size, head_dim), jnp.int8),
            "v": jnp.zeros((num_pages, n_kv, page_size, head_dim), jnp.int8),
            "k_scale": jnp.zeros((num_pages, n_kv, page_size), jnp.float32),
            "v_scale": jnp.zeros((num_pages, n_kv, page_size), jnp.float32),
        }
    return {
        "k": jnp.zeros((num_pages, n_kv, page_size, head_dim), dtype),
        "v": jnp.zeros((num_pages, n_kv, page_size, head_dim), dtype),
    }


def paged_kv_insert(pool: dict, dense: dict, pages: jnp.ndarray,
                    lead: int = 0) -> dict:
    """Scatter a batch-1 dense cache (filled by ``attn_prefill``) into pool
    pages ``pages`` (n,).  ``lead`` counts leading stack dims (layer/group
    axes) shared by both trees; the dense seq length must be
    ``n * page_size``."""
    idx = (slice(None),) * lead
    n = pages.shape[0]
    ps = pool["k"].shape[lead + 2]
    out = {}
    for key in ("k", "v"):
        d = dense[key][idx + (0,)]  # lead + (KV, n*ps, D)
        kv, dd = d.shape[lead], d.shape[-1]
        d = d.reshape(d.shape[:lead] + (kv, n, ps, dd))
        d = jnp.moveaxis(d, lead + 1, lead)  # lead + (n, KV, ps, D)
        out[key] = pool[key].at[idx + (pages,)].set(d.astype(pool[key].dtype))
    for key in ("k_scale", "v_scale"):
        if key in pool:
            d = dense[key][idx + (0,)]  # lead + (KV, n*ps)
            kv = d.shape[lead]
            d = d.reshape(d.shape[:lead] + (kv, n, ps))
            d = jnp.moveaxis(d, lead + 1, lead)
            out[key] = pool[key].at[idx + (pages,)].set(d)
    return out


def attn_decode_paged(
    p: dict,
    x: jnp.ndarray,  # (B, 1, D)
    cache: dict,  # paged pool leaves, see paged_kv_cache_init
    block_tables: jnp.ndarray,  # (B, W) int32 page ids
    pos: jnp.ndarray,  # (B,) int32 per-slot lengths
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 0.0,
    page_size: int,
) -> tuple[jnp.ndarray, dict]:
    """One decode step against the paged KV pool: scatter the new token's
    K/V into its slot's current page, gather the slot's pages at the
    contraction.  Per-slot ``pos`` makes every batch slot independent — the
    carry the continuous-batching scheduler steps."""
    b = x.shape[0]
    ps = page_size
    q = _split_heads(linear(x, p["wq"], p.get("bq")), n_heads, head_dim)
    k = _split_heads(linear(x, p["wk"], p.get("bk")), n_kv, head_dim)
    v = _split_heads(linear(x, p["wv"], p.get("bv")), n_kv, head_dim)
    if rope_theta:
        pvec = pos[:, None]  # (B, 1)
        q = apply_rope(q, pvec, rope_theta)
        k = apply_rope(k, pvec, rope_theta)
    k_t = k.transpose(0, 2, 1, 3)  # (B, KV, 1, D)
    v_t = v.transpose(0, 2, 1, 3)
    page = jnp.take_along_axis(block_tables, (pos // ps)[:, None], axis=1)[:, 0]
    off = pos % ps
    quantized = "k_scale" in cache
    new_cache = dict(cache)
    if quantized:
        k_codes, k_sc = _quant_kv(k_t)
        v_codes, v_sc = _quant_kv(v_t)
        new_cache["k"] = cache["k"].at[page, :, off, :].set(k_codes[:, :, 0, :])
        new_cache["v"] = cache["v"].at[page, :, off, :].set(v_codes[:, :, 0, :])
        new_cache["k_scale"] = cache["k_scale"].at[page, :, off].set(k_sc[:, :, 0])
        new_cache["v_scale"] = cache["v_scale"].at[page, :, off].set(v_sc[:, :, 0])
    else:
        new_cache["k"] = cache["k"].at[page, :, off, :].set(
            k_t[:, :, 0, :].astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[page, :, off, :].set(
            v_t[:, :, 0, :].astype(cache["v"].dtype))
    # Gather the slot's pages: (B, W, KV, ps, D) -> (B, KV, W*ps, D) — the
    # same head-major layout the dense contraction reads, just assembled from
    # the block table.  The gather is a transient; only the pool persists.
    w_pages = block_tables.shape[1]
    seq = w_pages * ps

    def gather(pool):
        g = pool[block_tables]  # (B, W, KV, ps, ...)
        g = jnp.moveaxis(g, 1, 2)  # (B, KV, W, ps, ...)
        return g.reshape((b, n_kv, seq) + g.shape[4:])

    if quantized:
        ck = gather(new_cache["k"]).astype(x.dtype) \
            * gather(new_cache["k_scale"])[..., None].astype(x.dtype)
        cv = gather(new_cache["v"]).astype(x.dtype) \
            * gather(new_cache["v_scale"])[..., None].astype(x.dtype)
    else:
        ck = gather(new_cache["k"])
        cv = gather(new_cache["v"])
    g = n_heads // n_kv
    qg = q.reshape(b, 1, n_kv, g, head_dim).astype(ck.dtype)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, ck,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(head_dim)
    valid = jnp.arange(seq)[None, None, None, None, :] \
        <= pos[:, None, None, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bqhgd", w.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    return linear(o, p["wo"]), new_cache


def attn_verify(
    p: dict,
    x: jnp.ndarray,  # (B, T, D)
    cache: dict,
    pos: jnp.ndarray,  # (B,) int32 per-row lengths (tokens already cached)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 0.0,
    block_tables: Optional[jnp.ndarray] = None,
    page_size: int = 0,
    tree: Optional[tuple[int, int]] = None,
) -> tuple[jnp.ndarray, dict]:
    """T-token decode for speculative verification: consume T proposed
    tokens at per-row positions ``pos .. pos+T-1`` against an existing cache
    (dense or paged), causal *within* the window and over the cached prefix.

    ``tree=(fan, depth)`` switches the window to a fan-of-chains candidate
    tree in node order (``T == 1 + fan*depth``, see ``tree_layout``): write
    columns stay ``pos + node``, rope positions become ``pos + dep[node]``,
    and the causal mask is replaced by the shared-prefix ancestor mask, so
    each chain scores exactly as if it were verified alone.

    Per query t the math is exactly ``attn_decode``'s — same projections,
    same f32 score accumulation, same masked softmax over the full store —
    so greedy verification reproduces the per-token path's argmax.  Rollback
    of rejected positions is free by construction: positions ``> pos + a``
    are (1) never attended by later steps, whose masks stop at their own
    frontier, and (2) rewritten by the next verify window, which starts at
    the accepted frontier ``pos + a + 1``.  Writes that would land past the
    store (``pos + t >= max_seq``, only reachable by already-finished rows)
    are dropped (dense) or routed to the trash page (paged)."""
    b, t, _ = x.shape
    q = _split_heads(linear(x, p["wq"], p.get("bq")), n_heads, head_dim)
    k = _split_heads(linear(x, p["wk"], p.get("bk")), n_kv, head_dim)
    v = _split_heads(linear(x, p["wv"], p.get("bv")), n_kv, head_dim)
    posm = pos[:, None] + jnp.arange(t, dtype=pos.dtype)[None, :]  # (B, T)
    if tree is None:
        posr = posm  # linear window: logical position == write column
    else:
        dep, _ = tree_layout(*tree)
        posr = pos[:, None] + jnp.asarray(dep, pos.dtype)[None, :]
    if rope_theta:
        q = apply_rope(q, posr, rope_theta)
        k = apply_rope(k, posr, rope_theta)
    quantized = "k_scale" in cache
    # k/v are already (B, T, KV, D) — the scatter-row layout — and
    # _quant_kv reduces over the last axis, so it applies in place.
    if quantized:
        k_rows, ks_rows = _quant_kv(k)  # (B, T, KV, D), (B, T, KV)
        v_rows, vs_rows = _quant_kv(v)
    else:
        k_rows = k.astype(cache["k"].dtype)
        v_rows = v.astype(cache["v"].dtype)

    new_cache = dict(cache)
    if block_tables is None:
        seq = cache["k"].shape[2]
        rows = jnp.arange(b)[:, None]  # (B, 1) broadcasts with posm
        col = jnp.where(posm < seq, posm, seq)  # out-of-store -> dropped
        new_cache["k"] = cache["k"].at[rows, :, col, :].set(k_rows, mode="drop")
        new_cache["v"] = cache["v"].at[rows, :, col, :].set(v_rows, mode="drop")
        if quantized:
            new_cache["k_scale"] = cache["k_scale"].at[rows, :, col].set(
                ks_rows, mode="drop")
            new_cache["v_scale"] = cache["v_scale"].at[rows, :, col].set(
                vs_rows, mode="drop")
    else:
        ps = page_size
        w_pages = block_tables.shape[1]
        seq = w_pages * ps
        logical = jnp.clip(posm // ps, 0, w_pages - 1)
        page = jnp.take_along_axis(block_tables, logical, axis=1)  # (B, T)
        page = jnp.where(posm < seq, page, 0)  # past the store -> trash page
        off = posm % ps
        new_cache["k"] = cache["k"].at[page, :, off, :].set(k_rows)
        new_cache["v"] = cache["v"].at[page, :, off, :].set(v_rows)
        if quantized:
            new_cache["k_scale"] = cache["k_scale"].at[page, :, off].set(ks_rows)
            new_cache["v_scale"] = cache["v_scale"].at[page, :, off].set(vs_rows)

    if block_tables is None:
        def fetch(key):
            return new_cache[key]
    else:
        def fetch(key):
            g = new_cache[key][block_tables]  # (B, W, KV, ps, ...)
            g = jnp.moveaxis(g, 1, 2)  # (B, KV, W, ps, ...)
            return g.reshape((b, n_kv, seq) + g.shape[4:])

    if quantized:
        ck = fetch("k").astype(x.dtype) * fetch("k_scale")[..., None].astype(x.dtype)
        cv = fetch("v").astype(x.dtype) * fetch("v_scale")[..., None].astype(x.dtype)
    else:
        ck, cv = fetch("k"), fetch("v")
    g = n_heads // n_kv
    qg = q.reshape(b, t, n_kv, g, head_dim).astype(ck.dtype)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, ck,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(head_dim)
    if tree is None:
        # query t's frontier is pos + t: the cached prefix plus the window's
        # earlier tokens and itself — causal across cache and window at once.
        valid = (jnp.arange(ck.shape[2])[None, None, None, None, :]
                 <= posm[:, None, None, :, None])
    else:
        _, vis = tree_layout(*tree)
        valid = _tree_valid(vis, pos, t, ck.shape[2])[:, None, None, :, :]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bqhgd", w.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, t, n_heads * head_dim).astype(x.dtype)
    return linear(o, p["wo"]), new_cache


def attn_decode(
    p: dict,
    x: jnp.ndarray,  # (B, 1, D)
    cache: dict,
    pos: jnp.ndarray,  # scalar int32: current length (tokens already cached)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 0.0,
) -> tuple[jnp.ndarray, dict]:
    """One decode step against a preallocated KV cache."""
    b = x.shape[0]
    q = _split_heads(linear(x, p["wq"], p.get("bq")), n_heads, head_dim)
    k = _split_heads(linear(x, p["wk"], p.get("bk")), n_kv, head_dim)
    v = _split_heads(linear(x, p["wv"], p.get("bv")), n_kv, head_dim)
    if rope_theta:
        pvec = jnp.full((b, 1), pos, jnp.int32)
        q = apply_rope(q, pvec, rope_theta)
        k = apply_rope(k, pvec, rope_theta)
    quantized = "k_scale" in cache
    k_t = k.transpose(0, 2, 1, 3)  # (B,KV,1,D)
    v_t = v.transpose(0, 2, 1, 3)
    new_cache = {}
    if quantized:
        k_codes, k_sc = _quant_kv(k_t)
        v_codes, v_sc = _quant_kv(v_t)
        ck8 = jax.lax.dynamic_update_slice(cache["k"], k_codes, (0, 0, pos, 0))
        cv8 = jax.lax.dynamic_update_slice(cache["v"], v_codes, (0, 0, pos, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], k_sc, (0, 0, pos))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], v_sc, (0, 0, pos))
        new_cache = {"k": ck8, "v": cv8, "k_scale": cks, "v_scale": cvs}
        # dequant at the compute boundary (fuses into the contraction on TPU)
        ck = ck8.astype(x.dtype) * cks[..., None].astype(x.dtype)
        cv = cv8.astype(x.dtype) * cvs[..., None].astype(x.dtype)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k_t.astype(cache["k"].dtype), (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v_t.astype(cache["v"].dtype), (0, 0, pos, 0))
        new_cache = {"k": ck, "v": cv}
    g = n_heads // n_kv
    # Keep the cache in its storage dtype through the contraction: upcasting
    # with .astype(f32) materialised (and all-gathered) a full f32 copy of
    # the 2S-byte cache per step — 2x the HBM + ICI bytes (EXPERIMENTS.md
    # §Perf, starcoder2-15b decode iteration 1).  preferred_element_type
    # keeps the accumulator in f32 without touching operand storage.
    qg = q.reshape(b, 1, n_kv, g, head_dim).astype(ck.dtype)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, ck,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(head_dim)
    valid = jnp.arange(ck.shape[2])[None, None, None, None, :] <= pos
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bqhgd", w.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    return linear(o, p["wo"]), new_cache
