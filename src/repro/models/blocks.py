"""Decoder/encoder block variants assembled from the layer library."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import mamba as mb
from .attention import (
    attn_apply,
    attn_decode,
    attn_decode_paged,
    attn_init,
    attn_prefill,
    attn_verify,
)
from .common import mlp_apply, mlp_init, rmsnorm, rmsnorm_init, split_keys
from .mla import (
    mla_apply,
    mla_decode,
    mla_decode_paged,
    mla_init,
    mla_prefill,
    mla_verify,
)
from .moe import moe_apply, moe_init


# ------------------------------------------------------------ dense block ---
def dense_block_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> dict:
    ka, km = split_keys(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype, bias=cfg.qkv_bias),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(km, cfg.d_model, d_ff or cfg.d_ff, dtype),
    }


def dense_block_apply(p, x, cfg: ModelConfig, causal: bool = True):
    h = attn_apply(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=causal, kv_chunk=cfg.kv_chunk,
        act_shard=cfg.act_shard,
    )
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))


def dense_block_prefill(p, x, cache, cfg: ModelConfig, pages=None):
    """Single-pass prefill: full-seq attention that also fills the KV cache
    (dense, or a paged pool's pages when ``pages`` is given)."""
    h, cache = attn_prefill(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, kv_chunk=cfg.kv_chunk, pages=pages,
    )
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps)), cache


def dense_block_decode(p, x, cache, pos, cfg: ModelConfig):
    h, cache = attn_decode(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, pos,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta,
    )
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps)), cache


def dense_block_decode_paged(p, x, cache, block_tables, pos, cfg: ModelConfig,
                             page_size: int):
    h, cache = attn_decode_paged(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, block_tables,
        pos, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, page_size=page_size,
    )
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps)), cache


def dense_block_verify(p, x, cache, block_tables, pos, cfg: ModelConfig,
                       page_size: int, tree=None):
    """T-token speculative-verify step (dense cache when ``block_tables`` is
    None, paged pool otherwise); ``pos`` is per-row (B,); ``tree=(fan,
    depth)`` verifies a candidate tree (see ``attention.attn_verify``)."""
    h, cache = attn_verify(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, pos,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, block_tables=block_tables,
        page_size=page_size, tree=tree,
    )
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps)), cache


# -------------------------------------------------------------- MoE block ---
def moe_block_init(key, cfg: ModelConfig, dtype) -> dict:
    ka, km = split_keys(key, 2)
    attn = (
        mla_init(ka, cfg.d_model, cfg.n_heads, cfg.mla, dtype)
        if cfg.mla
        else attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, dtype, bias=cfg.qkv_bias)
    )
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn,
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_init(km, cfg.d_model, cfg.moe, dtype),
    }


def moe_block_apply(p, x, cfg: ModelConfig):
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        h = mla_apply(p["attn"], xin, n_heads=cfg.n_heads, m=cfg.mla,
                      rope_theta=cfg.rope_theta)
    else:
        h = attn_apply(p["attn"], xin, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                       head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)
    x = x + h
    y, aux = moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    return x + y, aux


def moe_block_prefill(p, x, cache, cfg: ModelConfig, pages=None):
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        h, cache = mla_prefill(p["attn"], xin, cache, n_heads=cfg.n_heads,
                               m=cfg.mla, rope_theta=cfg.rope_theta,
                               pages=pages)
    else:
        h, cache = attn_prefill(p["attn"], xin, cache, n_heads=cfg.n_heads,
                                n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                                rope_theta=cfg.rope_theta, kv_chunk=cfg.kv_chunk,
                                pages=pages)
    x = x + h
    y, _ = moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    return x + y, cache


def moe_block_decode(p, x, cache, pos, cfg: ModelConfig):
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        h, cache = mla_decode(p["attn"], xin, cache, pos, n_heads=cfg.n_heads,
                              m=cfg.mla, rope_theta=cfg.rope_theta)
    else:
        h, cache = attn_decode(p["attn"], xin, cache, pos, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                               rope_theta=cfg.rope_theta)
    x = x + h
    y, _ = moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    return x + y, cache


def moe_block_decode_paged(p, x, cache, block_tables, pos, cfg: ModelConfig,
                           page_size: int):
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        h, cache = mla_decode_paged(
            p["attn"], xin, cache, block_tables, pos, n_heads=cfg.n_heads,
            m=cfg.mla, rope_theta=cfg.rope_theta, page_size=page_size)
    else:
        h, cache = attn_decode_paged(
            p["attn"], xin, cache, block_tables, pos, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, page_size=page_size)
    x = x + h
    y, _ = moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    return x + y, cache


def moe_block_verify(p, x, cache, block_tables, pos, cfg: ModelConfig,
                     page_size: int, tree=None):
    """T-token speculative-verify step for the MoE block (MLA or GQA
    attention; the expert MLP is per-position, nothing to roll back)."""
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        h, cache = mla_verify(
            p["attn"], xin, cache, pos, n_heads=cfg.n_heads, m=cfg.mla,
            rope_theta=cfg.rope_theta, block_tables=block_tables,
            page_size=page_size, tree=tree)
    else:
        h, cache = attn_verify(
            p["attn"], xin, cache, pos, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, block_tables=block_tables,
            page_size=page_size, tree=tree)
    x = x + h
    y, _ = moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    return x + y, cache


# -------------------------------------------------------------- SSM block ---
def ssm_block_init(key, cfg: ModelConfig, dtype) -> dict:
    init = mb.mamba1_init if cfg.ssm.version == 1 else mb.mamba2_init
    return {"ln": rmsnorm_init(cfg.d_model, dtype),
            "ssm": init(key, cfg.d_model, cfg.ssm, dtype)}


def ssm_block_apply(p, x, cfg: ModelConfig):
    f = mb.mamba1_apply if cfg.ssm.version == 1 else mb.mamba2_apply
    return x + f(p["ssm"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg.ssm)


def ssm_block_prefill(p, x, cache, cfg: ModelConfig, length=None, slot=None):
    """SSM prefill.  With ``slot``, ``cache`` is the PER-SLOT state of the
    paged engine (leading batch dim = slots): the batch-1 prompt runs from a
    zero state and the carried state lands in row ``slot`` directly — the
    SSM half of the direct admit path."""
    f = mb.mamba1_prefill if cfg.ssm.version == 1 else mb.mamba2_prefill
    xin = rmsnorm(x, p["ln"], cfg.norm_eps)
    if slot is None:
        y, cache = f(p["ssm"], xin, cache, cfg.ssm, length=length)
        return x + y, cache
    c1 = jax.tree.map(lambda a: jnp.zeros_like(a[:1]), cache)
    y, c1 = f(p["ssm"], xin, c1, cfg.ssm, length=length)
    cache = jax.tree.map(
        lambda full, one: full.at[slot].set(one[0].astype(full.dtype)),
        cache, c1)
    return x + y, cache


def ssm_block_decode(p, x, cache, cfg: ModelConfig):
    f = mb.mamba1_decode if cfg.ssm.version == 1 else mb.mamba2_decode
    y, cache = f(p["ssm"], rmsnorm(x, p["ln"], cfg.norm_eps), cache, cfg.ssm)
    return x + y, cache


def ssm_block_verify(p, x, cache, cfg: ModelConfig):
    """T-token speculative-verify step: the returned cache leaves are
    stacked (B, T, ...) per-step states (index j = after consuming token j)
    for ``models.commit_verify`` to select the accepted step from."""
    f = mb.mamba1_verify if cfg.ssm.version == 1 else mb.mamba2_verify
    y, cache = f(p["ssm"], rmsnorm(x, p["ln"], cfg.norm_eps), cache, cfg.ssm)
    return x + y, cache


def ssm_cache_init(cfg: ModelConfig, batch: int):
    d_in = cfg.ssm.expand * cfg.d_model
    init = mb.mamba1_cache_init if cfg.ssm.version == 1 else mb.mamba2_cache_init
    return init(batch, d_in, cfg.ssm)


# ------------------------------------------------------------ cross block ---
def cross_block_init(key, cfg: ModelConfig, dtype) -> dict:
    """Gated cross-attention block (llama-vision style)."""
    ka, km = split_keys(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "xattn": attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim, dtype),
        "gate_attn": jnp.zeros((), dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
        "gate_mlp": jnp.zeros((), dtype),
    }


def cross_block_apply(p, x, kv, cfg: ModelConfig):
    h = attn_apply(
        p["xattn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=0.0, causal=False, kv_input=kv,
    )
    x = x + jnp.tanh(p["gate_attn"]) * h
    h = mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x + jnp.tanh(p["gate_mlp"]) * h
