"""Mixture-of-Experts with GShard-style capacity dispatch.

Tokens are grouped (one group per sequence) so the dispatch one-hot stays
``(G, Sg, E, C)`` with G sharded over the data axis; the expert einsum
contracts tokens against experts sharded over the model axis — GSPMD lowers
the resharding to the canonical MoE all-to-all pair.  The top-k *combine* is
a fold-style weighted sum, the same log-tree reduction the paper's OpMux
performs over product terms (kernels.fold_sum provides the in-tile version).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig

from .common import dense_init, dq, linear, split_keys


def moe_init(key, d: int, cfg: MoEConfig, dtype) -> dict:
    kr, kg, ku, kd, ksh = split_keys(key, 5)
    e, f = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(kr, (d, e), jnp.float32),
        "gate": dense_init(kg, (e, d, f), dtype),
        "up": dense_init(ku, (e, d, f), dtype),
        "down": dense_init(kd, (e, f, d), dtype),
    }
    if cfg.n_shared:
        kg2, ku2, kd2 = split_keys(ksh, 3)
        fs = cfg.n_shared * f
        p["shared"] = {
            "gate": dense_init(kg2, (d, fs), dtype),
            "up": dense_init(ku2, (d, fs), dtype),
            "down": dense_init(kd2, (fs, d), dtype),
        }
    return p


def _capacity(sg: int, cfg: MoEConfig) -> int:
    c = int(sg * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


GROUP_TOKENS = 4096  # default dispatch-group size (cfg.group_tokens overrides)


def moe_apply(p: dict, x: jnp.ndarray, cfg: MoEConfig) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (out, aux) with load-balance + z losses.

    Long rows are split into dispatch groups of <= GROUP_TOKENS so the
    capacity C (and the expert-slot waste E*C / (gs*k)) stays constant in
    sequence length — without this, prefill_32k's one-hot is petabyte-scale
    and a 128-token decode batch computes 64 experts at capacity >= top_k
    each (384x waste; see EXPERIMENTS.md §Perf, deepseek decode iteration).
    Groups never span rows: routing (and hence capacity drops) is a
    per-row function, which batched-vs-rowwise parity depends on.
    """
    b0, s0, d = x.shape
    gt = cfg.group_tokens or GROUP_TOKENS
    # One dispatch group per ROW (split only rows longer than the group
    # budget): a token's expert-buffer position and drop decisions then
    # depend on its own row alone, so batched prefill over B rows and B
    # batch-1 admits produce IDENTICAL routing — the other half (with the
    # exact combine below) of dense-vs-paged moe bit-equality.  The old
    # flatten-all-then-split regrouped tokens ACROSS rows, so row 1's
    # tokens landed in buffers already holding row 0's and its capacity
    # drops changed with batch composition (~1e-2 logit swings).
    if s0 > gt:
        n = -(-s0 // gt)  # ceil
        if s0 % n == 0:
            x = x.reshape(b0 * n, s0 // n, d)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # (B,S,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's capacity buffer.
    sel = jax.nn.one_hot(top_i, e, dtype=jnp.float32)  # (B,S,k,E)
    sel_flat = sel.reshape(b, s * k, e)
    pos_in_e = jnp.cumsum(sel_flat, axis=1) - 1.0  # (B, S*k, E)
    pos = jnp.einsum("bte,bte->bt", pos_in_e, sel_flat).reshape(b, s, k)
    keep = (pos < cap).astype(jnp.float32)

    # dispatch (B,S,E,C)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("bske,bskc->bsec", sel, pos_oh)

    xe = jnp.einsum("bsd,bsec->ebcd", x.astype(jnp.float32), disp)  # (E,B,C,D)
    xe = xe.astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, dq(p["gate"], xe.dtype)))
    h = h * jnp.einsum("ebcd,edf->ebcf", xe, dq(p["up"], xe.dtype))
    ye = jnp.einsum("ebcf,efd->ebcd", h, dq(p["down"], h.dtype))  # (E,B,C,D)
    # Exact top-k combine: gather each (token, slot)'s expert output — the
    # (E, C) contraction has <= 1 nonzero per slot, so it is exact in any
    # summation order — then reduce over the fixed top-k axis.  The k-term
    # sum's reduction tree no longer depends on the capacity C, so batched
    # prefill (large dispatch group) and batch-1 admit (small group) produce
    # bit-identical outputs; the old joint (E*C) reduction put the k nonzero
    # products at group-size-dependent offsets, and the resulting ulp drift
    # amplified to ~1e-3 logits across layers (dense-vs-paged moe parity).
    ye_g = jnp.einsum("ebcd,bske,bskc->bskd", ye.astype(jnp.float32), sel,
                      pos_oh)  # (B,S,k,D)
    y = jnp.einsum("bsk,bskd->bsd", top_p, ye_g).astype(x.dtype)

    if "shared" in p:
        sh = p["shared"]
        y = y + linear(jax.nn.silu(linear(x, sh["gate"])) * linear(x, sh["up"]), sh["down"])

    # Aux losses (GShard load-balance + router z-loss).
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(sel.sum(2), axis=(0, 1))  # fraction of tokens per expert
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance": lb, "router_z": z,
           "aux_total": cfg.aux_loss * lb + cfg.router_z_loss * z}
    return y.reshape(b0, s0, d), aux
