"""Mamba1 (selective scan) and Mamba2 (SSD) blocks, chunked for memory.

Both scans process the sequence in chunks: a sequential ``lax.scan`` carries
the SSM state across chunks while the inside of a chunk uses an associative
scan (v1) or the quadratic-in-chunk SSD form (v2).  This bounds the
materialised (tokens x d_inner x state) tensor to one chunk — the same
working-set discipline as a VMEM-resident kernel tile.

Decode paths are single-token recurrences with O(1) state, which is what
makes the long_500k cells runnable for the SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig

from .common import dense_init, linear, split_keys, weight_shape


# ------------------------------------------------------------------ conv ----
def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, state: jnp.ndarray = None,
                 length=None):
    """Depthwise causal conv over seq. x: (B,S,C), w: (C,K). state: (B,K-1,C).

    ``length`` (scalar int32, <= S) returns the conv state as of that many
    real tokens — the tail a right-padded prompt would have produced without
    the pads (positions >= length never enter the carried state)."""
    k = w.shape[-1]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i : i + x.shape[1], :] * w[:, i] for i in range(k))
    if k <= 1:
        new_state = pad
    elif length is None:
        new_state = xp[:, -(k - 1) :, :]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, length, k - 1, axis=1)
    return out, new_state


# ---------------------------------------------------------------- Mamba 1 ---
def mamba1_init(key, d: int, s: SSMConfig, dtype) -> dict:
    d_in = s.expand * d
    dt_rank = s.dt_rank or d // 16
    kin, kconv, kx, kdt, kout = split_keys(key, 5)
    return {
        "in_proj": dense_init(kin, (d, 2 * d_in), dtype),
        "conv_w": dense_init(kconv, (d_in, s.conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(kx, (d_in, dt_rank + 2 * s.state_dim), dtype),
        "dt_proj": dense_init(kdt, (dt_rank, d_in), dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (d_in, s.state_dim))
        ).astype(jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(kout, (d_in, d), dtype),
    }


def _ssm_chunk_scan(dA, dBx, h0):
    """Associative scan within a chunk. dA,dBx: (B,L,C,N) f32; h0: (B,C,N)."""

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    dA0 = jnp.concatenate([jnp.ones_like(dA[:, :1]), dA[:, 1:]], axis=1)
    # fold h0 into the first element: h1 = dA1*h0 + dBx1
    dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
    _, hs = jax.lax.associative_scan(combine, (dA0, dBx), axis=1)
    # hs[t] = prod(dA[1..t]) ... correct recurrence given h0 folded in.
    return hs, hs[:, -1]


def mamba1_apply(p: dict, x: jnp.ndarray, s: SSMConfig) -> jnp.ndarray:
    """Full-sequence Mamba1. x: (B, S, D)."""
    d_in = weight_shape(p["dt_proj"])[1]
    y, _ = mamba1_prefill(p, x, mamba1_cache_init(x.shape[0], d_in, s), s)
    return y


def mamba1_prefill(p: dict, x: jnp.ndarray, cache: dict, s: SSMConfig,
                   length=None):
    """Full-sequence Mamba1 that also returns the decode cache (final SSM
    state + conv tail) — the single-pass prefill form. x: (B, S, D).

    ``length`` (scalar int32) treats positions >= length as right padding:
    masked steps carry dt=0 (state passes through) and the conv tail is
    taken at ``length``, so the returned cache equals an unpadded prefill —
    what the continuous-batching admit path needs for page-aligned prompts."""
    b, seq, d = x.shape
    d_in = weight_shape(p["dt_proj"])[1]
    n = s.state_dim
    chunk = min(s.chunk, seq)
    pad = -seq % chunk

    xz = linear(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = _causal_conv(xs, p["conv_w"], cache["conv"], length=length)
    conv_state = conv_state.astype(cache["conv"].dtype)  # stable scan carry
    xs = jax.nn.silu(xs + p["conv_b"])

    A = -jnp.exp(p["A_log"])  # (d_in, N)

    # Zero-pad S to a chunk multiple (keeps the chunked scan for any prompt
    # length, incl. primes).  Pad steps carry dt=0 via the mask, so dA=1 and
    # dBx=0 — the state passes through them unchanged.
    mask = jnp.ones((b, seq), jnp.float32)
    if length is not None:
        mask = mask * (jnp.arange(seq)[None, :] < length)
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    seq_p = seq + pad

    def chunk_body(h, xs_c):
        """h: (B, d_in, N); xc: (B, L, d_in) conv'd input chunk."""
        xc, mc = xs_c
        dbc = linear(xc, p["x_proj"])
        dt_rank = weight_shape(p["dt_proj"])[0]
        dt = jax.nn.softplus(linear(dbc[..., :dt_rank], p["dt_proj"]) + p["dt_bias"].astype(jnp.float32))
        bmat = dbc[..., dt_rank : dt_rank + n].astype(jnp.float32)  # (B,L,N)
        cmat = dbc[..., dt_rank + n :].astype(jnp.float32)  # (B,L,N)
        dtf = dt.astype(jnp.float32) * mc[..., None]  # (B,L,d_in)
        dA = jnp.exp(dtf[..., None] * A)  # (B,L,d_in,N)
        dBx = (dtf * xc.astype(jnp.float32))[..., None] * bmat[:, :, None, :]
        hs, h_last = _ssm_chunk_scan(dA, dBx, h)
        y = jnp.einsum("blcn,bln->blc", hs, cmat)  # (B,L,d_in)
        y = y + p["D"] * xc.astype(jnp.float32)
        return h_last, y.astype(x.dtype)

    xs_c = xs.reshape(b, seq_p // chunk, chunk, d_in).transpose(1, 0, 2, 3)
    m_c = mask.reshape(b, seq_p // chunk, chunk).transpose(1, 0, 2)
    h_last, ys = jax.lax.scan(chunk_body, cache["h"], (xs_c, m_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b, seq_p, d_in)[:, :seq]
    y = y * jax.nn.silu(z)
    return linear(y, p["out_proj"]), {"h": h_last, "conv": conv_state}


def mamba1_cache_init(batch: int, d_in: int, s: SSMConfig) -> dict:
    return {
        "h": jnp.zeros((batch, d_in, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_dim - 1, d_in), jnp.float32),
    }


def mamba1_decode(p: dict, x: jnp.ndarray, cache: dict, s: SSMConfig):
    """Single-token step. x: (B, 1, D)."""
    n = s.state_dim
    xz = linear(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = _causal_conv(xs, p["conv_w"], cache["conv"])
    xs = jax.nn.silu(xs + p["conv_b"])

    dbc = linear(xs, p["x_proj"])
    dt_rank = weight_shape(p["dt_proj"])[0]
    dt = jax.nn.softplus(linear(dbc[..., :dt_rank], p["dt_proj"]) + p["dt_bias"].astype(jnp.float32))
    bmat = dbc[..., dt_rank : dt_rank + n].astype(jnp.float32)
    cmat = dbc[..., dt_rank + n :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dtf = dt[:, 0].astype(jnp.float32)  # (B, d_in)
    dA = jnp.exp(dtf[..., None] * A)  # (B,d_in,N)
    dBx = (dtf * xs[:, 0].astype(jnp.float32))[..., None] * bmat[:, 0, None, :]
    h = cache["h"] * dA + dBx
    y = jnp.einsum("bcn,bn->bc", h, cmat[:, 0]) + p["D"] * xs[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    return linear(y, p["out_proj"]), {
        "h": h, "conv": conv_state.astype(cache["conv"].dtype)}


def _conv_step_states(xp: jnp.ndarray, t: int, k: int, dtype) -> jnp.ndarray:
    """Per-step conv tails for a T-token verify window.  ``xp`` is the
    padded conv input ``concat([carry, x], axis=1)`` of length ``T + K - 1``;
    the state after consuming token ``j`` is the window ``xp[:, j+1 : j+K]``
    — exactly what ``_causal_conv`` would have carried after j+1 single
    steps.  Returns (B, T, K-1, C); T is small (the speculation window), so
    the static stack is cheap."""
    if k <= 1:
        return jnp.zeros((xp.shape[0], t, 0, xp.shape[2]), dtype)
    return jnp.stack(
        [xp[:, j + 1 : j + k, :] for j in range(t)], axis=1).astype(dtype)


def mamba1_verify(p: dict, x: jnp.ndarray, cache: dict, s: SSMConfig):
    """T-token Mamba1 decode for speculative verification. x: (B, T, D).

    Runs the *per-token* recurrence sequentially over the window (NOT the
    associative chunk scan — same float association as T ``mamba1_decode``
    calls, so greedy verification reproduces the per-token argmax) and
    returns every intermediate state: the cache leaves come back stacked as
    (B, T, ...) where index ``j`` is the state after consuming token ``j``
    — ``models.commit_verify`` selects the accepted step per row."""
    b, t, _ = x.shape
    n = s.state_dim
    k = p["conv_w"].shape[-1]
    xz = linear(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xp = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], axis=1)
    conv_states = _conv_step_states(xp, t, k, cache["conv"].dtype)
    xs, _ = _causal_conv(xs, p["conv_w"], cache["conv"])
    xs = jax.nn.silu(xs + p["conv_b"])

    dbc = linear(xs, p["x_proj"])
    dt_rank = weight_shape(p["dt_proj"])[0]
    dt = jax.nn.softplus(linear(dbc[..., :dt_rank], p["dt_proj"])
                         + p["dt_bias"].astype(jnp.float32))
    bmat = dbc[..., dt_rank : dt_rank + n].astype(jnp.float32)  # (B,T,N)
    cmat = dbc[..., dt_rank + n :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dtf = dt.astype(jnp.float32)  # (B,T,d_in)
    dA = jnp.exp(dtf[..., None] * A)  # (B,T,C,N)
    dBx = (dtf * xs.astype(jnp.float32))[..., None] * bmat[:, :, None, :]

    def step(h, xs_t):
        dA_t, dBx_t = xs_t
        h = h * dA_t + dBx_t
        return h, h

    _, hs = jax.lax.scan(step, cache["h"],
                         (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)  # (B,T,C,N)
    y = jnp.einsum("btcn,btn->btc", hs, cmat) + p["D"] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return linear(y, p["out_proj"]), {"h": hs, "conv": conv_states}


# ---------------------------------------------------------------- Mamba 2 ---
def mamba2_init(key, d: int, s: SSMConfig, dtype) -> dict:
    d_in = s.expand * d
    nh = d_in // s.head_dim
    kin, kconv, kout = split_keys(key, 3)
    # Fused in_proj: [x (d_in), z (d_in), B (N), C (N), dt (nh)]
    return {
        "in_proj": dense_init(kin, (d, 2 * d_in + 2 * s.state_dim + nh), dtype),
        "conv_w": dense_init(kconv, (d_in + 2 * s.state_dim, s.conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_in + 2 * s.state_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(kout, (d_in, d), dtype),
    }


def _ssd_chunk(xh, bmat, cmat, dt_a, h0):
    """One SSD chunk (quadratic-in-chunk form).

    xh: (B,L,H,P) inputs; bmat/cmat: (B,L,N); dt_a: (B,L,H) = dt*A (negative);
    h0: (B,H,P,N) carried state.  Returns (y (B,L,H,P), h_last).
    """
    csum = jnp.cumsum(dt_a, axis=1)  # (B,L,H)
    # intra-chunk: decay from s to t = exp(csum_t - csum_s), t >= s
    diff = csum[:, :, None, :] - csum[:, None, :, :]  # (B,L,L,H)
    l_idx = jnp.arange(dt_a.shape[1])
    mask = l_idx[:, None] >= l_idx[None, :]
    decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bln,bsn->bls", cmat, bmat)  # (B,L,L)
    att = scores[..., None] * decay  # (B,L,L,H)
    y_intra = jnp.einsum("blsh,bshp->blhp", att, xh)
    # inter-chunk: contribution of h0
    dec0 = jnp.exp(csum)  # decay from chunk start to t
    y_inter = jnp.einsum("bln,blh,bhpn->blhp", cmat, dec0, h0)
    # state update: h_last = exp(csum_L) * h0 + sum_s exp(csum_L - csum_s) B_s x_s
    dec_end = jnp.exp(csum[:, -1:, :] - csum)  # (B,L,H)
    h_new = jnp.einsum("bln,blh,blhp->bhpn", bmat, dec_end, xh)
    h_last = jnp.exp(csum[:, -1])[:, :, None, None] * h0 + h_new
    return y_intra + y_inter, h_last


def mamba2_apply(p: dict, x: jnp.ndarray, s: SSMConfig) -> jnp.ndarray:
    """Full-sequence Mamba2 (SSD). x: (B, S, D)."""
    d_in = weight_shape(p["out_proj"])[0]
    y, _ = mamba2_prefill(p, x, mamba2_cache_init(x.shape[0], d_in, s), s)
    return y


def mamba2_prefill(p: dict, x: jnp.ndarray, cache: dict, s: SSMConfig,
                   length=None):
    """Full-sequence SSD that also returns the decode cache (final state +
    conv tail) — the single-pass prefill form. x: (B, S, D).

    ``length`` masks positions >= length as right padding (dt_a=0 so decay
    is 1, xh=0 so no state contribution, conv tail taken at ``length``) —
    the cache then matches an unpadded prefill exactly."""
    b, seq, d = x.shape
    d_in = weight_shape(p["out_proj"])[0]
    nh = p["A_log"].shape[0]
    hd = d_in // nh
    n = s.state_dim
    chunk = min(s.chunk, seq)
    pad = -seq % chunk

    zxbcdt = linear(x, p["in_proj"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * n :]  # (B,S,nh)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], cache["conv"], length=length)
    conv_state = conv_state.astype(cache["conv"].dtype)  # stable scan carry
    xbc = jax.nn.silu(xbc + p["conv_b"])
    xs, bmat, cmat = (
        xbc[..., :d_in],
        xbc[..., d_in : d_in + n].astype(jnp.float32),
        xbc[..., d_in + n :].astype(jnp.float32),
    )
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    a = -jnp.exp(p["A_log"])  # (nh,)
    dt_a = dt * a  # (B,S,nh), negative

    xh = xs.reshape(b, seq, nh, hd).astype(jnp.float32)
    if length is not None:
        valid = (jnp.arange(seq) < length).astype(jnp.float32)
        dt_a = dt_a * valid[None, :, None]
        xh = xh * valid[None, :, None, None]

    # Zero-pad S to a chunk multiple (keeps the chunked SSD path for any
    # prompt length).  Pad steps have dt_a=0 (decay exp(0)=1) and xh=0 (no
    # state contribution), so the carried state passes through unchanged.
    if pad:
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
    else:
        xh_p = xh
    seq_p = seq + pad
    n_chunks = seq_p // chunk

    def body(h, xs_c):
        xh_c, b_c, c_c, dta_c = xs_c
        y, h_last = _ssd_chunk(xh_c, b_c, c_c, dta_c, h)
        return h_last, y

    xh_cs = xh_p.reshape(b, n_chunks, chunk, nh, hd).transpose(1, 0, 2, 3, 4)
    b_cs = bmat.reshape(b, n_chunks, chunk, n).transpose(1, 0, 2, 3)
    c_cs = cmat.reshape(b, n_chunks, chunk, n).transpose(1, 0, 2, 3)
    dta_cs = dt_a.reshape(b, n_chunks, chunk, nh).transpose(1, 0, 2, 3)
    h_last, ys = jax.lax.scan(body, cache["h"], (xh_cs, b_cs, c_cs, dta_cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, seq_p, nh, hd)[:, :seq]
    y = y + p["D"][:, None] * xh
    y = y.reshape(b, seq, d_in).astype(x.dtype) * jax.nn.silu(z)
    return linear(y, p["out_proj"]), {"h": h_last, "conv": conv_state}


def mamba2_cache_init(batch: int, d_in: int, s: SSMConfig) -> dict:
    nh = d_in // s.head_dim
    return {
        "h": jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_dim - 1, d_in + 2 * s.state_dim), jnp.float32),
    }


def mamba2_verify(p: dict, x: jnp.ndarray, cache: dict, s: SSMConfig):
    """T-token SSD decode for speculative verification. x: (B, T, D).

    Sequential per-token recurrence (same float association as T
    ``mamba2_decode`` calls); cache leaves return stacked as (B, T, ...),
    index ``j`` = state after consuming token ``j`` (see
    ``mamba1_verify``)."""
    b, t, _ = x.shape
    d_in = weight_shape(p["out_proj"])[0]
    nh = p["A_log"].shape[0]
    hd = d_in // nh
    n = s.state_dim
    k = p["conv_w"].shape[-1]
    zxbcdt = linear(x, p["in_proj"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * n :]
    xp = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    conv_states = _conv_step_states(xp, t, k, cache["conv"].dtype)
    xbc, _ = _causal_conv(xbc, p["conv_w"], cache["conv"])
    xbc = jax.nn.silu(xbc + p["conv_b"])
    xs, bmat, cmat = (
        xbc[..., :d_in],
        xbc[..., d_in : d_in + n].astype(jnp.float32),
        xbc[..., d_in + n :].astype(jnp.float32),
    )
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,nh)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (B,T,nh)
    xh = xs.reshape(b, t, nh, hd).astype(jnp.float32)
    dbx = jnp.einsum("bth,bthp,btn->bthpn", dt, xh, bmat)

    def step(h, xs_t):
        decay_t, dbx_t = xs_t
        h = h * decay_t[..., None, None] + dbx_t
        return h, h

    _, hs = jax.lax.scan(step, cache["h"],
                         (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(dbx, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)  # (B,T,nh,hd,sd)
    y = jnp.einsum("bthpn,btn->bthp", hs, cmat) + p["D"][:, None] * xh
    y = y.reshape(b, t, d_in).astype(x.dtype) * jax.nn.silu(z)
    return linear(y, p["out_proj"]), {"h": hs, "conv": conv_states}


def mamba2_decode(p: dict, x: jnp.ndarray, cache: dict, s: SSMConfig):
    """Single-token SSD step. x: (B, 1, D)."""
    b = x.shape[0]
    d_in = weight_shape(p["out_proj"])[0]
    nh = p["A_log"].shape[0]
    hd = d_in // nh
    n = s.state_dim
    zxbcdt = linear(x, p["in_proj"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * n :]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], cache["conv"])
    xbc = jax.nn.silu(xbc + p["conv_b"])
    xs, bmat, cmat = (
        xbc[..., :d_in],
        xbc[..., d_in : d_in + n].astype(jnp.float32),
        xbc[..., d_in + n :].astype(jnp.float32),
    )
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (B,nh)
    xh = xs[:, 0].reshape(b, nh, hd).astype(jnp.float32)
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bmat[:, 0])
    h = cache["h"] * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", h, cmat[:, 0]) + p["D"][:, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    return linear(y, p["out_proj"]), {
        "h": h, "conv": conv_state.astype(cache["conv"].dtype)}
