"""Multi-head Latent Attention (DeepSeek-V2).

Prefill path materialises per-head K/V from the compressed latent; the decode
path uses the *absorbed* form: W_uk folds into the query and W_uv into the
output so the KV cache stores only (kv_lora_rank + qk_rope_dim) per token —
MLA's whole point, and on TPU a direct HBM-bandwidth win in the decode
roofline (the same storage-efficiency argument as the paper's Fig 7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig

from .attention import _tree_valid, tree_layout
from .common import apply_rope, dense_init, dq, linear, split_keys


def mla_init(key, d: int, n_heads: int, m: MLAConfig, dtype) -> dict:
    kq, kkv, kuk, kuv, ko, kr = split_keys(key, 6)
    qh = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": dense_init(kq, (d, n_heads * qh), dtype),
        "w_dkv": dense_init(kkv, (d, m.kv_lora_rank + m.qk_rope_dim), dtype),
        "w_uk": dense_init(kuk, (n_heads, m.kv_lora_rank, m.qk_nope_dim), dtype),
        "w_uv": dense_init(kuv, (n_heads, m.kv_lora_rank, m.v_head_dim), dtype),
        "wo": dense_init(ko, (n_heads * m.v_head_dim, d), dtype),
    }


def mla_apply(p, x, *, n_heads: int, m: MLAConfig, rope_theta: float) -> jnp.ndarray:
    """Training/prefill: expand the latent into per-head K/V.

    Delegates to ``mla_prefill`` with a throwaway zero cache — the unused
    cache writes are dead code XLA eliminates, so apply and prefill can
    never drift numerically."""
    b, s, _ = x.shape
    cache = mla_cache_init(b, s, m, x.dtype)
    y, _ = mla_prefill(p, x, cache, n_heads=n_heads, m=m, rope_theta=rope_theta)
    return y


# ---------------------------------------------------------------- prefill ---
def mla_prefill(p, x, cache, *, n_heads: int, m: MLAConfig, rope_theta: float,
                pages=None):
    """Single-pass prefill: full-sequence MLA that also fills the latent
    cache for all S prompt positions at once (rope-applied ``kr``, raw ``c``
    — the exact storage ``mla_decode`` reads back).

    With ``pages`` (n,) the cache is a paged latent pool
    (``mla_paged_cache_init``) and x must be batch-1 with
    ``S == n * page_size``: the latents scatter straight into the slot's
    pool pages (the direct admit path — no dense round-trip)."""
    b, s, _ = x.shape
    qh = m.qk_nope_dim + m.qk_rope_dim
    q = linear(x, p["wq"]).reshape(b, s, n_heads, qh)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    ckv = linear(x, p["w_dkv"])
    c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    pos = jnp.arange(s)
    q_rope = apply_rope(q_rope, pos, rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, rope_theta)  # (B,S,1,rope)

    if pages is not None:
        n, ps = pages.shape[0], cache["c"].shape[1]
        new_cache = {
            "c": cache["c"].at[pages].set(
                c[0].reshape(n, ps, -1).astype(cache["c"].dtype)),
            "kr": cache["kr"].at[pages].set(
                k_rope[0, :, 0, :].reshape(n, ps, -1).astype(cache["kr"].dtype)),
        }
    else:
        new_cache = {
            "c": jax.lax.dynamic_update_slice(
                cache["c"], c.astype(cache["c"].dtype), (0, 0, 0)),
            "kr": jax.lax.dynamic_update_slice(
                cache["kr"], k_rope[:, :, 0, :].astype(cache["kr"].dtype), (0, 0, 0)),
        }

    k_nope = jnp.einsum("bsc,hcd->bshd", c, dq(p["w_uk"], c.dtype))
    v = jnp.einsum("bsc,hcd->bshd", c, dq(p["w_uv"], c.dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, m.qk_rope_dim))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    scale = 1.0 / jnp.sqrt(qh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q_full, k).astype(jnp.float32) * scale
    qi, ki = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    scores = jnp.where(qi >= ki, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, -1)
    return linear(o, p["wo"]), new_cache


# ----------------------------------------------------------------- decode ---
def mla_cache_init(batch: int, max_seq: int, m: MLAConfig, dtype) -> dict:
    """Latent cache: only (kv_lora + rope_dim) per token."""
    return {"c": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_seq, m.qk_rope_dim), dtype)}


def mla_paged_cache_init(num_pages: int, page_size: int, m: MLAConfig,
                         dtype) -> dict:
    """Paged latent cache: ``(P, page_size, lora/rope)`` pools shared across
    batch slots (see attention.paged_kv_cache_init for the page discipline)."""
    return {"c": jnp.zeros((num_pages, page_size, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((num_pages, page_size, m.qk_rope_dim), dtype)}


def mla_paged_insert(pool: dict, dense: dict, pages: jnp.ndarray,
                     lead: int = 0) -> dict:
    """Scatter a batch-1 dense latent cache into pool pages ``pages`` (n,)."""
    idx = (slice(None),) * lead
    n = pages.shape[0]
    ps = pool["c"].shape[lead + 1]
    out = {}
    for key in ("c", "kr"):
        d = dense[key][idx + (0,)]  # lead + (n*ps, dim)
        d = d.reshape(d.shape[:lead] + (n, ps, d.shape[-1]))
        out[key] = pool[key].at[idx + (pages,)].set(d.astype(pool[key].dtype))
    return out


def mla_decode_paged(p, x, cache, block_tables, pos, *, n_heads: int,
                     m: MLAConfig, rope_theta: float, page_size: int):
    """Absorbed decode against the paged latent pool: scatter the new
    latent/rope rows into the slot's current page, gather pages at the
    score contraction.  ``pos`` is per-slot (B,)."""
    b = x.shape[0]
    ps = page_size
    qh = m.qk_nope_dim + m.qk_rope_dim
    q = linear(x, p["wq"]).reshape(b, 1, n_heads, qh)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    pvec = pos[:, None]
    q_rope = apply_rope(q_rope, pvec, rope_theta)
    q_lat = jnp.einsum("bqhd,hcd->bqhc", q_nope, dq(p["w_uk"], q_nope.dtype))

    ckv = linear(x, p["w_dkv"])
    c_new, kr_new = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    kr_new = apply_rope(kr_new[:, :, None, :], pvec, rope_theta)[:, :, 0, :]
    page = jnp.take_along_axis(block_tables, (pos // ps)[:, None], axis=1)[:, 0]
    off = pos % ps
    cc_pool = cache["c"].at[page, off, :].set(c_new[:, 0].astype(cache["c"].dtype))
    ckr_pool = cache["kr"].at[page, off, :].set(kr_new[:, 0].astype(cache["kr"].dtype))

    seq = block_tables.shape[1] * ps
    cc = cc_pool[block_tables].reshape(b, seq, m.kv_lora_rank)
    ckr = ckr_pool[block_tables].reshape(b, seq, m.qk_rope_dim)

    scale = 1.0 / jnp.sqrt(qh)
    s_lat = jnp.einsum("bqhc,bkc->bhqk", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32), ckr.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    valid = jnp.arange(seq)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqk,bkc->bqhc", w, cc.astype(jnp.float32))
    o = jnp.einsum("bqhc,hcd->bqhd", o_lat, dq(p["w_uv"], jnp.float32))
    o = o.reshape(b, 1, -1).astype(x.dtype)
    return linear(o, p["wo"]), {"c": cc_pool, "kr": ckr_pool}


def mla_verify(p, x, cache, pos, *, n_heads: int, m: MLAConfig,
               rope_theta: float, block_tables=None, page_size: int = 0,
               tree=None):
    """T-token absorbed decode for speculative verification (per-row ``pos``
    (B,), dense latent cache or paged pool — see ``attention.attn_verify``
    for the window/rollback discipline).  Per query the math is exactly
    ``mla_decode``'s absorbed form, so greedy verification reproduces the
    per-token argmax.  ``tree=(fan, depth)`` verifies a fan-of-chains
    candidate tree in node order: write columns stay ``pos + node``, rope
    positions become ``pos + dep[node]`` and the causal mask becomes the
    shared-prefix ancestor mask (``attention.tree_layout``)."""
    b, t, _ = x.shape
    qh = m.qk_nope_dim + m.qk_rope_dim
    q = linear(x, p["wq"]).reshape(b, t, n_heads, qh)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    posm = pos[:, None] + jnp.arange(t, dtype=pos.dtype)[None, :]  # (B, T)
    if tree is None:
        posr = posm  # linear window: logical position == write column
    else:
        dep, _ = tree_layout(*tree)
        posr = pos[:, None] + jnp.asarray(dep, pos.dtype)[None, :]
    q_rope = apply_rope(q_rope, posr, rope_theta)
    q_lat = jnp.einsum("bqhd,hcd->bqhc", q_nope, dq(p["w_uk"], q_nope.dtype))

    ckv = linear(x, p["w_dkv"])
    c_new, kr_new = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    kr_new = apply_rope(kr_new[:, :, None, :], posr, rope_theta)[:, :, 0, :]
    if block_tables is None:
        seq = cache["c"].shape[1]
        rows = jnp.arange(b)[:, None]
        col = jnp.where(posm < seq, posm, seq)  # out-of-store -> dropped
        cc_pool = cache["c"].at[rows, col, :].set(
            c_new.astype(cache["c"].dtype), mode="drop")
        ckr_pool = cache["kr"].at[rows, col, :].set(
            kr_new.astype(cache["kr"].dtype), mode="drop")
        cc, ckr = cc_pool, ckr_pool
    else:
        ps = page_size
        w_pages = block_tables.shape[1]
        seq = w_pages * ps
        logical = jnp.clip(posm // ps, 0, w_pages - 1)
        page = jnp.take_along_axis(block_tables, logical, axis=1)
        page = jnp.where(posm < seq, page, 0)  # past the store -> trash page
        off = posm % ps
        cc_pool = cache["c"].at[page, off, :].set(c_new.astype(cache["c"].dtype))
        ckr_pool = cache["kr"].at[page, off, :].set(
            kr_new.astype(cache["kr"].dtype))
        cc = cc_pool[block_tables].reshape(b, seq, m.kv_lora_rank)
        ckr = ckr_pool[block_tables].reshape(b, seq, m.qk_rope_dim)

    scale = 1.0 / jnp.sqrt(qh)
    s_lat = jnp.einsum("bqhc,bkc->bhqk", q_lat.astype(jnp.float32),
                       cc.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        ckr.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    if tree is None:
        valid = (jnp.arange(cc.shape[1])[None, None, None, :]
                 <= posm[:, None, :, None])  # (B, 1, T, S) — per-query frontier
    else:
        _, vis = tree_layout(*tree)
        valid = _tree_valid(vis, pos, t, cc.shape[1])[:, None, :, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqk,bkc->bqhc", w, cc.astype(jnp.float32))
    o = jnp.einsum("bqhc,hcd->bqhd", o_lat, dq(p["w_uv"], jnp.float32))
    o = o.reshape(b, t, -1).astype(x.dtype)
    return linear(o, p["wo"]), {"c": cc_pool, "kr": ckr_pool}


def mla_decode(p, x, cache, pos, *, n_heads: int, m: MLAConfig, rope_theta: float):
    """Absorbed decode: scores in latent space, W_uk/W_uv folded in."""
    b = x.shape[0]
    qh = m.qk_nope_dim + m.qk_rope_dim
    q = linear(x, p["wq"]).reshape(b, 1, n_heads, qh)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    pvec = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, pvec, rope_theta)
    # Absorb W_uk into the query: q_lat (B,1,H,kv_lora)
    q_lat = jnp.einsum("bqhd,hcd->bqhc", q_nope, dq(p["w_uk"], q_nope.dtype))

    ckv = linear(x, p["w_dkv"])
    c_new, kr_new = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    kr_new = apply_rope(kr_new[:, :, None, :], pvec, rope_theta)[:, :, 0, :]
    cc = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype), (0, pos, 0))
    ckr = jax.lax.dynamic_update_slice(cache["kr"], kr_new.astype(cache["kr"].dtype), (0, pos, 0))

    scale = 1.0 / jnp.sqrt(qh)
    s_lat = jnp.einsum("bqhc,bkc->bhqk", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32), ckr.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    valid = jnp.arange(cc.shape[1])[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqk,bkc->bqhc", w, cc.astype(jnp.float32))  # (B,1,H,lora)
    o = jnp.einsum("bqhc,hcd->bqhd", o_lat, dq(p["w_uv"], jnp.float32))
    o = o.reshape(b, 1, -1).astype(x.dtype)
    return linear(o, p["wo"]), {"c": cc, "kr": ckr}
