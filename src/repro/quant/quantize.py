"""Symmetric per-channel weight quantization + bit-plane / nibble packing,
plus the decode-time PartitionSpec derivation for sharding quantized leaves
over a tensor-parallel mesh (see :func:`decode_partition_spec`)."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass
class QuantizedTensor:
    """Weights as stored in 'PIM mode': integer codes + per-channel scale.

    codes: int8 codes in [-2^(bits-1), 2^(bits-1)-1], shape = original shape
           (or nibble-packed along axis 0 when ``packed`` is True, bits=4).
    scale: f32, broadcastable along the quantization axis.
    """

    codes: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    packed: bool = False

    @property
    def shape(self):
        if self.packed:
            return (2 * self.codes.shape[0],) + self.codes.shape[1:]
        return self.codes.shape


def quantize_symmetric(w: jnp.ndarray, bits: int = 8, axis: int = 0) -> QuantizedTensor:
    """Per-output-channel symmetric quantization (axis = reduction axis).

    The scale is chosen per channel of the *non*-reduction dims so the matmul
    can rescale once per output column.
    """
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    codes = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QuantizedTensor(codes=codes, scale=scale, bits=bits)


def dequantize(q: QuantizedTensor) -> jnp.ndarray:
    codes = unpack_int4(q.codes) if q.packed else q.codes
    return codes.astype(jnp.float32) * q.scale


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 codes pairwise along axis 0: (K, ...) int8 -> (K//2, ...) int8.

    Row 2i goes to the low nibble, row 2i+1 to the high nibble.  K must be
    even — callers with an odd K pad one zero-code row first (that is what
    ``serving.quantize_tree`` does, flagging it with ``nibbles_odd``).
    """
    if codes.shape[0] % 2:
        raise ValueError(
            f"pack_int4 requires an even K, got K={codes.shape[0]}; "
            "pad one zero code row (see serving.quantize_tree)")
    lo = codes[0::2] & 0xF
    hi = codes[1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`, with sign extension."""
    lo = ((packed & 0xF) ^ 8) - 8
    hi = (((packed >> 4) & 0xF) ^ 8) - 8
    k2 = packed.shape[0]
    out = jnp.stack([lo, hi], axis=1).reshape((2 * k2,) + packed.shape[1:])
    return out.astype(jnp.int8)


# ------------------------------------------------------- decode sharding ----
# Sentinel FSDP axis fed to launch.sharding.param_spec so the train-time rule
# reveals every dim it shards (batch axes included), not just 'model'.
_FSDP_SENTINEL = "fsdp"


def _train_axes(path_names: list[str], ndim: int) -> set:
    """The set of named axes the TRAIN-time rule puts on this leaf."""
    from repro.launch.sharding import param_spec

    axes: set = set()
    for entry in param_spec(path_names, ndim, _FSDP_SENTINEL):
        if entry is None:
            continue
        axes.update(entry if isinstance(entry, tuple) else (entry,))
    return axes


def decode_partition_spec(path_names: list[str], ndim: int,
                          axis: str = "model") -> P:
    """Decode-time PartitionSpec for a quantized weight leaf.

    The WHICH question — which leaves are worth distributing — is answered
    by the train-time rule (:func:`repro.launch.sharding.param_spec`): a
    leaf the trainer shards somewhere (tensor-parallel over 'model' or FSDP
    over the batch axes) is a real matmul weight whose bytes dominate the
    decode stream; a leaf the trainer replicates (router, norms, x_proj,
    conv kernels, SSM dynamics params) stays replicated at decode too.
    Deriving from ``param_spec`` instead of a second name table keeps the
    train-time and decode-time spec sets cross-checked — a new weight name
    added to one rule cannot silently diverge in the other
    (tests/test_sharded_decode.py asserts the correspondence per family).

    The WHERE question has a decode-specific answer: ``axis`` always lands
    on the LAST (output) dim, whatever dim the trainer shards.  Decode must
    be token-identical to the single-device engines, and only
    output-column sharding is exact — each column's contraction runs over
    the full K locally and the all-gather is pure concatenation.  The
    train-time placements (K-dim for wo/down, expert-dim for MoE) would
    need a psum whose float reassociation can flip greedy argmax at
    near-ties.

    Codes, scales, and int4 packing markers all follow this one spec: codes
    and scale both carry N on their last dim (int4 packs along K, never N),
    and the marker leaves hold only leading stack dims, so the returned
    spec left-truncates to a pure-replication spec for them.
    """
    if not _train_axes(path_names, ndim):
        return P(*(None,) * ndim)
    return P(*((None,) * (ndim - 1) + (axis,)))


def to_bitplanes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Integer codes -> bit-planes, LSB first: shape ``(bits,) + codes.shape``.

    Two's complement: plane ``bits-1`` carries weight ``-2^(bits-1)``.  This is
    the *spatial* analogue of PiCaSO's bit-serial striped storage (§III-A).
    """
    shifts = jnp.arange(bits, dtype=jnp.int32).reshape((bits,) + (1,) * codes.ndim)
    return ((codes.astype(jnp.int32)[None] >> shifts) & 1).astype(jnp.int8)


def from_bitplanes(planes: jnp.ndarray) -> jnp.ndarray:
    """Bit-planes -> int32 codes (two's complement)."""
    bits = planes.shape[0]
    weights = 2 ** jnp.arange(bits, dtype=jnp.int32)
    weights = weights.at[bits - 1].set(-weights[bits - 1])
    weights = weights.reshape((bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)
