"""Symmetric per-channel weight quantization + bit-plane / nibble packing."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass
class QuantizedTensor:
    """Weights as stored in 'PIM mode': integer codes + per-channel scale.

    codes: int8 codes in [-2^(bits-1), 2^(bits-1)-1], shape = original shape
           (or nibble-packed along axis 0 when ``packed`` is True, bits=4).
    scale: f32, broadcastable along the quantization axis.
    """

    codes: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    packed: bool = False

    @property
    def shape(self):
        if self.packed:
            return (2 * self.codes.shape[0],) + self.codes.shape[1:]
        return self.codes.shape


def quantize_symmetric(w: jnp.ndarray, bits: int = 8, axis: int = 0) -> QuantizedTensor:
    """Per-output-channel symmetric quantization (axis = reduction axis).

    The scale is chosen per channel of the *non*-reduction dims so the matmul
    can rescale once per output column.
    """
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    codes = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QuantizedTensor(codes=codes, scale=scale, bits=bits)


def dequantize(q: QuantizedTensor) -> jnp.ndarray:
    codes = unpack_int4(q.codes) if q.packed else q.codes
    return codes.astype(jnp.float32) * q.scale


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 codes pairwise along axis 0: (K, ...) int8 -> (K//2, ...) int8.

    Row 2i goes to the low nibble, row 2i+1 to the high nibble.  K must be
    even — callers with an odd K pad one zero-code row first (that is what
    ``serving.quantize_tree`` does, flagging it with ``nibbles_odd``).
    """
    if codes.shape[0] % 2:
        raise ValueError(
            f"pack_int4 requires an even K, got K={codes.shape[0]}; "
            "pad one zero code row (see serving.quantize_tree)")
    lo = codes[0::2] & 0xF
    hi = codes[1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`, with sign extension."""
    lo = ((packed & 0xF) ^ 8) - 8
    hi = (((packed >> 4) & 0xF) ^ 8) - 8
    k2 = packed.shape[0]
    out = jnp.stack([lo, hi], axis=1).reshape((2 * k2,) + packed.shape[1:])
    return out.astype(jnp.int8)


def to_bitplanes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Integer codes -> bit-planes, LSB first: shape ``(bits,) + codes.shape``.

    Two's complement: plane ``bits-1`` carries weight ``-2^(bits-1)``.  This is
    the *spatial* analogue of PiCaSO's bit-serial striped storage (§III-A).
    """
    shifts = jnp.arange(bits, dtype=jnp.int32).reshape((bits,) + (1,) * codes.ndim)
    return ((codes.astype(jnp.int32)[None] >> shifts) & 1).astype(jnp.int8)


def from_bitplanes(planes: jnp.ndarray) -> jnp.ndarray:
    """Bit-planes -> int32 codes (two's complement)."""
    bits = planes.shape[0]
    weights = 2 ** jnp.arange(bits, dtype=jnp.int32)
    weights = weights.at[bits - 1].set(-weights[bits - 1])
    weights = weights.reshape((bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)
