"""Weight quantization for PIM-mode execution.

The paper's PIM stores model weights bit-serially at reduced precision
(§I: "less than full precision operands can result in better utilization of
limited memory").  On TPU this becomes: weights live in HBM as packed INT4/
INT8 (or bit-planes) and are expanded to bf16 at the VMEM boundary inside the
matmul kernel — cutting HBM traffic by 16/B.
"""
from .quantize import (
    QuantizedTensor,
    decode_partition_spec,
    dequantize,
    from_bitplanes,
    pack_int4,
    quantize_symmetric,
    to_bitplanes,
    unpack_int4,
)

__all__ = [
    "QuantizedTensor",
    "quantize_symmetric",
    "dequantize",
    "pack_int4",
    "unpack_int4",
    "to_bitplanes",
    "from_bitplanes",
    "decode_partition_spec",
]
