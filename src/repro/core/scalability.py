"""Scalability model (paper Table VI, Fig 4).

PiCaSO's design goal: the PE array scales with BRAM capacity *independent of
the slice-to-BRAM ratio*.  SPAR-2, by contrast, is placement-limited by its
unique-control-set pressure (flip-flops sharing a slice must share a control
set; too many unique sets defeat placement long before slices run out).

The model below computes, for any device, the largest array each overlay can
realise, from three bounds: BRAM capacity, slice capacity, and the
control-set placement threshold.  Control-set-per-tile constants are
calibrated to the paper's Table VI observations (SPAR-2: 32.1% at 24K PEs on
xc7vx485 failing beyond; 19.5% at 63K on U55; PiCaSO: 2.1% / 0.8%).
"""
from __future__ import annotations

from dataclasses import dataclass

from .archmodels import TABLE_IV
from .devices import Device

TILE_PES = 256  # 4x4 PE-blocks of 16 PEs
TILE_BRAM18 = 16  # one BRAM18 per 16-PE block
PLACEMENT_CTRL_THRESHOLD = 0.322  # placement fails above this (calibrated, V7)
SLICE_CEILING = 0.90  # routable fraction of device slices

# Unique control sets per tile (calibrated to Table VI; see module docstring).
CTRL_SETS_PER_TILE = {
    ("spar2", "V7"): 256.0,
    ("spar2", "US+"): 128.0,
    ("picaso", "V7"): 12.4,
    ("picaso", "US+"): 5.2,
}

# At scale the placer packs tiles tighter than the standalone-tile synthesis
# numbers of Table IV; effective slice cost = packing * table-IV slice count.
# Calibrated against Table VI's achieved slice utilisations.
SLICE_PACKING = {
    ("spar2", "V7"): 0.65,
    ("spar2", "US+"): 0.75,
    ("picaso", "V7"): 0.86,
    ("picaso", "US+"): 0.85,
}

# Same effect on LUTs (synthesis of the full array shares logic the
# standalone tile cannot); calibrated to Table VI's LUT utilisations.
LUT_PACKING = {
    ("spar2", "V7"): 0.79,
    ("spar2", "US+"): 0.89,
    ("picaso", "V7"): 0.92,
    ("picaso", "US+"): 0.99,
}


@dataclass(frozen=True)
class FitReport:
    overlay: str
    device: str
    tiles: int
    pes: int
    lut_util: float
    ff_util: float
    slice_util: float
    bram_util: float
    ctrl_util: float
    limited_by: str


def _tile_cost(overlay: str, family: str) -> tuple[int, int, int]:
    key = "benchmark" if overlay == "spar2" else "full-pipe"
    dev = "V7" if family == "V7" else "U55"
    cfg = TABLE_IV[(key, dev)]
    return cfg.lut_tile, cfg.ff_tile, cfg.slice_tile


def max_array(overlay: str, device: Device) -> FitReport:
    """Largest array of ``overlay`` ('picaso' | 'spar2') fitting ``device``."""
    lut_t, ff_t, slice_t = _tile_cost(overlay, device.family)
    key = (overlay, device.family)
    slice_eff = slice_t * SLICE_PACKING[key]
    lut_eff = lut_t * LUT_PACKING[key]
    ctrl_t = CTRL_SETS_PER_TILE[key]
    ctrl_capacity = device.slices  # ~one control set per slice

    bram_bound = device.bram18 // TILE_BRAM18
    slice_bound = int(device.slices * SLICE_CEILING / slice_eff)
    ctrl_bound = int(PLACEMENT_CTRL_THRESHOLD * ctrl_capacity / ctrl_t)
    lut_bound = int(device.luts * 0.95 / lut_eff)

    tiles = min(bram_bound, slice_bound, ctrl_bound, lut_bound)
    # Order matters on ties: the paper attributes SPAR-2's V7 failure to
    # control sets (placement), which binds before raw LUT exhaustion.
    limited_by = "bram"
    for bound, label in (
        (slice_bound, "slice"),
        (ctrl_bound, "control-sets"),
        (lut_bound, "lut"),
    ):
        if bound < {"bram": bram_bound, "slice": slice_bound,
                    "control-sets": ctrl_bound, "lut": lut_bound}[limited_by]:
            limited_by = label

    return FitReport(
        overlay=overlay,
        device=device.short_id,
        tiles=tiles,
        pes=tiles * TILE_PES,
        lut_util=tiles * lut_eff / device.luts,
        ff_util=tiles * ff_t / device.ffs,
        slice_util=tiles * slice_eff / device.slices,
        bram_util=tiles * TILE_BRAM18 / device.bram18,
        ctrl_util=tiles * ctrl_t / ctrl_capacity,
        limited_by=limited_by,
    )


def scaling_study(devices: dict[str, Device]) -> dict[str, dict[str, FitReport]]:
    """Fig 4: PiCaSO vs SPAR-2 max arrays across the Table VII device set."""
    return {
        dev_id: {ov: max_array(ov, dev) for ov in ("picaso", "spar2")}
        for dev_id, dev in devices.items()
    }
