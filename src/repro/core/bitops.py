"""Bit-plane <-> integer conversions and the parallel-to-serial corner turn.

PiCaSO stores operands *bit-serially*: an N-bit operand occupies N consecutive
wordlines of a PE's register-file column (paper §III-A).  In the functional
simulator, the register file of a PE array is a ``uint8`` array of shape
``(num_pes, rf_depth)`` whose entries are single bits.  These helpers convert
between ordinary integer arrays and striped bit-plane storage.

Two's-complement semantics throughout: ``width``-bit operands represent values
in ``[-2**(width-1), 2**(width-1))``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def to_bits(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """Integer array -> bit-planes, LSB first.  Output shape ``x.shape + (width,)``."""
    x = jnp.asarray(x, dtype=jnp.int32)
    shifts = jnp.arange(width, dtype=jnp.int32)
    return ((x[..., None] >> shifts) & 1).astype(jnp.uint8)


def from_bits(bits: jnp.ndarray, signed: bool = True) -> jnp.ndarray:
    """Bit-planes (LSB first, last axis) -> int32, two's complement if signed."""
    width = bits.shape[-1]
    if width > 32:
        raise ValueError("from_bits supports widths up to 32 (int32 lanes)")
    weights = (1 << np.arange(width, dtype=np.int64)).astype(np.int64)
    if signed and width > 0:
        weights = weights.copy()
        weights[-1] = -weights[-1]
    # int32 modular arithmetic == two's-complement semantics for width <= 32.
    w32 = jnp.asarray(weights.astype(np.int64).astype(np.int32))
    return jnp.sum(bits.astype(jnp.int32) * w32, axis=-1, dtype=jnp.int32)


def sign_extend_bits(bits: jnp.ndarray, width: int) -> jnp.ndarray:
    """Extend bit-plane operands (last axis) to ``width`` bits, two's complement."""
    cur = bits.shape[-1]
    if cur >= width:
        return bits[..., :width]
    msb = bits[..., -1:]
    pad = jnp.broadcast_to(msb, bits.shape[:-1] + (width - cur,))
    return jnp.concatenate([bits, pad], axis=-1)


def corner_turn(words: jnp.ndarray, width: int) -> jnp.ndarray:
    """Parallel-to-serial corner turn (paper §III-A).

    Takes parallel data ``words`` of shape ``(num_pes,)`` (one word per PE,
    as read from DRAM/external I/O) and produces the striped column layout
    ``(num_pes, width)`` written into the BRAM register files.
    """
    return to_bits(words, width)


def corner_turn_inverse(striped: jnp.ndarray, signed: bool = True) -> jnp.ndarray:
    """Serial-to-parallel corner turn: gather a striped column back to words."""
    return from_bits(striped, signed=signed)
