"""Operand Multiplexer (OpMux) — zero-copy folding reduction (paper Fig 2, Table III).

The OpMux selects the ALU's X and Y operands.  Besides the pass-through
``A-OP-B`` configuration, the ``A-FOLD-k`` configurations route *another PE's*
bitline into the Y port, so a PE row can be reduced (summed) in log2 steps
without ever copying operands between bitlines — the paper's key memory
efficiency and accumulation-latency win.

Two fold families from Fig 2:
  pattern (a) "half" folds:      PE i receives PE (i + span)     (span halves)
  pattern (b) "adjacent" folds:  PE 2^k*i receives PE 2^k*i+2^(k-1)

For a 16-PE block, A-FOLD-1..4 are pattern (a) with span 8, 4, 2, 1; the
result accumulates in PE 0.
"""
from __future__ import annotations

import enum

import jax.numpy as jnp
import numpy as np

from .alu import serial_alu
from .isa import OpCode


class OpMuxConf(enum.IntEnum):
    """Table III configuration codes."""

    A_OP_B = 0      # X=A, Y=B: standard element-wise operation
    A_FOLD_1 = 1    # Y = {0, A[H2]}   second half of A
    A_FOLD_2 = 2    # Y = {0, A[Q2]}   second quarter
    A_FOLD_3 = 3    # Y = {0, A[HQ2]}  second half-quarter
    A_FOLD_4 = 4    # Y = {0, A[HHQ2]} second half of first half-quarter
    A_OP_NET = 5    # Y = network stream
    ZERO_OP_B = 6   # X = 0, Y = B: first iteration of MULT


def fold_source_index(block: int, level: int, pattern: str = "a") -> np.ndarray:
    """Lane index each PE's Y port reads from at fold ``level`` (1-based).

    Returns an index array ``src`` of length ``block``; lanes whose Y operand
    is the constant 0 are marked with ``-1``.
    """
    span = block >> level
    src = np.full((block,), -1, dtype=np.int64)
    if span < 1:
        raise ValueError(f"fold level {level} too deep for block of {block}")
    if pattern == "a":
        # PE i (< span) receives PE i + span.
        idx = np.arange(span)
        src[idx] = idx + span
    elif pattern == "b":
        # Adjacent folding: PE (2^level * i) receives PE (2^level*i + 2^(level-1)).
        stride = 1 << level
        idx = np.arange(0, block, stride)
        src[idx] = idx + (stride >> 1)
    else:
        raise ValueError(f"unknown fold pattern {pattern!r}")
    return src


def fold_operand(a_bits: jnp.ndarray, level: int, pattern: str = "a") -> jnp.ndarray:
    """Materialise the Y operand ``{0, A[...]}`` for ``A-FOLD-level``.

    ``a_bits``: ``(..., block, width)`` bit-planes.  Lanes not receiving data
    get 0 (per Table III the fold operand is zero outside the active half).
    """
    block = a_bits.shape[-2]
    src = fold_source_index(block, level, pattern)
    gathered = jnp.take(a_bits, jnp.asarray(np.where(src < 0, 0, src)), axis=-2)
    mask = jnp.asarray(src >= 0, dtype=a_bits.dtype)[..., :, None]
    return gathered * mask


def fold_reduce_block(a_bits: jnp.ndarray, pattern: str = "a") -> jnp.ndarray:
    """Sum all lanes of a PE block via successive A-FOLD serial ADDs.

    ``a_bits``: ``(block, width)``.  Returns the full ``(block, width)`` state
    after all folds; the reduction lives in lane 0 (pattern a) — exactly what
    the hardware leaves in the register file.  The operand width must already
    include enough headroom bits to hold the sum (callers sign-extend first,
    as the real machine stores products with headroom).
    """
    block, _ = a_bits.shape
    levels = int(np.log2(block))
    ops = jnp.full((block,), int(OpCode.ADD), dtype=jnp.int32)
    state = a_bits
    for level in range(1, levels + 1):
        y = fold_operand(state, level, pattern)
        state, _ = serial_alu(state, y, ops)
    return state


def fold_reduce_cycles(block: int, width: int, cycles_per_bit: int = 2) -> int:
    """Cycles for the in-block fold phase: log2(block) serial ADD passes."""
    return int(np.log2(block)) * cycles_per_bit * width
