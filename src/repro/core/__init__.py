"""PiCaSO core: bit-serial PIM overlay reproduction (FPL 2023).

Layers:
  isa/alu/booth/opmux/network — functional bit-level machine (JAX)
  simulator                   — PE-array machine with cycle accounting
  costmodel/archmodels        — the paper's analytical latency/throughput/
                                memory-efficiency models (Tables V, VIII)
  devices/scalability         — Table VII device DB + Table VI / Fig 4 model
"""
from .isa import OpCode, EncoderConf, booth_decode, encode
from .bitops import to_bits, from_bits, corner_turn, corner_turn_inverse, sign_extend_bits
from .alu import serial_alu, alu_cycles
from .booth import booth_multiply, booth_multiply_bits, booth_cycles, booth_nop_fraction
from .opmux import OpMuxConf, fold_operand, fold_reduce_block, fold_source_index
from .network import network_reduce_bits, node_roles, network_levels
from .simulator import PicasoArray, simulate_dot_product
from . import costmodel, archmodels, devices, scalability

__all__ = [
    "OpCode", "EncoderConf", "booth_decode", "encode",
    "to_bits", "from_bits", "corner_turn", "corner_turn_inverse", "sign_extend_bits",
    "serial_alu", "alu_cycles",
    "booth_multiply", "booth_multiply_bits", "booth_cycles", "booth_nop_fraction",
    "OpMuxConf", "fold_operand", "fold_reduce_block", "fold_source_index",
    "network_reduce_bits", "node_roles", "network_levels",
    "PicasoArray", "simulate_dot_product",
    "costmodel", "archmodels", "devices", "scalability",
]
