"""Functional PiCaSO array simulator with cycle accounting.

The machine is a grid of PE-blocks (16 bit-serial PEs each, one BRAM18 per
block).  State is the striped register file: ``(n_blocks, 16, rf_depth)``
single-bit planes.  Instructions operate on *address ranges* of the register
file, exactly like the hardware's wordline addressing, and every instruction
charges its paper-formula cycle cost to a counter — so the simulator both
computes correct values (validated against integer oracles) and reproduces
the Table V latencies.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from . import costmodel as cm
from .alu import serial_alu
from .bitops import from_bits, to_bits
from .booth import booth_multiply_bits
from .isa import OpCode
from .network import network_reduce_bits
from .opmux import fold_operand

BLOCK = 16


@dataclass
class PicasoArray:
    """A PiCaSO PIM array of ``n_blocks`` 16-PE blocks with ``rf_depth``-bit
    register files (1024 in the widest Virtex BRAM mode)."""

    n_blocks: int
    rf_depth: int = 1024
    pipeline: str = "full-pipe"  # affects only the cycle model
    rf: jnp.ndarray = field(init=False)
    cycles: int = field(default=0, init=False)

    def __post_init__(self):
        self.rf = jnp.zeros((self.n_blocks, BLOCK, self.rf_depth), dtype=jnp.uint8)

    # ------------------------------------------------------------- helpers --
    @property
    def num_pes(self) -> int:
        return self.n_blocks * BLOCK

    def _slice(self, addr: int, width: int) -> jnp.ndarray:
        return self.rf[:, :, addr : addr + width]

    def _store(self, addr: int, bits: jnp.ndarray) -> None:
        width = bits.shape[-1]
        self.rf = self.rf.at[:, :, addr : addr + width].set(bits)

    # -------------------------------------------------------------- I/O -----
    def write_operands(self, addr: int, values: jnp.ndarray, width: int) -> None:
        """Corner-turn parallel words into striped bit-serial storage.

        ``values``: ``(n_blocks, 16)`` integers (two's complement width-bit).
        The corner-turn happens at the memory interface and is not charged to
        the PE array (paper §III-A: done by the I/O path).
        """
        self._store(addr, to_bits(values, width))

    def read_operands(self, addr: int, width: int, signed: bool = True) -> jnp.ndarray:
        return from_bits(self._slice(addr, width), signed=signed)

    # ------------------------------------------------------- instructions ---
    def alu_op(self, op: OpCode, xa: int, ya: int, dest: int, width: int) -> None:
        """Element-wise serial ALU op: RF[dest] = RF[xa] op RF[ya]."""
        x = self._slice(xa, width).reshape(self.num_pes, width)
        y = self._slice(ya, width).reshape(self.num_pes, width)
        ops = jnp.full((self.num_pes,), int(op), dtype=jnp.int32)
        s, _ = serial_alu(x, y, ops)
        self._store(dest, s.reshape(self.n_blocks, BLOCK, width))
        self.cycles += cm.add_sub_cycles(width)

    def mult(self, xa: int, ya: int, dest: int, width: int) -> None:
        """Booth radix-2 multiply: RF[dest:dest+2N] = RF[xa] * RF[ya]."""
        m = self._slice(xa, width).reshape(self.num_pes, width)
        y = self._slice(ya, width).reshape(self.num_pes, width)
        p = booth_multiply_bits(m, y)
        self._store(dest, p.reshape(self.n_blocks, BLOCK, 2 * width))
        self.cycles += cm.mult_cycles_overlay(width)

    def fold_accumulate(self, addr: int, width: int, pattern: str = "a") -> None:
        """In-block OpMux fold reduction: lane 0 of each block gets the block sum.

        ``width`` must include headroom (callers place 2N-bit products plus
        log2(16)=4 guard bits before reducing, as the hardware does).
        """
        state = self._slice(addr, width).reshape(self.num_pes, width)
        state = state.reshape(self.n_blocks, BLOCK, width)
        ops = jnp.full((self.n_blocks * BLOCK,), int(OpCode.ADD), dtype=jnp.int32)
        for level in range(1, 5):  # A-FOLD-1..4 over 16 lanes
            y = fold_operand(state, level, pattern)
            s, _ = serial_alu(
                state.reshape(self.num_pes, width),
                y.reshape(self.num_pes, width),
                ops,
            )
            state = s.reshape(self.n_blocks, BLOCK, width)
        self._store(addr, state)
        # Full-Pipe folds run at 1 cycle/bit (Table V: the 4N term).
        self.cycles += 4 * width

    def network_accumulate(self, addr: int, width: int) -> None:
        """Binary-hopping reduction of each block's lane-0 into block 0."""
        lane0 = self._slice(addr, width)[:, 0, :]  # (n_blocks, width)
        reduced = network_reduce_bits(lane0)
        self.rf = self.rf.at[:, 0, addr : addr + width].set(reduced)
        jumps = cm.log2i(self.n_blocks) if self.n_blocks > 1 else 0
        self.cycles += jumps * (width + 4)  # (N+4) per network jump (Table V)

    # --------------------------------------------------------- composites ---
    def accumulate_row(self, addr: int, width: int) -> None:
        """Full q-column accumulation: folds then network (paper Table V).

        Charges the full PiCaSO-F accumulation formula including the fixed
        pipeline overhead, replacing the two phases' individual charges.
        """
        c0 = self.cycles
        self.fold_accumulate(addr, width)
        if self.n_blocks > 1:
            self.network_accumulate(addr, width)
        self.cycles = c0 + cm.accum_cycles_picaso(self.num_pes, width)

    def result_scalar(self, addr: int, width: int) -> jnp.ndarray:
        """The accumulation result: block 0, lane 0."""
        return from_bits(self.rf[0, 0, addr : addr + width], signed=True)


def dot_product_reference(x: np.ndarray, w: np.ndarray) -> int:
    return int(np.dot(x.astype(np.int64), w.astype(np.int64)))


def simulate_dot_product(
    x: np.ndarray, w: np.ndarray, width: int, rf_depth: int = 1024
) -> tuple[int, int]:
    """Map a q-length dot product onto a PiCaSO row and run it.

    Returns ``(value, cycles)``.  q must be a multiple of 16 (block size);
    operands are signed ``width``-bit.
    """
    q = len(x)
    n_blocks = max(q // BLOCK, 1)
    arr = PicasoArray(n_blocks=n_blocks, rf_depth=rf_depth)
    xs = jnp.asarray(x).reshape(n_blocks, BLOCK)
    ws = jnp.asarray(w).reshape(n_blocks, BLOCK)

    a_x, a_w, a_p = 0, width, 2 * width
    acc_width = 2 * width + cm.log2i(max(q, 2)) + 1  # headroom for the sum
    arr.write_operands(a_x, xs, width)
    arr.write_operands(a_w, ws, width)
    arr.mult(a_x, a_w, a_p, width)
    # Sign-extend products to accumulator width in place (free in HW: the
    # fold ALU pass reads the MSB repeatedly; we charge no extra cycles).
    prod = arr._slice(a_p, 2 * width)
    from .bitops import sign_extend_bits

    arr._store(a_p, sign_extend_bits(prod, acc_width))
    arr.accumulate_row(a_p, acc_width)
    return int(arr.result_scalar(a_p, acc_width)), arr.cycles
