"""FPGA device database (paper Table VII + the Table IV/VI evaluation parts).

LUT counts are reconstructed from Table VII's LUT-to-BRAM ratio x BRAM count
(which matches the public Xilinx numbers); FF = 2 x LUT and slices = LUT/4
(7-series, 4 LUT + 8 FF per slice) or LUT/8 (UltraScale+, 8 LUT + 16 FF).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Device:
    part: str
    family: str  # "V7" | "US+"
    bram36: int
    lut_to_bram: int
    short_id: str
    bram_fmax_mhz: float  # datasheet max BRAM clock for the speed grade

    @property
    def luts(self) -> int:
        return self.bram36 * self.lut_to_bram

    @property
    def ffs(self) -> int:
        return 2 * self.luts

    @property
    def slices(self) -> int:
        return self.luts // (4 if self.family == "V7" else 8)

    @property
    def bram18(self) -> int:
        return 2 * self.bram36

    @property
    def max_pes(self) -> int:
        """PiCaSO fits 16 bit-serial PEs per BRAM18 (paper §III-A)."""
        return 16 * self.bram18


# Paper Table VII (speed-grade fmax: -2 V7 ~ 543.77 MHz, -3/-2 US+ ~ 737 MHz).
TABLE_VII = {
    "V7-a": Device("xc7vx330tffg-2", "V7", 750, 272, "V7-a", 543.77),
    "V7-b": Device("xc7vx485tffg-2", "V7", 1030, 295, "V7-b", 543.77),
    "V7-c": Device("xc7v2000tfhg-2", "V7", 1292, 946, "V7-c", 543.77),
    "V7-d": Device("xc7vx1140tflg-2", "V7", 1880, 379, "V7-d", 543.77),
    "US-a": Device("xcvu3p-ffvc-3", "US+", 720, 547, "US-a", 737.0),
    "US-b": Device("xcvu23p-vsva-3", "US+", 2112, 488, "US-b", 737.0),
    "US-c": Device("xcvu19p-fsvb-2", "US+", 2160, 1892, "US-c", 737.0),
    "US-d": Device("xcvu29p-figd-3", "US+", 2688, 643, "US-d", 737.0),
}

# Evaluation devices of Tables IV and VI.
VIRTEX7_485 = TABLE_VII["V7-b"]  # xc7vx485 is the paper's Virtex-7 eval part
ALVEO_U55 = Device("xcu55c-fsvh2892-2L", "US+", 2016, 647, "U55", 737.0)

ALL_DEVICES = dict(TABLE_VII, U55=ALVEO_U55)
