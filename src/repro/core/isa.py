"""PiCaSO instruction-set: FA/S op-codes (Table I) and the Booth Op-Encoder (Table II).

The bit-serial ALU is a Full-Adder/Subtractor (FA/S) with four op-codes.  The
Op-Encoder sits in front of the FA/S and translates a 3-bit *configuration*
plus the current Booth bit-pair ``(y_i, y_{i-1})`` of the multiplier into an
FA/S op-code, exactly per Table II of the paper.
"""
from __future__ import annotations

import enum

import jax.numpy as jnp


class OpCode(enum.IntEnum):
    """FA/S op-codes (paper Table I)."""

    ADD = 0  # SUM = X + Y           (full adder)
    SUB = 1  # SUM = X - Y           (full adder with borrow logic)
    CPX = 2  # SUM = X               (copy operand X)
    CPY = 3  # SUM = Y               (copy operand Y)


class EncoderConf(enum.IntEnum):
    """Op-Encoder configurations (paper Table II, 'Conf' column)."""

    REQ_ADD = 0b000  # request ADD unconditionally
    SEL_X = 0b001    # select X operand (CPX)
    SEL_Y = 0b010    # select Y operand (CPY)
    REQ_SUB = 0b011  # request SUB unconditionally
    BOOTH = 0b100    # 1xx: decode from the Booth bit-pair YX


def booth_decode(y_pair: jnp.ndarray) -> jnp.ndarray:
    """Decode Booth radix-2 bit-pairs into FA/S op-codes (Table II, rows 1xx).

    ``y_pair`` holds ``2*y_i + y_{i-1}`` per lane:
      00 -> CPX (NOP: keep accumulator) ; 01 -> ADD (+Y) ;
      10 -> SUB (-Y)                    ; 11 -> CPX (NOP).
    """
    table = jnp.array(
        [OpCode.CPX, OpCode.ADD, OpCode.SUB, OpCode.CPX], dtype=jnp.int32
    )
    return table[y_pair]


def encode(conf: int, y_pair: jnp.ndarray) -> jnp.ndarray:
    """Full Op-Encoder: static configuration -> per-lane FA/S op-code array."""
    if conf == EncoderConf.REQ_ADD:
        code = OpCode.ADD
    elif conf == EncoderConf.SEL_X:
        code = OpCode.CPX
    elif conf == EncoderConf.SEL_Y:
        code = OpCode.CPY
    elif conf == EncoderConf.REQ_SUB:
        code = OpCode.SUB
    elif conf & 0b100:
        return booth_decode(y_pair)
    else:  # pragma: no cover - exhaustive above
        raise ValueError(f"unknown Op-Encoder configuration {conf:#05b}")
    return jnp.full(y_pair.shape, int(code), dtype=jnp.int32)
