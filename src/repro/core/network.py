"""Binary-hopping reduction network between PIM blocks (paper Fig 3, §III-D).

Each network node (one per PE-block) is configured per *level* L as a
Transmitter (T), Receiver (R) or Pass-through (P):

  level 0: even nodes receive from their right neighbour,
  level 1: every 4th node receives from node+2 (the node between is a P),
  level L: nodes with index % 2^(L+1) == 0 receive from index + 2^L.

During accumulation the transmitter's operand bits *stream* through P nodes
into the receiver's serial ALU (OpMux conf ``A-OP-NET``), so transfer overlaps
with computation; only the pipeline fill of the hop chain is exposed, which is
why a network jump costs ``N + 4`` cycles (Table V) instead of a full
store-and-forward copy.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .alu import serial_alu
from .isa import OpCode


def node_roles(n_nodes: int, level: int) -> list[str]:
    """Role of each node at ``level``: 'R', 'T', 'P' or '-' (idle)."""
    roles = []
    stride = 1 << (level + 1)
    span = 1 << level
    for i in range(n_nodes):
        if i % stride == 0 and i + span < n_nodes:
            roles.append("R")
        elif i % stride == span:
            roles.append("T")
        elif i % stride and i % stride < span:
            roles.append("P")  # sits between a later T and its R
        else:
            roles.append("P" if i % stride else "-")
    return roles


def network_reduce_bits(block_bits: jnp.ndarray) -> jnp.ndarray:
    """Reduce lane-0 operands across blocks via binary hopping.

    ``block_bits``: ``(n_blocks, width)`` bit-planes (each block's partial
    sum, i.e. its PE-0 register after the in-block folds).  Returns the state
    after all levels; the total lands in block 0.  Width must already include
    headroom for the sum.
    """
    n_blocks, _ = block_bits.shape
    levels = int(np.log2(n_blocks))
    state = block_bits
    for level in range(levels):
        span = 1 << level
        recv = np.arange(0, n_blocks, 1 << (level + 1))
        recv = recv[recv + span < n_blocks]
        x = state[recv]  # receivers' operands
        y = state[recv + span]  # transmitters', streamed over the net
        ops = jnp.full((len(recv),), int(OpCode.ADD), dtype=jnp.int32)
        s, _ = serial_alu(x, y, ops)
        state = state.at[recv].set(s)
    return state


def network_jump_cycles(width: int, fill: int = 4) -> int:
    """Cycles per network level: serial add of N bits + hop-chain fill."""
    return width + fill


def network_levels(n_blocks: int) -> int:
    return int(np.log2(n_blocks)) if n_blocks > 1 else 0
