"""Closed-form cycle-latency models (paper Tables V and VIII).

These are the paper's own analytical formulas, used both to reproduce its
tables and as the cost-accounting layer of the functional simulator.

Notation: N = operand width (bits), q = number of columns (PEs) accumulated.
"""
from __future__ import annotations

import math

BLOCK = 16  # PEs per PE-block (one BRAM's port width of bit-serial lanes)


def log2i(x: float) -> int:
    return int(round(math.log2(x)))


# ---------------------------------------------------------------- Table V ---
def add_sub_cycles(n: int) -> int:
    """ADD/SUB: 2N (both PiCaSO and the SPAR-2 benchmark)."""
    return 2 * n


def mult_cycles_overlay(n: int) -> int:
    """Booth radix-2 MULT on the overlay: 2N^2 + 2N (Table V / Table VIII(b))."""
    return 2 * n * n + 2 * n


def mult_cycles_overlay_booth_avg(n: int) -> int:
    """Average-case overlay MULT when the controller skips Booth NOPs.

    §V-B: half of Booth's intermediate steps are NOPs on average, so the
    multiplication latency can be reduced by ~50%.
    """
    return mult_cycles_overlay(n) // 2


def mult_cycles_custom(n: int) -> int:
    """Custom PIM blocks (CCB/CoMeFa): N^2 + 3N - 2 (Table VIII(a)).

    Custom designs extend the clock to a full read-modify-write per cycle, so
    a MULT takes roughly half the cycles of the 2-cycle-per-bit overlay.
    """
    return n * n + 3 * n - 2


def accum_cycles_spar2(q: int, n: int) -> int:
    """SPAR-2 NEWS-network accumulation: (q - 1 + 2*log2 q) * N (Table V)."""
    return (q - 1 + 2 * log2i(q)) * n


def accum_cycles_picaso(q: int, n: int) -> int:
    """PiCaSO-F accumulation: 15 + q/16 + 4N + (N+4)*J, J = log2(q/16).

    15 = controller/pipeline fixed overhead, q/16 = per-block drain, 4N = the
    four in-block OpMux folds (1 cycle/bit in Full-Pipe), (N+4) per network
    jump (serial add overlapped with hopping; 4 = hop-chain fill).
    For q <= 16 only the fold phase applies and the formula reduces to the
    Table VIII(d) form (N+4)*log2(q) when q = 16.
    """
    j = max(log2i(q) - log2i(BLOCK), 0)
    return 15 + q // BLOCK + 4 * n + (n + 4) * j


def accum_cycles_custom(q: int, n: int) -> int:
    """CCB / CoMeFa accumulation: (2N + log2 q) * log2 q (Table VIII(c)).

    Requires copying operands between bitlines each halving step (2N cycles
    of copy + log-step alignment) — no zero-copy fold.
    """
    return (2 * n + log2i(q)) * log2i(q)


def accum_cycles_picaso_block(q: int, n: int) -> int:
    """PiCaSO per-block form (N+4)*log2 q — Table VIII(d)."""
    return (n + 4) * log2i(q)


def accum_cycles_amod(q: int, n: int) -> int:
    """A-Mod / D-Mod (custom + PiCaSO OpMux/network): (N+2)*log2 q (VIII(e)).

    The custom RMW port saves the overlay's extra read cycle, and the OpMux
    removes the operand copies, leaving N+2 per halving step.
    """
    return (n + 2) * log2i(q)


# -------------------------------------------------------- composite ops -----
def mac16_cycles_overlay(n: int, booth_avg: bool = False) -> int:
    """16 parallel MULTs + in-block accumulation of the 16 products (Fig 5)."""
    mult = mult_cycles_overlay_booth_avg(n) if booth_avg else mult_cycles_overlay(n)
    return mult + accum_cycles_picaso_block(BLOCK, n)


def mac16_cycles_custom(n: int) -> int:
    return mult_cycles_custom(n) + accum_cycles_custom(BLOCK, n)


def mac16_cycles_mod(n: int) -> int:
    """A-Mod / D-Mod: custom MULT + PiCaSO-style zero-copy accumulation."""
    return mult_cycles_custom(n) + accum_cycles_amod(BLOCK, n)


def matvec_cycles_overlay(q: int, n: int, booth_avg: bool = False) -> int:
    """q-wide dot product on a PiCaSO row: q parallel MULTs + full reduction."""
    mult = mult_cycles_overlay_booth_avg(n) if booth_avg else mult_cycles_overlay(n)
    return mult + accum_cycles_picaso(q, n)
