"""Booth radix-2 bit-serial multiplier (paper §III-B, Table II).

Classic serial/parallel Booth recoding as implemented by the PiCaSO PE: a
2N-bit product register is updated over N steps; at step ``i`` the Op-Encoder
inspects the multiplier bit-pair ``(y_i, y_{i-1})`` and requests ADD (+M),
SUB (-M) or CPX (NOP) of the multiplicand ``M`` into the *upper half* of the
product register, which is then arithmetic-shifted right by one.  Each step is
an ``N+1``-bit serial ALU pass (2 cycles/bit), giving the paper's Table V
latency ``2N^2 + 2N``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .alu import serial_alu
from .bitops import from_bits, sign_extend_bits, to_bits
from .isa import booth_decode


def booth_multiply_bits(
    m_bits: jnp.ndarray, y_bits: jnp.ndarray
) -> jnp.ndarray:
    """Multiply bit-plane operands.

    Args:
      m_bits: multiplicand, ``(lanes, N)`` uint8 LSB-first two's complement.
      y_bits: multiplier, ``(lanes, N)``.

    Returns:
      Product bit-planes ``(lanes, 2N)`` (exact signed product, two's compl.).
    """
    lanes, width = m_bits.shape
    m_ext = sign_extend_bits(m_bits, width + 1)  # (lanes, N+1)

    p0 = jnp.zeros((lanes, 2 * width), dtype=jnp.uint8)
    y_prev0 = jnp.zeros((lanes,), dtype=jnp.uint8)

    def step(carry, y_i):
        p, y_prev = carry  # p: (lanes, 2N)
        pair = (2 * y_i + y_prev).astype(jnp.int32)
        op = booth_decode(pair)  # (lanes,) FA/S op-codes
        hi = sign_extend_bits(p[:, width:], width + 1)  # (lanes, N+1)
        s, _ = serial_alu(hi, m_ext, op)  # (lanes, N+1)
        # Arithmetic shift right by 1: low half picks up s[0]; high half = s[1:].
        p_new = jnp.concatenate([p[:, 1:width], s[:, :1], s[:, 1:]], axis=1)
        return (p_new, y_i), None

    (p, _), _ = jax.lax.scan(step, (p0, y_prev0), y_bits.T)
    return p


def booth_multiply(x: jnp.ndarray, y: jnp.ndarray, width: int) -> jnp.ndarray:
    """Integer-level wrapper: signed ``width``-bit multiply via the serial PE."""
    xb = to_bits(x, width)
    yb = to_bits(y, width)
    return from_bits(booth_multiply_bits(xb, yb), signed=True)


def booth_cycles(width: int) -> int:
    """Paper Table V: MULT latency (cycles) = 2N^2 + 2N."""
    return 2 * width * width + 2 * width


def booth_nop_fraction(y: jnp.ndarray, width: int) -> jnp.ndarray:
    """Fraction of Booth steps that are NOPs (bit-pairs 00/11).

    Paper §V-B: on average half of the intermediate steps are NOPs, which a
    controller-scheduled overlay can skip (custom designs mostly cannot).
    """
    yb = to_bits(y, width).astype(jnp.int32)
    prev = jnp.concatenate(
        [jnp.zeros(yb.shape[:-1] + (1,), jnp.int32), yb[..., :-1]], axis=-1
    )
    nop = (yb == prev).astype(jnp.float32)
    return jnp.mean(nop)
