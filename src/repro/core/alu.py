"""Bit-serial FA/S ALU (paper Fig 1(b), Table I).

The ALU processes one operand bit per step; a carry flip-flop holds the
running carry/borrow between steps, exactly like the hardware.  All PEs
(lanes) execute in SIMD, but the Op-Encoder may give each lane its own op-code
(Booth's algorithm uses per-lane multiplier bits), so the op-code is a per-lane
array.

Functional contract (validated in tests/test_core_alu.py):
  ADD: SUM = X + Y  (mod 2**width, two's complement)
  SUB: SUM = X - Y
  CPX: SUM = X
  CPY: SUM = Y
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .isa import OpCode


def _carry_init(op: jnp.ndarray) -> jnp.ndarray:
    """SUB lanes start with carry=1 (borrow via ~Y + 1); others with 0."""
    return (op == OpCode.SUB).astype(jnp.uint8)


def serial_alu(
    x_bits: jnp.ndarray, y_bits: jnp.ndarray, op: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the bit-serial FA/S over full operands.

    Args:
      x_bits, y_bits: ``(lanes, width)`` uint8 bit-planes, LSB first.
      op: ``(lanes,)`` int32 FA/S op-codes.

    Returns:
      ``(sum_bits, carry_out)`` with ``sum_bits`` of shape ``(lanes, width)``.
    """
    op = jnp.asarray(op, dtype=jnp.int32)
    carry0 = _carry_init(op)

    def step(carry, xy):
        x, y = xy  # each (lanes,) uint8
        y_eff = jnp.where(op == OpCode.SUB, 1 - y, y).astype(jnp.uint8)
        s_fa = (x ^ y_eff ^ carry).astype(jnp.uint8)
        c_fa = ((x & y_eff) | (carry & (x ^ y_eff))).astype(jnp.uint8)
        s = jnp.where(
            op == OpCode.CPX, x, jnp.where(op == OpCode.CPY, y, s_fa)
        ).astype(jnp.uint8)
        c = jnp.where((op == OpCode.CPX) | (op == OpCode.CPY), carry, c_fa)
        return c, s

    carry_out, sum_bits = jax.lax.scan(
        step, carry0, (x_bits.T, y_bits.T)
    )
    return sum_bits.T, carry_out


def alu_cycles(width: int, cycles_per_bit: int = 2) -> int:
    """Cycle cost of one serial ALU pass.

    PiCaSO needs 2 cycles per bit (read + write of the register file through a
    single port pair); hence ADD/SUB latency ``2N`` in paper Table V.
    """
    return cycles_per_bit * width
