"""Analytical models of the compared PIM architectures (paper Tables IV & VIII,
Figs 5-7): CCB, CoMeFa-D/A, PiCaSO-F, A-Mod/D-Mod, plus the SPAR-2 benchmark
overlay.

Every number used by the benchmarks is produced by these models; the paper's
published values are kept in tests/ as goldens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from . import costmodel as cm
from .devices import ALVEO_U55, VIRTEX7_485, Device


# ------------------------------------------------------------- Table IV -----
@dataclass(frozen=True)
class PipelineConfig:
    """One overlay pipeline configuration, per device (paper Table IV).

    Utilisation is per tile = 4x4 PE-blocks = 256 PEs (tile) and per block
    (16 PEs).
    """

    name: str
    device: str  # "V7" | "U55"
    lut_tile: int
    ff_tile: int
    slice_tile: int
    fmax_mhz: float

    @property
    def lut_block(self) -> int:
        return self.lut_tile // 16

    @property
    def ff_block(self) -> int:
        return self.ff_tile // 16

    @property
    def slice_block(self) -> int:
        return self.slice_tile // 16


TABLE_IV = {
    ("benchmark", "V7"): PipelineConfig("benchmark", "V7", 3023, 1024, 1056, 240.0),
    ("benchmark", "U55"): PipelineConfig("benchmark", "U55", 2449, 768, 556, 445.0),
    ("full-pipe", "V7"): PipelineConfig("full-pipe", "V7", 835, 1799, 522, 540.0),
    ("full-pipe", "U55"): PipelineConfig("full-pipe", "U55", 774, 1799, 243, 737.0),
    ("single-cycle", "V7"): PipelineConfig("single-cycle", "V7", 895, 1031, 395, 245.0),
    ("single-cycle", "U55"): PipelineConfig("single-cycle", "U55", 1068, 1031, 223, 487.0),
    ("rf-pipe", "V7"): PipelineConfig("rf-pipe", "V7", 1017, 1543, 451, 360.0),
    ("rf-pipe", "U55"): PipelineConfig("rf-pipe", "U55", 1064, 1527, 243, 600.0),
    ("op-pipe", "V7"): PipelineConfig("op-pipe", "V7", 836, 1543, 472, 370.0),
    ("op-pipe", "U55"): PipelineConfig("op-pipe", "U55", 774, 1543, 295, 620.0),
}


# ------------------------------------------------------------ Table VIII ----
@dataclass(frozen=True)
class PimArch:
    """One PIM architecture's analytical model (paper Table VIII columns)."""

    name: str
    kind: str  # "custom" | "overlay"
    clock_overhead: float  # fractional fmax degradation vs the BRAM fmax
    parallel_macs_per_bram36: int
    mult_cycles: Callable[[int], int]
    accum_cycles: Callable[[int, int], int]  # (q, n) -> cycles
    reserved_wordlines_per_bit: int  # scratchpad wordlines per operand bit
    rf_bits_per_pe: int  # register-file (bitline) depth per PE
    booth: str  # "yes" | "partial" | "no"
    complexity: str
    practicality: str

    def fmax(self, device: Device) -> float:
        """Achievable clock (MHz): BRAM fmax degraded by the design's overhead."""
        return device.bram_fmax_mhz / (1.0 + self.clock_overhead)

    # ---- Fig 7: BRAM memory-utilisation efficiency ----
    def memory_efficiency(self, n: int) -> float:
        """Fraction of BRAM usable for model weights at N-bit precision.

        CCB needs 8N reserved wordlines (Neural-Cache style scratch), CoMeFa
        5N (OOOR), PiCaSO and the -Mod designs 4N (zero-copy OpMux folds).
        """
        reserved = self.reserved_wordlines_per_bit * n
        return (self.rf_bits_per_pe - reserved) / self.rf_bits_per_pe

    # ---- Fig 5: latency of 16 parallel MULTs + product accumulation ----
    def mac16_latency_us(self, n: int, device: Device, booth_avg: bool = False) -> float:
        mult = self.mult_cycles(n)
        if booth_avg and self.booth == "yes":
            mult //= 2
        cycles = mult + self.accum_cycles(16, n)
        return cycles / self.fmax(device)  # MHz -> us

    # ---- Fig 6: peak MAC throughput on a device ----
    def peak_tmacs(self, n: int, device: Device, booth_avg: bool = True) -> float:
        """Peak TeraMAC/s: all PEs issuing back-to-back MULTs.

        The paper's Fig 6 peak assumes the controller exploits Booth NOP
        skipping on the overlay (§V-B) — we expose the flag so both numbers
        are reported.
        """
        mult = self.mult_cycles(n)
        if booth_avg and self.booth == "yes":
            mult //= 2
        pes = self.parallel_macs_per_bram36 * device.bram36
        return pes * self.fmax(device) * 1e6 / mult / 1e12


ARCHS = {
    "CCB": PimArch(
        "CCB", "custom", 0.60, 144, cm.mult_cycles_custom, cm.accum_cycles_custom,
        8, 256, "no", "high", "low",
    ),
    "CoMeFa-D": PimArch(
        "CoMeFa-D", "custom", 0.25, 144, cm.mult_cycles_custom, cm.accum_cycles_custom,
        5, 256, "partial", "medium", "medium",
    ),
    "CoMeFa-A": PimArch(
        "CoMeFa-A", "custom", 1.50, 144, cm.mult_cycles_custom, cm.accum_cycles_custom,
        5, 256, "partial", "medium", "high",
    ),
    "PiCaSO-F": PimArch(
        "PiCaSO-F", "overlay", 0.0, 36, cm.mult_cycles_overlay,
        cm.accum_cycles_picaso_block, 4, 1024, "yes", "none", "very high",
    ),
    "A-Mod": PimArch(
        "A-Mod", "custom", 1.50, 144, cm.mult_cycles_custom, cm.accum_cycles_amod,
        4, 256, "yes", "medium", "high",
    ),
    "D-Mod": PimArch(
        "D-Mod", "custom", 0.25, 144, cm.mult_cycles_custom, cm.accum_cycles_amod,
        4, 256, "yes", "medium", "medium",
    ),
}

# SPAR-2 (benchmark overlay) for the Table V comparison: NEWS-network copies.
SPAR2 = PimArch(
    "SPAR-2", "overlay", 0.0, 32, cm.mult_cycles_overlay, cm.accum_cycles_spar2,
    4, 1024, "yes", "none", "high",
)


def relative_mac_latency(n: int, device: Device = ALVEO_U55) -> dict[str, float]:
    """Fig 5: MAC latency of each design relative to PiCaSO-F (>1 = slower)."""
    base = ARCHS["PiCaSO-F"].mac16_latency_us(n, device)
    return {
        name: arch.mac16_latency_us(n, device) / base
        for name, arch in ARCHS.items()
    }


def peak_throughput_table(n: int, device: Device = ALVEO_U55) -> dict[str, float]:
    """Fig 6: peak TeraMAC/s per design on the given device."""
    return {name: arch.peak_tmacs(n, device) for name, arch in ARCHS.items()}


def memory_efficiency_table(n: int) -> dict[str, float]:
    """Fig 7 points at precision n."""
    return {name: arch.memory_efficiency(n) for name, arch in ARCHS.items()}


__all__ = [
    "ARCHS", "SPAR2", "TABLE_IV", "PimArch", "PipelineConfig",
    "relative_mac_latency", "peak_throughput_table", "memory_efficiency_table",
    "ALVEO_U55", "VIRTEX7_485",
]
