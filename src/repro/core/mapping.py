"""Map linear-algebra workloads onto the PiCaSO array (corner turning +
row-per-output scheduling) — the application layer of the paper's machine.

A matvec ``W (M, K) @ x (K,)`` maps one output element per PE *row* of K
PEs: weights are corner-turned into bit-serial columns (§III-A), every row
multiplies element-wise with the broadcast activation (Booth, all rows in
parallel — SIMD), then each row fold/network-reduces into its PE 0.  The
cycle model is therefore one MULT + one row-accumulation regardless of M,
as long as M rows fit the array — exactly the scaling argument of the
paper's throughput analysis (Fig 6).
"""
from __future__ import annotations

import numpy as np

from . import costmodel as cm
from .simulator import BLOCK, simulate_dot_product


def matvec_cycles(m_rows: int, k: int, width: int, total_pes: int,
                  booth_avg: bool = False) -> int:
    """Cycles for W(M,K) @ x on an array of ``total_pes`` bit-serial PEs."""
    rows_at_once = max(total_pes // k, 1)
    waves = -(-m_rows // rows_at_once)
    mult = (cm.mult_cycles_overlay_booth_avg(width) if booth_avg
            else cm.mult_cycles_overlay(width))
    acc_w = 2 * width + cm.log2i(max(k, 2)) + 1
    return waves * (mult + cm.accum_cycles_picaso(k, acc_w))


def simulate_matvec(w: np.ndarray, x: np.ndarray, width: int):
    """Functionally execute W @ x on the simulated array (row per wave).

    Returns (values (M,), cycles) with the parallel-wave cycle model (rows
    run SIMD-parallel in hardware; the functional sim iterates them).
    """
    m, k = w.shape
    assert k % BLOCK == 0, f"K={k} must be a multiple of the 16-PE block"
    vals = np.empty((m,), dtype=np.int64)
    per_row_cycles = 0
    for i in range(m):
        vals[i], per_row_cycles = simulate_dot_product(x, w[i], width)
    # SIMD: all rows that fit the array execute in the same wave.
    total = matvec_cycles(m, k, width, total_pes=max(m * k, k))
    assert total == per_row_cycles, (total, per_row_cycles)
    return vals, total
