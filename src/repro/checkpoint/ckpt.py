"""Fault-tolerant checkpointing (no external deps).

Design (mirrors Orbax semantics at framework scale):
  * one directory per step, written to ``<step>.tmp`` then atomically renamed
    — a crash mid-save never corrupts the latest checkpoint;
  * leaves stored as .npy inside a flat key->file layout with a JSON manifest
    (pytree structure, dtypes, shapes) — restore works without the model;
  * per-host shard files (``shard<k>``) so each data-parallel host writes
    only its addressable slice at scale;
  * ``keep_last`` garbage collection;
  * ``latest_step`` + manifest validation gives crash-safe resume, which the
    runtime (repro.runtime) uses for restart-on-failure.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # np.save can't store ml_dtypes (bf16 etc.); upcast losslessly.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_tree(tree, directory: str, shard: int = 0) -> None:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    manifest = {
        "treedef": str(jax.tree_util.tree_structure(tree)),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        "shard": shard,
    }
    for k, v in flat.items():
        fn = os.path.join(directory, k.replace("/", "__") + f".shard{shard}.npy")
        np.save(fn, v)
    with open(os.path.join(directory, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)


def restore_tree(template, directory: str, shard: int = 0):
    """Restore into the structure (and dtypes) of ``template``."""
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    missing = set(flat_t) - set(manifest["keys"])
    if missing:
        raise ValueError(f"checkpoint at {directory} missing keys: {sorted(missing)[:5]}")
    leaves_by_key = {}
    for k in flat_t:
        fn = os.path.join(directory, k.replace("/", "__") + f".shard{shard}.npy")
        leaves_by_key[k] = np.load(fn)

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = leaves_by_key[key]
        out.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    """Atomic step checkpoints with retention and resume."""

    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)

    def dir_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree, shard: int = 0) -> str:
        final = self.dir_for(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_tree(tree, tmp, shard=shard)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def restore_latest(self, template, shard: int = 0):
        step = latest_step(self.root)
        if step is None:
            return None, None
        return step, restore_tree(template, self.dir_for(step), shard=shard)

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir_for(s), ignore_errors=True)
