"""Fault-tolerant checkpointing (no external deps).

Design (mirrors Orbax semantics at framework scale):
  * one directory per step, written to ``<step>.tmp`` then atomically renamed
    — a crash mid-save never corrupts the latest checkpoint;
  * durability discipline: every leaf file is fsync'd, the MANIFEST is
    written LAST (it is the commit record — ``latest_step`` only counts
    directories whose manifest exists), and the parent directory is
    fsync'd around the publish rename, so a kill -9 / power cut at ANY
    point leaves either the old checkpoint or the complete new one, never
    a half-written directory that parses as valid;
  * leaves stored as .npy inside a flat key->file layout with a JSON manifest
    (pytree structure, dtypes, shapes) — restore works without the model;
  * per-host shard files (``shard<k>``) so each data-parallel host writes
    only its addressable slice at scale;
  * ``keep_last`` garbage collection (also sweeps orphaned ``.tmp``/``.old``
    staging directories left by a crash mid-save);
  * ``latest_step`` + manifest validation gives crash-safe resume, which the
    runtime (repro.runtime) uses for restart-on-failure and
    crash-mid-save behavior is locked by tests/test_checkpoint_atomic.py.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _fsync_path(path: str) -> None:
    """fsync a file or directory; directory fsync makes renames/creates
    inside it durable (no-op where unsupported)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir-open
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _step_of(name: str) -> int | None:
    """Parse ``step_<n>`` directory names; None for staging/foreign entries
    (``step_00000001.tmp``, ``step_00000001.old``, stray files) so a crash's
    leftovers never break resume."""
    if not name.startswith("step_"):
        return None
    suffix = name[len("step_"):]
    return int(suffix) if suffix.isdigit() else None


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # np.save can't store ml_dtypes (bf16 etc.); upcast losslessly.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_tree(tree, directory: str, shard: int = 0) -> None:
    """Write every leaf (fsync'd), then the manifest LAST (fsync'd): the
    manifest is the commit record, so a directory with a manifest always
    has all its leaf files durably on disk."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    manifest = {
        "treedef": str(jax.tree_util.tree_structure(tree)),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        "shard": shard,
    }
    for k, v in flat.items():
        fn = os.path.join(directory, k.replace("/", "__") + f".shard{shard}.npy")
        with open(fn, "wb") as f:
            np.save(f, v)
            f.flush()
            os.fsync(f.fileno())
    with open(os.path.join(directory, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(directory)


def restore_tree(template, directory: str, shard: int = 0):
    """Restore into the structure (and dtypes) of ``template``."""
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    missing = set(flat_t) - set(manifest["keys"])
    if missing:
        raise ValueError(f"checkpoint at {directory} missing keys: {sorted(missing)[:5]}")
    leaves_by_key = {}
    for k in flat_t:
        fn = os.path.join(directory, k.replace("/", "__") + f".shard{shard}.npy")
        leaves_by_key[k] = np.load(fn)

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = leaves_by_key[key]
        out.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        step = _step_of(name)
        if step is not None:
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                steps.append(step)
    return max(steps) if steps else None


class CheckpointManager:
    """Atomic step checkpoints with retention and resume."""

    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)

    def dir_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree, shard: int = 0) -> str:
        """Stage to ``<dir>.tmp`` (fully fsync'd, manifest last), then
        publish with one atomic rename.  Re-saving an existing step moves
        the old directory aside FIRST (``.old``, invisible to
        ``latest_step``) instead of deleting it in place — there is no
        instant at which the step exists half-written or not at all; the
        aside copy is swept after the rename (and by ``_gc`` if the
        process dies in between)."""
        final = self.dir_for(step)
        tmp = final + ".tmp"
        old = final + ".old"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_tree(tree, tmp, shard=shard)
        if os.path.exists(old):
            shutil.rmtree(old)
        if os.path.exists(final):
            os.rename(final, old)
        os.rename(tmp, final)  # atomic publish
        _fsync_path(self.root)  # make the rename itself durable
        if os.path.exists(old):
            shutil.rmtree(old)
        self._gc()
        return final

    def restore_latest(self, template, shard: int = 0):
        step = latest_step(self.root)
        if step is None:
            return None, None
        return step, restore_tree(template, self.dir_for(step), shard=shard)

    def _gc(self) -> None:
        steps = []
        for n in os.listdir(self.root):
            if n.startswith("step_") and (n.endswith(".tmp")
                                          or n.endswith(".old")):
                # Orphaned staging dir from a crash mid-save.
                shutil.rmtree(os.path.join(self.root, n), ignore_errors=True)
            elif _step_of(n) is not None:
                steps.append(_step_of(n))
        for s in sorted(steps)[: -self.keep_last]:
            shutil.rmtree(self.dir_for(s), ignore_errors=True)
