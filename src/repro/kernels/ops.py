"""Public jit'd entry points for the PIM kernels.

Selects interpret mode automatically off-TPU so the same call sites work in
CPU tests (Pallas interpret) and on real hardware (compiled Mosaic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import QuantizedTensor, pack_int4, quantize_symmetric, to_bitplanes

from .bitplane import bitplane_matmul
from .fold_reduce import fold_reduce
from .pim_matmul import pim_matmul
from .pim_matvec import pim_matvec


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_for_pim(w: jnp.ndarray, bits: int = 8) -> QuantizedTensor:
    """Quantize a (K, N) weight for PIM-mode matmul (packs nibbles for int4)."""
    q = quantize_symmetric(w, bits=bits, axis=0)
    if bits == 4:
        return QuantizedTensor(pack_int4(q.codes), q.scale, 4, packed=True)
    return q


def pim_dense(x: jnp.ndarray, q: QuantizedTensor, **kw) -> jnp.ndarray:
    """Quantized dense layer: x @ dequant(q).  Accepts int4-packed or int8."""
    return pim_matmul(
        x, q.codes, q.scale, bits=q.bits, interpret=_interpret(), **kw
    )


def pim_matvec_dense(x: jnp.ndarray, q: QuantizedTensor, *, bias=None,
                     activation: str = "none", residual=None, **kw) -> jnp.ndarray:
    """Decode-shaped (M<=8) quantized matvec with the fused epilogue."""
    return pim_matvec(
        x, q.codes, q.scale, bits=q.bits, bias=bias, activation=activation,
        residual=residual, interpret=_interpret(), **kw
    )


def pim_dense_bitplane(x: jnp.ndarray, w: jnp.ndarray, bits: int = 4, **kw) -> jnp.ndarray:
    """PIM-semantic path: quantize + bit-plane decompose + plane-wise matmul."""
    q = quantize_symmetric(w, bits=bits, axis=0)
    planes = to_bitplanes(q.codes, bits)
    return bitplane_matmul(x, planes, q.scale, interpret=_interpret(), **kw)


def fold_sum(x: jnp.ndarray, **kw) -> jnp.ndarray:
    """OpMux-fold reduction of the last axis (power-of-two length)."""
    return fold_reduce(x, interpret=_interpret(), **kw)


__all__ = [
    "pim_matmul", "pim_matvec", "bitplane_matmul", "fold_reduce",
    "quantize_for_pim", "pim_dense", "pim_matvec_dense",
    "pim_dense_bitplane", "fold_sum",
]
